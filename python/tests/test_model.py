"""L2 correctness: TinyGPT shapes, KV-cache semantics, and the
prefill/decode equivalence that the serving layer's cache reuse relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

PARAMS = model.init_params(0)


def test_param_layout_consistent():
    assert PARAMS.shape == (model.param_count(),)
    p = model.unflatten(PARAMS)
    assert p["tok_emb"].shape == (model.VOCAB, model.D_MODEL)
    assert p["l3.w2"].shape == (model.MLP, model.D_MODEL)
    # Unflatten must cover the vector exactly (no overlap / gap): sum check.
    total = sum(int(np.prod(v.shape)) for v in p.values())
    assert total == model.param_count()


def test_init_is_deterministic():
    a = model.init_params(0)
    b = model.init_params(0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = model.init_params(1)
    assert not np.allclose(np.asarray(a), np.asarray(c))


def test_prefill_shapes_and_kv_insertion():
    tokens = jnp.arange(model.T_PRE, dtype=jnp.int32) % model.VOCAB
    kv = model.empty_kv()
    tok, kv2 = model.prefill(PARAMS, tokens, kv, jnp.int32(0))
    assert tok.shape == ()
    assert kv2.shape == model.KV_SHAPE
    # KV must be written exactly for positions [0, T_PRE) and untouched after.
    filled = np.asarray(kv2[:, :, :, : model.T_PRE, :])
    assert np.abs(filled).sum() > 0
    rest = np.asarray(kv2[:, :, :, model.T_PRE :, :])
    assert np.abs(rest).sum() == 0


def test_decode_appends_single_position():
    tokens = jnp.arange(model.T_PRE, dtype=jnp.int32)
    _, kv = model.prefill(PARAMS, tokens, model.empty_kv(), jnp.int32(0))
    tok2, kv2 = model.decode(PARAMS, jnp.array([42], jnp.int32), kv, jnp.int32(model.T_PRE))
    changed = np.asarray(kv2) != np.asarray(kv)
    # Only the T_PRE-th position may change.
    pos_changed = np.where(changed.any(axis=(0, 1, 2, 4)))[0]
    np.testing.assert_array_equal(pos_changed, [model.T_PRE])
    assert 0 <= int(tok2) < model.VOCAB


def test_chunked_prefill_equals_fresh_history():
    """Serving equivalence: prefilling chunk B on top of cached chunk A must
    give the same next-token as prefilling [A; B] from scratch. This is the
    property that makes HiCache-style KV reuse lossless."""
    rng = np.random.RandomState(7)
    a = jnp.asarray(rng.randint(0, model.VOCAB, model.T_PRE), jnp.int32)
    b = jnp.asarray(rng.randint(0, model.VOCAB, model.T_PRE), jnp.int32)
    # Path 1: two chunks with cache carried over.
    _, kv1 = model.prefill(PARAMS, a, model.empty_kv(), jnp.int32(0))
    t1, kv1b = model.prefill(PARAMS, b, kv1, jnp.int32(model.T_PRE))
    # Path 2: same, but the cache for A was "fetched" (bitwise copy).
    kv_fetched = jnp.asarray(np.asarray(kv1).copy())
    t2, _ = model.prefill(PARAMS, b, kv_fetched, jnp.int32(model.T_PRE))
    assert int(t1) == int(t2)
    assert kv1b.shape == model.KV_SHAPE


def test_greedy_decode_is_deterministic():
    tokens = jnp.arange(model.T_PRE, dtype=jnp.int32)
    t1, kv1 = model.prefill(PARAMS, tokens, model.empty_kv(), jnp.int32(0))
    t2, kv2 = model.prefill(PARAMS, tokens, model.empty_kv(), jnp.int32(0))
    assert int(t1) == int(t2)
    np.testing.assert_array_equal(np.asarray(kv1), np.asarray(kv2))


def test_kv_bytes_accounting():
    assert model.KV_BYTES == int(np.prod(model.KV_SHAPE)) * 4
    assert model.KV_BYTES_PER_TOKEN * model.T_MAX == model.KV_BYTES
