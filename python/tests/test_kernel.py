"""L1 correctness: Pallas decode-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, block sizes, and offsets; targeted tests
cover the serving-relevant shapes and the masking edge cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.ref import ref_attention

jax.config.update("jax_platform_name", "cpu")


def make_inputs(h, tq, tmax, dh, start, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (h, tq, dh), dtype)
    k = jax.random.normal(kk, (h, tmax, dh), dtype)
    v = jax.random.normal(kv, (h, tmax, dh), dtype)
    return q, k, v, jnp.int32(start)


def check(h, tq, tmax, dh, start, dtype=jnp.float32, block_k=128, atol=1e-4, seed=0):
    q, k, v, s = make_inputs(h, tq, tmax, dh, start, dtype, seed)
    got = decode_attention(q, k, v, s, block_k=block_k)
    want = ref_attention(q, k, v, s)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol, rtol=atol
    )


# ---- serving shapes ----


def test_prefill_shape():
    check(h=4, tq=128, tmax=640, dh=64, start=0)


def test_prefill_mid_history():
    check(h=4, tq=128, tmax=640, dh=64, start=256)


def test_decode_shape():
    check(h=4, tq=1, tmax=640, dh=64, start=639 - 0)


def test_decode_first_token():
    check(h=4, tq=1, tmax=640, dh=64, start=0)


def test_last_block_exactly_fits():
    check(h=4, tq=128, tmax=640, dh=64, start=512)


# ---- edge cases ----


def test_single_head():
    check(h=1, tq=16, tmax=128, dh=32, start=5)


def test_tiny_block_k():
    check(h=2, tq=8, tmax=64, dh=16, start=3, block_k=16)


def test_block_k_equals_tmax():
    check(h=2, tq=8, tmax=128, dh=16, start=0, block_k=128)


def test_non_multiple_tmax_rejected():
    q, k, v, s = make_inputs(1, 1, 100, 16, 0, jnp.float32)
    with pytest.raises(ValueError):
        decode_attention(q, k, v, s, block_k=64)


def test_bf16_tolerance():
    check(h=2, tq=16, tmax=256, dh=32, start=17, dtype=jnp.bfloat16, atol=3e-2)


def test_mask_blocks_future_keys():
    """Keys beyond start+i must not influence the output: poisoning them
    with huge values must not change anything."""
    h, tq, tmax, dh, start = 2, 4, 128, 16, 10
    q, k, v, s = make_inputs(h, tq, tmax, dh, start, jnp.float32)
    out1 = decode_attention(q, k, v, s)
    k2 = k.at[:, start + tq :, :].set(1e4)
    v2 = v.at[:, start + tq :, :].set(-1e4)
    out2 = decode_attention(q, k2, v2, s)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_causality_within_block():
    """Row i must see key start+i but not start+i+1."""
    h, tq, tmax, dh = 1, 8, 64, 8
    q, k, v, s = make_inputs(h, tq, tmax, dh, 0, jnp.float32, seed=3)
    out = decode_attention(q, k, v, s)
    # Changing key at position 7 must not affect rows 0..6.
    k2 = k.at[:, 7, :].set(123.0)
    out2 = decode_attention(q, k2, v, s)
    np.testing.assert_allclose(
        np.asarray(out[:, :7]), np.asarray(out2[:, :7]), atol=1e-5
    )
    assert not np.allclose(np.asarray(out[:, 7]), np.asarray(out2[:, 7]))


# ---- hypothesis sweep ----


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 4),
    tq_pow=st.integers(0, 5),
    nkb=st.integers(1, 5),
    dh=st.sampled_from([8, 16, 32, 64]),
    block_k=st.sampled_from([16, 32, 64, 128]),
    start_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_sweep(h, tq_pow, nkb, dh, block_k, start_frac, seed):
    tq = 1 << tq_pow  # 1..32
    tmax = nkb * block_k
    if tmax < tq:
        tmax = ((tq + block_k - 1) // block_k) * block_k
    start = int(start_frac * (tmax - tq))
    check(h, tq, tmax, dh, start, block_k=block_k, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    dh=st.sampled_from([16, 32]),
    start=st.integers(0, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_sweep(dh, start, seed):
    check(h=2, tq=1, tmax=128, dh=dh, start=start, block_k=32, seed=seed)
