"""Layer 2 — TinyGPT: the JAX model served by the Rust coordinator.

A small GPT-style decoder whose attention hot loop is the Layer-1 Pallas
kernel. Both serving phases are a single `step` function specialized by
shape at AOT time:

* **prefill** — a 128-token chunk attends over the KV cache and appends its
  own K/V at `offset`;
* **decode** — the same with a 1-token block.

All parameters travel as ONE flat f32 vector so the Rust runtime passes a
single weights literal (and the checkpoint-engine benches treat the same
buffer as the update payload).
"""

import jax
import jax.numpy as jnp

from .kernels.decode_attention import decode_attention

# Model dimensions (fixed at AOT time; see DESIGN.md for the scaling note).
VOCAB = 4096
D_MODEL = 256
LAYERS = 4
HEADS = 4
HEAD_DIM = 64
MLP = 4 * D_MODEL
T_MAX = 640
T_PRE = 128
EPS = 1e-5

KV_SHAPE = (LAYERS, 2, HEADS, T_MAX, HEAD_DIM)
KV_BYTES = LAYERS * 2 * HEADS * T_MAX * HEAD_DIM * 4
KV_BYTES_PER_TOKEN = LAYERS * 2 * HEADS * HEAD_DIM * 4


def param_specs():
    """Fixed (name, shape) layout of the flat parameter vector."""
    specs = [("tok_emb", (VOCAB, D_MODEL)), ("pos_emb", (T_MAX, D_MODEL))]
    for l in range(LAYERS):
        specs += [
            (f"l{l}.ln1", (D_MODEL,)),
            (f"l{l}.wq", (D_MODEL, D_MODEL)),
            (f"l{l}.wk", (D_MODEL, D_MODEL)),
            (f"l{l}.wv", (D_MODEL, D_MODEL)),
            (f"l{l}.wo", (D_MODEL, D_MODEL)),
            (f"l{l}.ln2", (D_MODEL,)),
            (f"l{l}.w1", (D_MODEL, MLP)),
            (f"l{l}.w2", (MLP, D_MODEL)),
        ]
    specs.append(("lnf", (D_MODEL,)))
    return specs


def _size(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def param_count():
    return sum(_size(s) for _, s in param_specs())


def init_params(seed: int = 0):
    """Deterministic init; returns the flat f32 vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "lnf")):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            w = jax.random.normal(sub, shape, jnp.float32) * fan_in**-0.5
            chunks.append(w.ravel().astype(jnp.float32))
    return jnp.concatenate(chunks)


def unflatten(flat):
    """Split the flat vector back into named arrays (static offsets)."""
    params = {}
    off = 0
    for name, shape in param_specs():
        n = _size(shape)
        params[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        off += n
    return params


def _rmsnorm(x, scale):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * scale


def step(flat_params, tokens, kv, offset):
    """One serving step: process `tokens` starting at global position
    `offset`, updating the KV cache in-graph.

    Args:
      flat_params: ``[P]`` f32 — the whole model.
      tokens: ``[Tq]`` int32 (Tq = T_PRE for prefill, 1 for decode).
      kv: ``[LAYERS, 2, HEADS, T_MAX, HEAD_DIM]`` f32.
      offset: scalar int32 — current sequence length.

    Returns:
      (next_token ``[] int32`` — greedy argmax at the last position,
       kv_out — cache with this block's K/V inserted at ``offset``).
    """
    p = unflatten(flat_params)
    tq = tokens.shape[0]
    x = p["tok_emb"][tokens] + jax.lax.dynamic_slice(
        p["pos_emb"], (offset, 0), (tq, D_MODEL)
    )
    for l in range(LAYERS):
        h = _rmsnorm(x, p[f"l{l}.ln1"])
        q = (h @ p[f"l{l}.wq"]).reshape(tq, HEADS, HEAD_DIM).transpose(1, 0, 2)
        k = (h @ p[f"l{l}.wk"]).reshape(tq, HEADS, HEAD_DIM).transpose(1, 0, 2)
        v = (h @ p[f"l{l}.wv"]).reshape(tq, HEADS, HEAD_DIM).transpose(1, 0, 2)
        kv = jax.lax.dynamic_update_slice(kv, k[None, None], (l, 0, 0, offset, 0))
        kv = jax.lax.dynamic_update_slice(kv, v[None, None], (l, 1, 0, offset, 0))
        attn = decode_attention(q, kv[l, 0], kv[l, 1], offset)  # [H, Tq, Dh]
        attn = attn.transpose(1, 0, 2).reshape(tq, D_MODEL)
        x = x + attn @ p[f"l{l}.wo"]
        h2 = _rmsnorm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]
    xf = _rmsnorm(x[-1], p["lnf"])
    logits = xf @ p["tok_emb"].T  # tied head, [VOCAB]
    next_token = jnp.argmax(logits).astype(jnp.int32)
    return next_token, kv


def prefill(flat_params, tokens, kv, offset):
    """Prefill entry point: `tokens` is a full T_PRE chunk."""
    assert tokens.shape == (T_PRE,)
    return step(flat_params, tokens, kv, offset)


def decode(flat_params, token, kv, pos):
    """Decode entry point: a single token."""
    assert token.shape == (1,)
    return step(flat_params, token, kv, pos)


def empty_kv():
    return jnp.zeros(KV_SHAPE, jnp.float32)
