"""AOT compile path: lower TinyGPT's prefill/decode to HLO **text** and dump
the flat parameter vector.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the runtime's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (``make artifacts``):
  artifacts/prefill.hlo.txt   step() at Tq = T_PRE
  artifacts/decode.hlo.txt    step() at Tq = 1
  artifacts/params.bin        flat f32 little-endian weights
  artifacts/model_meta.json   dimensions the Rust runtime needs

Python runs ONCE at build time and never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_phase(tq: int) -> str:
    pspec = jax.ShapeDtypeStruct((model.param_count(),), jnp.float32)
    tokens = jax.ShapeDtypeStruct((tq,), jnp.int32)
    kv = jax.ShapeDtypeStruct(model.KV_SHAPE, jnp.float32)
    off = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(model.step).lower(pspec, tokens, kv, off)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, tq in [("prefill", model.T_PRE), ("decode", 1)]:
        text = lower_phase(tq)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    params = model.init_params(args.seed)
    import numpy as np

    raw = np.asarray(params, dtype="<f4").tobytes()
    with open(os.path.join(args.out_dir, "params.bin"), "wb") as f:
        f.write(raw)
    print(f"wrote params.bin ({len(raw)} bytes, {model.param_count()} params)")

    meta = {
        "vocab": model.VOCAB,
        "d_model": model.D_MODEL,
        "layers": model.LAYERS,
        "heads": model.HEADS,
        "head_dim": model.HEAD_DIM,
        "t_max": model.T_MAX,
        "t_pre": model.T_PRE,
        "param_count": model.param_count(),
        "kv_shape": list(model.KV_SHAPE),
        "kv_bytes": model.KV_BYTES,
        "kv_bytes_per_token": model.KV_BYTES_PER_TOKEN,
    }
    with open(os.path.join(args.out_dir, "model_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("wrote model_meta.json:", meta)


if __name__ == "__main__":
    main()
