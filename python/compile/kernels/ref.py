"""Pure-jnp oracle for the Pallas decode-attention kernel.

Materializes the full [Tq, Tmax] score matrix with an explicit causal mask —
slow but obviously correct; pytest/hypothesis compares the kernel against it
across shapes and dtypes.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, start):
    """Reference attention.

    Args:
      q: ``[H, Tq, Dh]``; k, v: ``[H, Tmax, Dh]``; start: scalar int32.

    Returns: ``[H, Tq, Dh]`` in q.dtype.
    """
    h, tq, dh = q.shape
    tmax = k.shape[1]
    scale = 1.0 / (dh**0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("hqd,hkd->hqk", qf, kf)  # [H, Tq, Tmax]
    qpos = start + jnp.arange(tq)[:, None]  # [Tq, 1]
    jpos = jnp.arange(tmax)[None, :]  # [1, Tmax]
    mask = jpos <= qpos  # [Tq, Tmax]
    s = jnp.where(mask[None, :, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, :, :], p, 0.0)
    o = jnp.einsum("hqk,hkd->hqd", p, vf) / jnp.sum(p, axis=-1, keepdims=True)
    return o.astype(q.dtype)
