"""Layer 1 — Pallas attention kernel over a KV cache (flash-decode style).

The serving hot-spot: every prefill chunk and every decode step attends over
the request's KV cache. The kernel processes one head per grid step and
streams the cache in `block_k`-wide tiles with an online-softmax
(running-max + renormalized accumulator), so the working set stays one tile —
the VMEM analogue of TENT's 64 KB slice (see DESIGN.md §Hardware-Adaptation).

Always lowered with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls; on a real TPU the same kernel lowers natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(start_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One head: q [1,Tq,Dh] attends over k/v [1,Tmax,Dh] with causal mask.

    Keys at global positions `j` are visible to query row `i` (global
    position `start + i`) iff ``j <= start + i``.
    """
    q = q_ref[0].astype(jnp.float32) * scale  # [Tq, Dh]
    tq = q.shape[0]
    tmax = k_ref.shape[1]
    nkb = tmax // block_k
    start = start_ref[0]

    qpos = start + lax.broadcasted_iota(jnp.int32, (tq, block_k), 0)  # [Tq, BK]

    def body(kb, carry):
        m, l, acc = carry
        k_tile = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        k_tile = k_tile.astype(jnp.float32)
        v_tile = v_tile.astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [Tq, BK]
        jpos = kb * block_k + lax.broadcasted_iota(jnp.int32, (tq, block_k), 1)
        mask = jpos <= qpos
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # Masked probabilities: explicit where() so fully-masked tiles stay 0.
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)
    acc0 = jnp.zeros((tq, q.shape[1]), jnp.float32)
    _, l, acc = lax.fori_loop(0, nkb, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, start, block_k: int = 128):
    """Attention over the KV cache.

    Args:
      q: ``[H, Tq, Dh]`` queries for the new token block.
      k, v: ``[H, Tmax, Dh]`` KV cache (new block already inserted at
        ``start .. start+Tq``).
      start: scalar int32 — global position of the first query row.
      block_k: KV tile width; ``Tmax % block_k == 0`` required.

    Returns:
      ``[H, Tq, Dh]`` attention output, in ``q.dtype``.
    """
    h, tq, dh = q.shape
    tmax = k.shape[1]
    block_k = min(block_k, tmax)
    if tmax % block_k != 0:
        raise ValueError(f"Tmax={tmax} not a multiple of block_k={block_k}")
    scale = 1.0 / (dh**0.5)
    start_arr = jnp.asarray(start, jnp.int32).reshape((1,))
    kernel = functools.partial(_attn_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, tq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tmax, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, tmax, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dh), q.dtype),
        interpret=True,
    )(start_arr, q, k, v)
