#!/usr/bin/env python3
"""Doc link/anchor checker for README.md and docs/*.md.

Every relative markdown link must point at a file that exists (resolved
against the file containing the link), and every `#anchor` — bare or
appended to a file link — must match a heading slug (GitHub slugging
rules) in the target document. External http(s) links are not fetched.

Runs from the repo root with no dependencies:  python3 tools/check_doc_links.py
Exit status is the number of broken links (0 = pass).
"""

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")
FENCE = re.compile(r"^\s*(```|~~~)")


def strip_fences(text):
    """Drop fenced code blocks so code snippets can't register links."""
    out, fenced = [], False
    for line in text.splitlines():
        if FENCE.match(line):
            fenced = not fenced
            continue
        out.append("" if fenced else line)
    return "\n".join(out)


def slugify(heading):
    """GitHub anchor slugging: lowercase, drop punctuation, spaces → '-'."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    slug = []
    for ch in heading:
        if ch.isalnum():
            slug.append(ch)
        elif ch in (" ", "-"):
            slug.append("-")
    return "".join(slug)


def anchors_of(path, cache={}):
    if path not in cache:
        text = strip_fences(path.read_text(encoding="utf-8"))
        cache[path] = {slugify(m.group(1)) for m in map(HEADING.match, text.splitlines()) if m}
    return cache[path]


def check(doc, root):
    errors = []
    for target in LINK.findall(strip_fences(doc.read_text(encoding="utf-8"))):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = doc if not path_part else (doc.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{doc.relative_to(root)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and slugify(anchor) not in anchors_of(dest):
            errors.append(f"{doc.relative_to(root)}: missing anchor -> {target}")
    return errors


def main():
    root = Path(__file__).resolve().parent.parent
    docs = [root / "README.md"] + sorted((root / "docs").glob("*.md"))
    errors = [e for doc in docs for e in check(doc, root)]
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    checked = ", ".join(str(d.relative_to(root)) for d in docs)
    print(f"doc-link check: {len(errors)} broken ({checked})")
    return min(len(errors), 100)


if __name__ == "__main__":
    sys.exit(main())
