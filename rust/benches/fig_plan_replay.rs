//! fig_plan_replay — the determinism gate over the shipped transfer plans.
//!
//! Every `.tent` file under `plans/` is compiled and executed **twice**, on
//! two fresh fleets, and the two replay journals must be byte-identical:
//! same plan digest, same per-stage op digests, same chaos applied-action
//! log at the same scheduled offsets. This is the paper's declarative
//! contract made testable — a plan plus a seed *is* the run.
//!
//! The gate is hard even under `--smoke` (journals exclude wall-clock
//! quantities by construction, so shrinking the chaos horizon never makes
//! the comparison flaky). A third run with a different seed must produce a
//! *different* journal digest — guarding against a digest that ignores its
//! inputs.
//!
//! Flags: --plans <dir>   plan directory          [plans, then ../plans]
//!        --smoke         cap chaos horizons at 100 ms for CI
//!        --json <path>   write BENCH_plan.json

use std::path::{Path, PathBuf};
use tent::plan::{compile, fleet_for, PlanSpec};
use tent::util::cli::Args;
use tent::util::json::Json;

struct Row {
    file: String,
    plan: String,
    stages: usize,
    ops: u64,
    bytes: u64,
    failed: u64,
    chaos_actions: usize,
    journal_digest: String,
    replay_ok: bool,
    seed_sensitive: bool,
}

fn plans_dir(args: &Args) -> PathBuf {
    if let Some(d) = args.get("plans") {
        return PathBuf::from(d);
    }
    // `cargo bench` runs from rust/, a repo-root invocation from ./.
    for cand in ["plans", "../plans"] {
        if Path::new(cand).is_dir() {
            return PathBuf::from(cand);
        }
    }
    PathBuf::from("plans")
}

fn run_file(path: &Path, smoke: bool) -> tent::Result<Row> {
    let src = std::fs::read_to_string(path).map_err(tent::Error::Io)?;
    let mut spec = PlanSpec::parse_any(&src)?;
    if smoke {
        spec.cap_chaos_horizon(100_000_000.0);
    }
    let dag = compile(&spec)?;
    let r1 = fleet_for(&spec)?.run_plan(&dag)?;
    let r2 = fleet_for(&spec)?.run_plan(&dag)?;
    let replay_ok = r1.journal.to_jsonl() == r2.journal.to_jsonl();
    if !replay_ok {
        if let Some(d) = r1.journal.diff(&r2.journal) {
            eprintln!("  REPLAY DIVERGED ({}): {d}", spec.name);
        }
    }
    // Seed sensitivity: a re-seeded plan must journal differently.
    let mut spec_b = spec.clone();
    spec_b.seed = spec.seed.wrapping_add(1);
    let dag_b = compile(&spec_b)?;
    let r3 = fleet_for(&spec_b)?.run_plan(&dag_b)?;
    let seed_sensitive = r3.journal_digest() != r1.journal_digest();
    Ok(Row {
        file: path.file_name().unwrap().to_string_lossy().into_owned(),
        plan: spec.name.clone(),
        stages: r1.stages.len(),
        ops: r1.total_ops,
        bytes: r1.total_bytes,
        failed: r1.failed_ops,
        chaos_actions: r1.chaos_actions,
        journal_digest: r1.journal.digest_hex(),
        replay_ok,
        seed_sensitive,
    })
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let dir = plans_dir(&args);
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("plan directory {}: {e}", dir.display()))
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "tent").unwrap_or(false))
        .collect();
    files.sort();
    assert!(
        !files.is_empty(),
        "no .tent files under {} — pass --plans <dir>",
        dir.display()
    );

    println!("== fig_plan_replay: journal determinism over shipped plans ==");
    println!("(each plan runs twice on fresh fleets; journals must match byte-for-byte)");
    println!(
        "{:<24} {:>6} {:>7} {:>10} {:>6} {:>6} {:>18} {:>7} {:>5}",
        "plan", "stages", "ops", "bytes", "failed", "chaos", "journal_digest", "replay", "seed"
    );
    let mut rows = Vec::new();
    for f in &files {
        let row = run_file(f, smoke).unwrap_or_else(|e| panic!("{}: {e}", f.display()));
        println!(
            "{:<24} {:>6} {:>7} {:>10} {:>6} {:>6} {:>18} {:>7} {:>5}",
            row.plan,
            row.stages,
            row.ops,
            tent::util::fmt_bytes(row.bytes),
            row.failed,
            row.chaos_actions,
            row.journal_digest,
            if row.replay_ok { "OK" } else { "DIVERGED" },
            if row.seed_sensitive { "OK" } else { "STUCK" }
        );
        rows.push(row);
    }
    let pass = rows.iter().all(|r| r.replay_ok && r.seed_sensitive);
    println!(
        "\nacceptance (every plan replays byte-identically and re-rolls under a new seed): {}",
        if pass { "PASS" } else { "FAIL" }
    );
    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("bench", Json::str("fig_plan_replay")),
            ("smoke", Json::Bool(smoke)),
            (
                "plans",
                Json::arr(rows.iter().map(|r| {
                    Json::obj(vec![
                        ("file", Json::str(&r.file)),
                        ("plan", Json::str(&r.plan)),
                        ("stages", Json::num(r.stages as f64)),
                        ("ops", Json::num(r.ops as f64)),
                        ("bytes", Json::num(r.bytes as f64)),
                        ("failed", Json::num(r.failed as f64)),
                        ("chaos_actions", Json::num(r.chaos_actions as f64)),
                        ("journal_digest", Json::str(&r.journal_digest)),
                        ("replay_ok", Json::Bool(r.replay_ok)),
                        ("seed_sensitive", Json::Bool(r.seed_sensitive)),
                    ])
                })),
            ),
            ("pass", Json::Bool(pass)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!("results written to {path}");
    }
    if !pass {
        std::process::exit(1);
    }
}
