//! Ablation — the two §4.2 design knobs the paper fixes by fiat:
//!
//! * **slice size** (64 KB default): "small enough that no single slice
//!   holds a rail for long … large enough to amortize the enqueue and
//!   completion costs";
//! * **tolerance window γ** (0.05 default): γ=0 degenerates to strict
//!   join-shortest-queue (no round-robin smoothing, maximal sensitivity to
//!   β noise); large γ degenerates toward plain round-robin (state-blind).
//!
//! Both swept on the Fig-5 H2H workload — plus the two telemetry-driven
//! hot-path features this ablation gates:
//!
//! * **adaptive γ** (`--adaptive` runs only this arm): the engine derives
//!   the slice size per rail from the learned cost model instead of the
//!   static minimum. PASS iff adaptive goodput lands within 5% of the best
//!   statically-tuned slice size — i.e. the controller finds the sweet
//!   spot nobody hand-picked.
//! * **batched completion feedback** (`--feedback` runs only this arm):
//!   per-(engine, class) completion batches fold N EWMA/telemetry updates
//!   into one. PASS iff batching does not regress goodput vs the
//!   per-slice ablation on a many-small-slices workload.
//!
//! `--smoke` shrinks the sweep for CI; `--json <path>` dumps all results.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::segment::Location;
use tent::util::cli::Args;
use tent::util::json::Json;
use tent::util::{fmt_bw, fmt_bytes, fmt_ns};

struct Arm {
    goodput: f64,
    p99: u64,
    slices: u64,
    wall_ns: u64,
}

fn run(min_slice: u64, gamma: f64, adaptive: bool, batched: bool, iters: usize) -> Arm {
    let cluster = Cluster::from_profile("h800_hgx").unwrap();
    let mut cfg = EngineConfig {
        min_slice,
        batched_feedback: batched,
        ..Default::default()
    };
    cfg.sched.gamma = gamma;
    cfg.sched.adaptive_gamma = adaptive;
    let engine = Arc::new(TentEngine::new(&cluster, cfg).unwrap());
    let seg_len = 32u64 << 20;
    let pairs: Vec<ThreadPair> = (0..2u8)
        .map(|s| ThreadPair {
            src: engine.register_segment(Location::host(0, s), seg_len).unwrap(),
            dst: engine.register_segment(Location::host(1, s), seg_len).unwrap(),
            seg_len,
        })
        .collect();
    let t0 = Instant::now();
    let r = bench::run(
        &engine,
        &pairs,
        &TeBenchConfig {
            block_size: 8 << 20,
            batch_size: 1,
            iters,
            warmup: if iters >= 8 { 2 } else { 1 },
            op: TransferOp::Write,
            time_limit: Duration::from_secs(25),
        },
    )
    .unwrap();
    Arm {
        goodput: r.throughput(),
        p99: r.latency.p99(),
        slices: engine.stats().slices_completed,
        wall_ns: t0.elapsed().as_nanos() as u64,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let only_adaptive = args.flag("adaptive");
    let only_feedback = args.flag("feedback");
    let all = !only_adaptive && !only_feedback;
    let iters = if smoke { 4 } else { 16 };

    let slice_sweep: &[u64] = if smoke {
        &[64 << 10, 1 << 20]
    } else {
        &[16 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20]
    };
    let gamma_sweep: &[f64] = if smoke { &[0.0, 0.05] } else { &[0.0, 0.02, 0.05, 0.2, 1.0] };

    let mut pass = true;
    let mut slice_rows: Vec<(u64, f64, u64)> = Vec::new();
    let mut gamma_rows: Vec<(f64, f64, u64)> = Vec::new();
    let mut best_static = 0.0f64;

    if all || only_adaptive {
        println!("== Ablation: slice size (gamma = 0.05, static) ==");
        println!("{:<12} {:>12} {:>12}", "min_slice", "goodput", "p99");
        for &s in slice_sweep {
            let a = run(s, 0.05, false, true, iters);
            println!("{:<12} {:>12} {:>12}", fmt_bytes(s), fmt_bw(a.goodput), fmt_ns(a.p99));
            best_static = best_static.max(a.goodput);
            slice_rows.push((s, a.goodput, a.p99));
        }
    }

    if all {
        println!("\n== Ablation: tolerance window gamma (slice = 64 KiB) ==");
        println!("{:<8} {:>12} {:>12}", "gamma", "goodput", "p99");
        for &g in gamma_sweep {
            let a = run(64 << 10, g, false, true, iters);
            println!("{:<8} {:>12} {:>12}", g, fmt_bw(a.goodput), fmt_ns(a.p99));
            gamma_rows.push((g, a.goodput, a.p99));
        }
    }

    // ---- adaptive γ arm: the controller vs the hand-tuned sweep ----
    let mut adaptive_row: Option<(f64, u64, bool)> = None;
    if all || only_adaptive {
        println!("\n== Adaptive gamma: model-derived slice size ==");
        let a = run(64 << 10, 0.05, true, true, iters);
        let ok = a.goodput >= 0.95 * best_static;
        println!(
            "adaptive: {} (p99 {}) vs best static {}: {}",
            fmt_bw(a.goodput),
            fmt_ns(a.p99),
            fmt_bw(best_static),
            if ok { "PASS" } else { "FAIL" }
        );
        println!("(gate: adaptive >= 95% of the best statically-tuned slice size)");
        pass &= ok;
        adaptive_row = Some((a.goodput, a.p99, ok));
    }

    // ---- batched feedback arm: many small slices stress the completion
    // path, where batching folds N model/telemetry updates into one ----
    let mut feedback_row: Option<(f64, f64, f64, f64, bool)> = None;
    if all || only_feedback {
        println!("\n== Completion feedback: batched vs per-slice (slice = 16 KiB) ==");
        let per = run(16 << 10, 0.05, false, false, iters);
        let bat = run(16 << 10, 0.05, false, true, iters);
        let per_ns = per.wall_ns as f64 / per.slices.max(1) as f64;
        let bat_ns = bat.wall_ns as f64 / bat.slices.max(1) as f64;
        println!(
            "{:<12} {:>12} {:>14}",
            "feedback", "goodput", "wall ns/slice"
        );
        println!("{:<12} {:>12} {:>14.0}", "per-slice", fmt_bw(per.goodput), per_ns);
        println!("{:<12} {:>12} {:>14.0}", "batched", fmt_bw(bat.goodput), bat_ns);
        let ok = bat.goodput >= 0.95 * per.goodput;
        println!(
            "batched feedback holds goodput (>= 95% of per-slice): {}",
            if ok { "PASS" } else { "FAIL" }
        );
        println!("(wall ns/slice is paced-simulation wall clock — informative only)");
        pass &= ok;
        feedback_row = Some((per.goodput, bat.goodput, per_ns, bat_ns, ok));
    }

    if all {
        println!("\nexpected: tiny slices pay per-slice overhead; huge slices hold rails");
        println!("too long (HoL) — 64-256 KiB is the sweet spot. gamma=0 is brittle to");
        println!("estimator noise; gamma>=1 approaches state-blind RR. adaptive gamma");
        println!("should land at the sweet spot without the sweep.");
    }

    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("bench", Json::str("ablation_slice_gamma")),
            ("smoke", Json::Bool(smoke)),
            (
                "slice_sweep",
                Json::arr(slice_rows.iter().map(|&(s, g, p)| {
                    Json::obj(vec![
                        ("min_slice", Json::num(s as f64)),
                        ("goodput_bytes_per_sec", Json::num(g)),
                        ("p99_ns", Json::num(p as f64)),
                    ])
                })),
            ),
            (
                "gamma_sweep",
                Json::arr(gamma_rows.iter().map(|&(g, gp, p)| {
                    Json::obj(vec![
                        ("gamma", Json::num(g)),
                        ("goodput_bytes_per_sec", Json::num(gp)),
                        ("p99_ns", Json::num(p as f64)),
                    ])
                })),
            ),
            (
                "adaptive",
                match adaptive_row {
                    Some((g, p, ok)) => Json::obj(vec![
                        ("goodput_bytes_per_sec", Json::num(g)),
                        ("p99_ns", Json::num(p as f64)),
                        ("best_static_bytes_per_sec", Json::num(best_static)),
                        ("pass", Json::Bool(ok)),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "feedback",
                match feedback_row {
                    Some((pg, bg, pn, bn, ok)) => Json::obj(vec![
                        ("per_slice_goodput", Json::num(pg)),
                        ("batched_goodput", Json::num(bg)),
                        ("per_slice_wall_ns_per_slice", Json::num(pn)),
                        ("batched_wall_ns_per_slice", Json::num(bn)),
                        ("pass", Json::Bool(ok)),
                    ]),
                    None => Json::Null,
                },
            ),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!("\nresults written to {path}");
    }

    println!("\noverall: {}", if pass { "PASS" } else { "FAIL" });
    // Wall-clock verdicts on shared CI runners are informative, not a
    // gate — `--smoke` reports but never fails the build. Full runs on
    // real hardware hard-fail.
    if !pass && !smoke {
        std::process::exit(1);
    }
}
