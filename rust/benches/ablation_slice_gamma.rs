//! Ablation — the two §4.2 design knobs the paper fixes by fiat:
//!
//! * **slice size** (64 KB default): "small enough that no single slice
//!   holds a rail for long … large enough to amortize the enqueue and
//!   completion costs";
//! * **tolerance window γ** (0.05 default): γ=0 degenerates to strict
//!   join-shortest-queue (no round-robin smoothing, maximal sensitivity to
//!   β noise); large γ degenerates toward plain round-robin (state-blind).
//!
//! Both swept on the Fig-5 H2H workload.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::segment::Location;
use tent::util::{fmt_bw, fmt_bytes, fmt_ns};

fn run(min_slice: u64, gamma: f64) -> (f64, u64) {
    let cluster = Cluster::from_profile("h800_hgx").unwrap();
    let mut cfg = EngineConfig {
        min_slice,
        ..Default::default()
    };
    cfg.sched.gamma = gamma;
    let engine = Arc::new(TentEngine::new(&cluster, cfg).unwrap());
    let seg_len = 32u64 << 20;
    let pairs: Vec<ThreadPair> = (0..2u8)
        .map(|s| ThreadPair {
            src: engine.register_segment(Location::host(0, s), seg_len).unwrap(),
            dst: engine.register_segment(Location::host(1, s), seg_len).unwrap(),
            seg_len,
        })
        .collect();
    let r = bench::run(
        &engine,
        &pairs,
        &TeBenchConfig {
            block_size: 8 << 20,
            batch_size: 1,
            iters: 16,
            warmup: 2,
            op: TransferOp::Write,
            time_limit: Duration::from_secs(25),
        },
    )
    .unwrap();
    (r.throughput(), r.latency.p99())
}

fn main() {
    println!("== Ablation: slice size (gamma = 0.05) ==");
    println!("{:<12} {:>12} {:>12}", "min_slice", "goodput", "p99");
    for s in [16u64 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20] {
        let (bw, p99) = run(s, 0.05);
        println!("{:<12} {:>12} {:>12}", fmt_bytes(s), fmt_bw(bw), fmt_ns(p99));
    }
    println!("\n== Ablation: tolerance window gamma (slice = 64 KiB) ==");
    println!("{:<8} {:>12} {:>12}", "gamma", "goodput", "p99");
    for g in [0.0, 0.02, 0.05, 0.2, 1.0] {
        let (bw, p99) = run(64 << 10, g);
        println!("{:<8} {:>12} {:>12}", g, fmt_bw(bw), fmt_ns(p99));
    }
    println!("\nexpected: tiny slices pay per-slice overhead; huge slices hold rails");
    println!("too long (HoL) — 64-256 KiB is the sweet spot. gamma=0 is brittle to");
    println!("estimator noise; gamma>=1 approaches state-blind RR.");
}
