//! Figure 9 — host-to-host write throughput and P90 latency vs batch size.
//!
//! Paper setup: one submission thread, both buffers on NUMA node 0 (four
//! local NICs → ideal 800 Gbps), 4 MB blocks, batch 1 … 128. NIXL keeps a
//! single NIC (4 MB is below its multi-rail threshold); Mooncake TE's
//! randomized tier-1 selection ignores load, so the slowest rail dictates
//! completion; TENT approaches the 4-NIC limit as batches deepen
//! (paper: 1.16–2.72× TE, P90 −27%).

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::{fmt_bw, fmt_ns};

const POLICIES: [PolicyKind; 3] = [PolicyKind::Tent, PolicyKind::MooncakeTe, PolicyKind::Nixl];
const BATCHES: [usize; 5] = [1, 4, 16, 64, 128];

fn bench_one(policy: PolicyKind, batch: usize) -> tent::Result<(f64, u64)> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let block = 4u64 << 20;
    let seg_len = (block * batch as u64).max(16 << 20);
    let src = engine.register_segment(Location::host(0, 0), seg_len)?;
    let dst = engine.register_segment(Location::host(1, 0), seg_len)?;
    let pairs = [ThreadPair { src, dst, seg_len }];
    let iters = (32 / batch).clamp(3, 32);
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: batch,
        iters,
        warmup: 1,
        op: TransferOp::Write,
        time_limit: Duration::from_secs(30),
    };
    let r = bench::run(&engine, &pairs, &cfg)?;
    Ok((r.throughput(), r.latency.quantile(0.90)))
}

fn main() {
    println!("== Figure 9: H2H write goodput + P90 vs batch size (1 thread, 4 MiB, NUMA-0) ==");
    println!("(ideal aggregate: 4 local NICs x 250 MB/s = 1000 MB/s)");
    print!("{:<7}", "batch");
    for p in POLICIES {
        print!(" {:>24}", p.name());
    }
    println!();
    for batch in BATCHES {
        print!("{:<7}", batch);
        for p in POLICIES {
            let (bw, p90) = bench_one(p, batch).unwrap();
            print!(" {:>12} {:>11}", fmt_bw(bw), fmt_ns(p90));
        }
        println!();
    }
    println!("\nexpected shape: TENT approaches 4-NIC ideal as batch grows; NIXL stays");
    println!("single-NIC (4 MiB < multirail threshold); TE below TENT, worst at low batch.");
}
