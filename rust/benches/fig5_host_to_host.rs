//! Figure 5 — host-to-host read/write throughput and P99 latency between
//! two nodes across block sizes, four engines.
//!
//! Paper setup: two H800 nodes, eight 200 Gbps rails, pinned host memory
//! per socket, one submission thread per socket, batch size 1, block sizes
//! 4 KB … 64 MB.
//!
//! Expected shape: TENT ≳ Mooncake TE on both metrics (paper: up to ~33%
//! higher throughput, P99 down to ~28%); NIXL caps at its two "best" NICs;
//! UCCL caps at a single NIC per region.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::{fmt_bw, fmt_bytes, fmt_ns};

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::Tent,
    PolicyKind::MooncakeTe,
    PolicyKind::Nixl,
    PolicyKind::UcclP2p,
];
const BLOCKS: [u64; 5] = [4 << 10, 64 << 10, 1 << 20, 8 << 20, 64 << 20];

fn bench_one(policy: PolicyKind, block: u64, op: TransferOp) -> tent::Result<(f64, u64)> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    // One submission thread per socket, memory pinned per socket.
    let seg_len = (block * 4).max(16 << 20);
    let pairs: Vec<ThreadPair> = (0..2u8)
        .map(|sock| {
            let src = engine.register_segment(Location::host(0, sock), seg_len)?;
            let dst = engine.register_segment(Location::host(1, sock), seg_len)?;
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;
    // Aim for ~192 MiB of traffic per config, capped by count.
    let iters = ((192u64 << 20) / (block * 2)).clamp(6, 192) as usize;
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: 1,
        iters,
        warmup: 2,
        op,
        time_limit: Duration::from_secs(25),
    };
    let r = bench::run(&engine, &pairs, &cfg)?;
    Ok((r.throughput(), r.latency.p99()))
}

fn main() {
    println!("== Figure 5: host-to-host throughput + P99 vs block size ==");
    for op in [TransferOp::Read, TransferOp::Write] {
        println!("\n--- {op:?} ---");
        print!("{:<10}", "block");
        for p in POLICIES {
            print!(" {:>22}", p.name());
        }
        println!();
        for block in BLOCKS {
            print!("{:<10}", fmt_bytes(block));
            for p in POLICIES {
                let (bw, p99) = bench_one(p, block, op).unwrap();
                print!(" {:>11} {:>10}", fmt_bw(bw), fmt_ns(p99));
            }
            println!();
        }
    }
    println!("\nexpected shape: TENT highest goodput / lowest P99 at >=1MB; NIXL ~2 rails;");
    println!("UCCL ~1 rail; TE all rails but state-blind (slow rail dominates P99).");
}
