//! Fleet scaling — multi-engine shared fabric at 8–64 nodes (ROADMAP
//! "Fabric scaling"; the §2.3 cluster-scale claim the paper never bench-
//! marks below thousands of GPUs).
//!
//! One engine per node shares a single fabric through the cluster-owned
//! datapath; every engine fetches KV blocks (Latency class) from random
//! peers — so each node's rails carry slices from many engines at once —
//! and pushes checkpoint blocks (Bulk class) to its ring neighbour.
//!
//! Output:
//! * the node-count × policy sweep: aggregate goodput, per-class transfer
//!   latency, fleet-wide slice P50/P99, per-engine fairness (min/max
//!   goodput), spawned rail workers, and the share of enqueues whose
//!   wakeup was coalesced by the parked-flag protocol;
//! * TENT additionally runs with the per-engine-sharded queued-bytes
//!   counters *disabled* (single atomic per rail) — the goodput ablation;
//! * a counter hot-path microbenchmark (N engine threads hammering one
//!   rail's `add_queued`/`sub_queued` with periodic telemetry reads):
//!   wall-clock goodput of a *paced simulation* mostly hides cache-line
//!   bouncing, so the microbench is the PASS/FAIL evidence that sharding
//!   fixes the hot spot, alongside the fairness gate.
//!
//! `--smoke` runs the 8-node column only (CI); `--nodes 8,16` overrides
//! the sweep.

use std::time::{Duration, Instant};
use tent::cluster::{Fleet, FleetConfig, WorkloadConfig};
use tent::engine::TransferClass;
use tent::fabric::{Fabric, FabricConfig};
use tent::policy::PolicyKind;
use tent::topology::profile::build_profile;
use tent::topology::{FabricKind, NodeId};
use tent::util::cli::Args;
use tent::util::json::Json;
use tent::util::{fmt_bw, fmt_ns};

struct Cell {
    goodput: f64,
    fairness: f64,
    fetch_p50: u64,
    fetch_p99: u64,
    bulk_p50: u64,
    slice_p99: u64,
    workers: usize,
    coalesced_pct: f64,
    cross_stalls: u64,
}

fn run_cell(nodes: u16, policy: PolicyKind, sharded: bool, duration: Duration) -> Cell {
    let mut cfg = FleetConfig::new("h800_hgx", nodes);
    cfg.policy = policy;
    cfg.sharded_counters = sharded;
    let fleet = Fleet::new(cfg).expect("fleet build");
    let w = WorkloadConfig {
        duration,
        ..Default::default()
    };
    let r = fleet.run_workload(&w).expect("workload");
    let slice_lat = fleet.class_slice_latency(TransferClass::Latency);
    let (mut sent, mut coalesced, mut cross) = (0u64, 0u64, 0u64);
    for e in fleet.engines() {
        let s = e.stats();
        sent += s.wakeups_sent;
        coalesced += s.wakeups_coalesced;
        cross += s.cross_engine_stalls;
    }
    Cell {
        goodput: r.aggregate_goodput(),
        fairness: r.fairness(),
        fetch_p50: r.latency_hist.p50(),
        fetch_p99: r.latency_hist.p99(),
        bulk_p50: r.bulk_hist.p50(),
        slice_p99: slice_lat.p99(),
        workers: fleet.cluster.datapath().map(|d| d.spawned_workers()).unwrap_or(0),
        coalesced_pct: 100.0 * coalesced as f64 / (sent + coalesced).max(1) as f64,
        cross_stalls: cross,
    }
}

/// Counter hot path: `threads` engine threads doing add/sub on one shared
/// rail (+ a telemetry read every 64 ops), single-counter vs sharded.
/// Returns ns/op.
fn counter_bench(threads: usize, shards: usize, ops_per_thread: u64) -> f64 {
    let topo = build_profile("h800_hgx", 1).unwrap();
    let fabric = Fabric::new(
        &topo,
        FabricConfig {
            counter_shards: shards,
            ..Default::default()
        },
    );
    let rail = topo.rails_of(NodeId(0), FabricKind::Rdma)[0];
    let start = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let fabric = &fabric;
            scope.spawn(move || {
                let shard = fabric.register_engine();
                for i in 0..ops_per_thread {
                    fabric.add_queued_at(shard, rail, 64 << 10, 1);
                    if i % 64 == 0 {
                        std::hint::black_box(fabric.queued_bytes_from(shard, rail));
                    }
                    fabric.sub_queued_at(shard, rail, 64 << 10, 1);
                }
            });
        }
    });
    let total_ops = (threads as u64 * ops_per_thread * 2) as f64;
    start.elapsed().as_nanos() as f64 / total_ops
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let sweep: Vec<u16> = match args.get("nodes") {
        Some(list) => list.split(',').map(|s| s.trim().parse().expect("--nodes list")).collect(),
        None if smoke => vec![8],
        None => vec![8, 16, 32, 64],
    };
    let duration = if smoke {
        Duration::from_millis(600)
    } else {
        Duration::from_millis(1500)
    };

    println!("== fig_scaling: multi-engine shared fabric, one engine per node ==");
    println!("(h800_hgx, KV fetches from random peers + checkpoint pushes; 20x time compression)");
    println!();
    println!(
        "{:<7} {:<16} {:>10} {:>9} {:>10} {:>10} {:>10} {:>10} {:>8} {:>7} {:>9}",
        "nodes", "policy", "goodput", "fair", "fetchP50", "fetchP99", "bulkP50", "sliceP99",
        "workers", "coal%", "xstalls"
    );

    let mut tent_by_nodes: Vec<(u16, Cell)> = Vec::new();
    for &n in &sweep {
        let variants: &[(&str, PolicyKind, bool)] = if smoke {
            &[("tent", PolicyKind::Tent, true)]
        } else {
            &[
                ("tent", PolicyKind::Tent, true),
                ("tent/1ctr", PolicyKind::Tent, false),
                ("mooncake-te", PolicyKind::MooncakeTe, true),
            ]
        };
        for &(label, policy, sharded) in variants {
            let c = run_cell(n, policy, sharded, duration);
            println!(
                "{:<7} {:<16} {:>10} {:>9.3} {:>10} {:>10} {:>10} {:>10} {:>8} {:>6.1}% {:>9}",
                n,
                label,
                fmt_bw(c.goodput),
                c.fairness,
                fmt_ns(c.fetch_p50),
                fmt_ns(c.fetch_p99),
                fmt_ns(c.bulk_p50),
                fmt_ns(c.slice_p99),
                c.workers,
                c.coalesced_pct,
                c.cross_stalls,
            );
            if label == "tent" {
                tent_by_nodes.push((n, c));
            }
        }
    }

    println!();
    println!("== counter hot path: add/sub on one shared rail (ns/op) ==");
    println!(
        "{:<9} {:>12} {:>12} {:>9}",
        "engines", "single", "sharded", "speedup"
    );
    let ops: u64 = if smoke { 200_000 } else { 500_000 };
    let mut micro: Vec<(u16, f64, f64)> = Vec::new();
    for &n in &sweep {
        let t = n as usize;
        let single = counter_bench(t, 1, ops);
        let sharded = counter_bench(t, t, ops);
        println!(
            "{:<9} {:>12.1} {:>12.1} {:>8.2}x",
            t,
            single,
            sharded,
            single / sharded.max(1e-9)
        );
        micro.push((n, single, sharded));
    }

    // ---- verdicts ----
    println!();
    let mut pass = true;

    let (max_n, last) = tent_by_nodes
        .last()
        .map(|(n, c)| (*n, c))
        .expect("at least one TENT cell");
    let fair_ok = last.fairness >= 0.5;
    println!(
        "fairness at {max_n} nodes (TENT): {:.3} (>= 0.5): {}",
        last.fairness,
        if fair_ok { "PASS" } else { "FAIL" }
    );
    pass &= fair_ok;

    if tent_by_nodes.len() > 1 {
        let (n0, first) = &tent_by_nodes[0];
        let scale_ok = last.goodput > 1.5 * first.goodput;
        println!(
            "aggregate goodput scales {n0}->{max_n} nodes: {} -> {} (> 1.5x): {}",
            fmt_bw(first.goodput),
            fmt_bw(last.goodput),
            if scale_ok { "PASS" } else { "FAIL" }
        );
        pass &= scale_ok;
    }

    // Smoke runs on tiny CI machines where 8 threads get ~2-way true
    // parallelism and the two variants can land within noise of each
    // other; gate with a margin there, strictly in the full sweep.
    let (mn, single, sharded) = *micro.last().expect("microbench ran");
    let ctr_ok = if smoke { sharded < single * 1.15 } else { sharded < single };
    println!(
        "sharded counters beat single counter at {mn} engines{}: {sharded:.1} vs {single:.1} ns/op: {}",
        if smoke { " (15% smoke margin)" } else { "" },
        if ctr_ok { "PASS" } else { "FAIL" }
    );
    pass &= ctr_ok;

    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("bench", Json::str("fig_scaling")),
            ("smoke", Json::Bool(smoke)),
            (
                "tent_cells",
                Json::arr(tent_by_nodes.iter().map(|(n, c)| {
                    Json::obj(vec![
                        ("nodes", Json::num(*n as f64)),
                        ("goodput_bytes_per_sec", Json::num(c.goodput)),
                        ("fairness", Json::num(c.fairness)),
                        ("fetch_p50_ns", Json::num(c.fetch_p50 as f64)),
                        ("fetch_p99_ns", Json::num(c.fetch_p99 as f64)),
                        ("bulk_p50_ns", Json::num(c.bulk_p50 as f64)),
                        ("slice_p99_ns", Json::num(c.slice_p99 as f64)),
                        ("workers", Json::num(c.workers as f64)),
                        ("coalesced_pct", Json::num(c.coalesced_pct)),
                        ("cross_stalls", Json::num(c.cross_stalls as f64)),
                    ])
                })),
            ),
            (
                "counter_bench",
                Json::arr(micro.iter().map(|&(n, single, sharded)| {
                    Json::obj(vec![
                        ("engines", Json::num(n as f64)),
                        ("single_ns_per_op", Json::num(single)),
                        ("sharded_ns_per_op", Json::num(sharded)),
                    ])
                })),
            ),
            ("pass", Json::Bool(pass)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!();
        println!("results written to {path}");
    }

    println!();
    println!("overall: {}", if pass { "PASS" } else { "FAIL" });
    // The verdicts are wall-clock performance assertions; on a shared CI
    // runner they are informative, not a gate — `--smoke` reports but
    // never fails the build (a crash or hang still does). Full runs on
    // real hardware hard-fail.
    if !pass && !smoke {
        std::process::exit(1);
    }
}
