//! Figure 2 — per-rail average latency: Round-Robin vs TENT.
//!
//! Paper setup: eight-rail 200 Gbps fabric, read requests split into 1 MB
//! slices, four submission threads that can post to any NIC. Rails attached
//! to remote NUMA domains exhibit higher per-slice service times; under RR
//! the queue buildup on those rails inflates latency (HoL blocking), while
//! TENT's telemetry steers slices away before queues build.
//!
//! Expected shape: RR shows latency spikes on the cross-NUMA rails
//! (n0-mlx4..7); TENT is flat and lower on the rails it uses.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::fmt_ns;

fn run_policy(policy: PolicyKind) -> tent::Result<()> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let mut cfg = EngineConfig::with_policy(policy);
    cfg.min_slice = 1 << 20; // the paper's 1 MB slices
    let engine = Arc::new(TentEngine::new(&cluster, cfg)?);

    // Four submission threads with per-socket memory (sockets 0,1,0,1),
    // each able to post to any NIC: for every buffer, the remote socket's
    // four rails are NUMA-crossing (the Fig. 2 asymmetry).
    let seg_len = 32u64 << 20;
    let pairs: Vec<ThreadPair> = (0..4u8)
        .map(|i| {
            let src = engine.register_segment(Location::host(0, i % 2), seg_len)?;
            let dst = engine.register_segment(Location::host(1, i % 2), seg_len)?;
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;

    let bcfg = TeBenchConfig {
        block_size: 8 << 20, // 8 slices per request
        batch_size: 1,
        iters: 24,
        warmup: 2,
        op: TransferOp::Read,
        time_limit: Duration::from_secs(60),
    };
    let r = bench::run(&engine, &pairs, &bcfg)?;

    println!("\n{} — aggregate: {}", policy.name(), bench::fmt_row("8MBx1 read", &r));
    println!("  {:<14} {:<7} {:>10} {:>12} {:>12} {:>9}", "rail", "numa", "slices", "avg", "p99", "bytes");
    for s in engine.rail_snapshots() {
        if s.fabric == "rdma" && s.slices_ok > 0 {
            let numa = if s.name.contains("mlx") {
                let idx: u32 = s.name.chars().last().unwrap().to_digit(10).unwrap();
                idx / 4
            } else {
                0
            };
            println!(
                "  {:<14} numa{:<3} {:>10} {:>12} {:>12} {:>9}",
                s.name,
                numa,
                s.slices_ok,
                fmt_ns(s.mean_latency_ns as u64),
                fmt_ns(s.p99_ns),
                tent::util::fmt_bytes(s.bytes_carried)
            );
        }
    }
    Ok(())
}

fn main() {
    println!("== Figure 2: per-rail latency, Round-Robin vs TENT ==");
    println!("(reads, 1 MB slices, 4 submission threads, per-socket buffers)");
    for p in [PolicyKind::RoundRobin, PolicyKind::Tent] {
        run_policy(p).unwrap();
    }
    println!("\nexpected shape: RR shows inflated avg/p99 on cross-NUMA rails (mlx4-7);");
    println!("TENT concentrates on NUMA-local rails and keeps latency flat.");
}
