//! Figure 7 — GPU-to-GPU read bandwidth vs submission threads (4 MB
//! blocks), each thread bound to a local GPU.
//!
//! Paper: with all eight GPUs issuing, TENT sustains 144 GB/s (~77% of
//! peak, >2× Mooncake TE) and saturates with only 16 threads. Sim peak =
//! 8 rails × 250 MB/s = 2 GB/s aggregate.
//!
//! `--engines N` switches to the *engine*-scaling axis: instead of more
//! submission threads inside one engine, a `cluster::Fleet` runs 1→N
//! engine instances (one per node, shared fabric) with a fixed number of
//! submitters each — so thread scaling and engine scaling are separately
//! measurable.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::{Cluster, Fleet, FleetConfig, WorkloadConfig};
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::cli::Args;
use tent::util::{fmt_bw, fmt_ns};

const POLICIES: [PolicyKind; 3] = [PolicyKind::Tent, PolicyKind::MooncakeTe, PolicyKind::Nixl];
const THREADS: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn bench_one(policy: PolicyKind, threads: usize) -> tent::Result<f64> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let block = 4u64 << 20;
    let seg_len = 16u64 << 20;
    let pairs: Vec<ThreadPair> = (0..threads)
        .map(|i| {
            let gpu = (i % 8) as u8;
            let src = engine.register_segment(Location::device(0, gpu), seg_len)?;
            let dst = engine.register_segment(Location::device(1, gpu), seg_len)?;
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;
    let iters = (48 / threads).clamp(4, 48);
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: 1,
        iters,
        warmup: 1,
        op: TransferOp::Read,
        time_limit: Duration::from_secs(25),
    };
    let r = bench::run(&engine, &pairs, &cfg)?;
    Ok(r.throughput())
}

fn engines_axis(max_engines: u16) {
    println!("== Figure 7b: goodput vs engine count (fleet, shared fabric, 2 submitters/engine) ==");
    println!(
        "{:<9} {:>12} {:>9} {:>12} {:>12} {:>8}",
        "engines", "goodput", "fair", "fetchP50", "fetchP99", "workers"
    );
    let mut points: Vec<u16> = Vec::new();
    let mut p = 1u16;
    while p < max_engines {
        points.push(p);
        p *= 2;
    }
    points.push(max_engines); // always measure the requested count
    for n in points {
        let fleet = Fleet::new(FleetConfig::new("h800_hgx", n)).unwrap();
        let w = WorkloadConfig {
            duration: Duration::from_millis(1000),
            ..Default::default()
        };
        let r = fleet.run_workload(&w).unwrap();
        println!(
            "{:<9} {:>12} {:>9.3} {:>12} {:>12} {:>8}",
            n,
            fmt_bw(r.aggregate_goodput()),
            r.fairness(),
            fmt_ns(r.latency_hist.p50()),
            fmt_ns(r.latency_hist.p99()),
            fleet
                .cluster
                .datapath()
                .map(|d| d.spawned_workers())
                .unwrap_or(0),
        );
    }
    println!("\nexpected shape: goodput grows with engine count (every node adds rails)");
    println!("while fairness stays high — engines share rails, not starve each other.");
}

fn main() {
    let args = Args::from_env();
    if let Some(e) = args.get("engines") {
        engines_axis(e.parse().expect("--engines N"));
        return;
    }
    println!("== Figure 7: GPU-to-GPU read bandwidth vs submission threads (4 MiB) ==");
    println!("(sim hardware peak: 8 rails x 250 MB/s = 2000 MB/s aggregate)");
    print!("{:<9}", "threads");
    for p in POLICIES {
        print!(" {:>14}", p.name());
    }
    println!("  TENT %peak");
    for t in THREADS {
        print!("{:<9}", t);
        let mut tent_bw = 0.0;
        for p in POLICIES {
            let bw = bench_one(p, t).unwrap();
            if p == PolicyKind::Tent {
                tent_bw = bw;
            }
            print!(" {:>14}", fmt_bw(bw));
        }
        println!("  {:>6.1}%", tent_bw / 2000e6 * 100.0);
    }
    println!("\nexpected shape: TENT saturates by ~8-16 threads near the aggregate peak;");
    println!("TE stays on tier-1 rails (~1/8 peak per pair); NIXL caps at 2 rails.");
}
