//! Figure 7 — GPU-to-GPU read bandwidth vs submission threads (4 MB
//! blocks), each thread bound to a local GPU.
//!
//! Paper: with all eight GPUs issuing, TENT sustains 144 GB/s (~77% of
//! peak, >2× Mooncake TE) and saturates with only 16 threads. Sim peak =
//! 8 rails × 250 MB/s = 2 GB/s aggregate.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::fmt_bw;

const POLICIES: [PolicyKind; 3] = [PolicyKind::Tent, PolicyKind::MooncakeTe, PolicyKind::Nixl];
const THREADS: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn bench_one(policy: PolicyKind, threads: usize) -> tent::Result<f64> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let block = 4u64 << 20;
    let seg_len = 16u64 << 20;
    let pairs: Vec<ThreadPair> = (0..threads)
        .map(|i| {
            let gpu = (i % 8) as u8;
            let src = engine.register_segment(Location::device(0, gpu), seg_len)?;
            let dst = engine.register_segment(Location::device(1, gpu), seg_len)?;
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;
    let iters = (48 / threads).clamp(4, 48);
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: 1,
        iters,
        warmup: 1,
        op: TransferOp::Read,
        time_limit: Duration::from_secs(25),
    };
    let r = bench::run(&engine, &pairs, &cfg)?;
    Ok(r.throughput())
}

fn main() {
    println!("== Figure 7: GPU-to-GPU read bandwidth vs submission threads (4 MiB) ==");
    println!("(sim hardware peak: 8 rails x 250 MB/s = 2000 MB/s aggregate)");
    print!("{:<9}", "threads");
    for p in POLICIES {
        print!(" {:>14}", p.name());
    }
    println!("  TENT %peak");
    for t in THREADS {
        print!("{:<9}", t);
        let mut tent_bw = 0.0;
        for p in POLICIES {
            let bw = bench_one(p, t).unwrap();
            if p == PolicyKind::Tent {
                tent_bw = bw;
            }
            print!(" {:>14}", fmt_bw(bw));
        }
        println!("  {:>6.1}%", tent_bw / 2000e6 * 100.0);
    }
    println!("\nexpected shape: TENT saturates by ~8-16 threads near the aggregate peak;");
    println!("TE stays on tier-1 rails (~1/8 peak per pair); NIXL caps at 2 rails.");
}
