//! Table 2 — multi-turn conversation serving with SGLang-HiCache-style
//! tiered KV cache: Baseline (no HiCache) vs HiCache+Mooncake TE vs
//! HiCache+TENT.
//!
//! Full three-layer stack: Pallas-kernel HLO executed via PJRT, KV blocks
//! moved between GPU/CPU/SSD tiers by the transfer engine. Requires
//! `make artifacts` (prints SKIPPED otherwise). Scaled workload: the paper
//! runs 60 clients × 10 turns on Qwen3-235B; we run 6 × 4 on TinyGPT —
//! the *ratios* are the reproduction target.

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::policy::PolicyKind;
use tent::runtime::Runtime;
use tent::serving::{build_conversations, run_serving, ServeConfig, ServeMode, ServeReport};

fn run_config(rt: &Runtime, policy: PolicyKind, mode: ServeMode, cfg: &ServeConfig) -> ServeReport {
    let cluster =
        Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default()).unwrap();
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy)).unwrap());
    let convs = build_conversations(
        cfg.clients,
        cfg.turns,
        rt.meta.t_pre,
        rt.meta.vocab as i32,
        cfg.cache.gpus,
        cfg.seed,
        cfg.shared_system_prompt,
    );
    let cfg = ServeConfig { mode, ..cfg.clone() };
    run_serving(&engine, rt, &convs, &cfg).unwrap()
}

fn main() {
    println!("== Table 2: multi-turn HiCache serving (Baseline / Mooncake TE / TENT) ==");
    let dir = tent::runtime::default_artifacts_dir();
    if !Runtime::artifacts_available(&dir) {
        println!("SKIPPED: model runtime unavailable (AOT artifacts + real PJRT backend required; this offline build stubs PJRT)");
        return;
    }
    let rt = Runtime::load(&dir).unwrap();
    let cfg = ServeConfig {
        clients: 6,
        turns: 4,
        decode_tokens: 2,
        seed: 7,
        ..Default::default()
    };

    let base = run_config(&rt, PolicyKind::Tent, ServeMode::Baseline, &cfg);
    let te = run_config(&rt, PolicyKind::MooncakeTe, ServeMode::HiCache, &cfg);
    let tnt = run_config(&rt, PolicyKind::Tent, ServeMode::HiCache, &cfg);

    let turns = cfg.turns;
    println!(
        "\n{:<26} {:>10} {:>10} {:>10}",
        "Metric", "Baseline", "MooncakeTE", "TENT"
    );
    println!(
        "{:<26} {:>10.0} {:>10.0} {:>10.0}",
        "Input Throughput (tok/s)",
        base.input_throughput_tok_s(),
        te.input_throughput_tok_s(),
        tnt.input_throughput_tok_s()
    );
    println!(
        "{:<26} {:>10.3} {:>10.3} {:>10.3}",
        "Average TTFT (s)",
        base.avg_ttft_s(),
        te.avg_ttft_s(),
        tnt.avg_ttft_s()
    );
    println!(
        "{:<26} {:>10.3} {:>10.3} {:>10.3}",
        "P90 TTFT (s)",
        base.p90_ttft_s(),
        te.p90_ttft_s(),
        tnt.p90_ttft_s()
    );
    for r in [1, turns / 2 + 1, turns] {
        println!(
            "{:<26} {:>10.3} {:>10.3} {:>10.3}",
            format!("R{r} Avg TTFT (s)"),
            base.round_avg_ttft_s(r),
            te.round_avg_ttft_s(r),
            tnt.round_avg_ttft_s(r)
        );
    }
    println!(
        "\nratios — TENT/Baseline throughput: {:.2}x (paper 3.79x at 10 turns)",
        tnt.input_throughput_tok_s() / base.input_throughput_tok_s()
    );
    println!(
        "ratios — TENT/TE throughput: {:.2}x (paper 1.36x) | P90 TTFT -{:.1}% (paper -26.4%)",
        tnt.input_throughput_tok_s() / te.input_throughput_tok_s(),
        (1.0 - tnt.p90_ttft_s() / te.p90_ttft_s()) * 100.0
    );
}
