//! Table 2 — multi-turn conversation serving with SGLang-HiCache-style
//! tiered KV cache: Baseline (no HiCache) vs HiCache+Mooncake TE vs
//! HiCache+TENT.
//!
//! Full three-layer stack: a pluggable model executor (deterministic
//! synthetic model by default — no artifacts needed; `--model pjrt` for the
//! Pallas-kernel HLO via PJRT) with KV blocks moved between GPU/CPU/SSD
//! tiers by the transfer engine. Scaled workload: the paper runs 60 clients
//! × 10 turns on Qwen3-235B; we run 6 × 4 on TinyGPT dims — the *ratios*
//! are the reproduction target.
//!
//! `--smoke` shrinks the workload to a seconds-long CI-sized run (2 clients
//! × 2 turns, tiny pools) that still prints the full Table-2 shape.

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::policy::PolicyKind;
use tent::runtime::{make_executor, ModelExecutor, ModelSelect};
use tent::serving::{build_for, run_serving, KvCacheConfig, ServeConfig, ServeMode, ServeReport};
use tent::util::cli::Args;
use tent::util::TempPool;

fn run_config(
    model: &dyn ModelExecutor,
    policy: PolicyKind,
    mode: ServeMode,
    cfg: &ServeConfig,
) -> ServeReport {
    let cluster =
        Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default()).unwrap();
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy)).unwrap());
    // Per-run disk pool, removed on drop even when a run panics.
    let pool = TempPool::new("t2_kv");
    let mut cfg = ServeConfig { mode, ..cfg.clone() };
    cfg.cache.disk_path = pool.path();
    let convs = build_for(model.meta(), &cfg);
    run_serving(&engine, model, &convs, &cfg).unwrap()
}

fn main() {
    println!("== Table 2: multi-turn HiCache serving (Baseline / Mooncake TE / TENT) ==");
    let args = Args::from_env();
    let sel = ModelSelect::parse(&args.get_str("model", "auto"))
        .expect("unknown --model (synthetic|pjrt|auto)");
    let smoke = args.flag("smoke");
    let cfg = if smoke {
        ServeConfig {
            clients: args.get_usize("clients", 2),
            turns: args.get_usize("turns", 2),
            decode_tokens: 1,
            seed: 7,
            model: sel,
            cache: KvCacheConfig {
                gpu_blocks_per_gpu: 2,
                cpu_blocks: 32,
                disk_blocks: 64,
                ..Default::default()
            },
            ..Default::default()
        }
    } else {
        ServeConfig {
            clients: args.get_usize("clients", 6),
            turns: args.get_usize("turns", 4),
            decode_tokens: 2,
            seed: 7,
            model: sel,
            ..Default::default()
        }
    };
    // The config is the single source of truth for executor selection.
    let model = make_executor(cfg.model).unwrap();
    println!(
        "model={} clients={} turns={}{}",
        model.name(),
        cfg.clients,
        cfg.turns,
        if smoke { " (smoke)" } else { "" }
    );

    let base = run_config(model.as_ref(), PolicyKind::Tent, ServeMode::Baseline, &cfg);
    let te = run_config(model.as_ref(), PolicyKind::MooncakeTe, ServeMode::HiCache, &cfg);
    let tnt = run_config(model.as_ref(), PolicyKind::Tent, ServeMode::HiCache, &cfg);

    let turns = cfg.turns;
    println!(
        "\n{:<26} {:>10} {:>10} {:>10}",
        "Metric", "Baseline", "MooncakeTE", "TENT"
    );
    println!(
        "{:<26} {:>10.0} {:>10.0} {:>10.0}",
        "Input Throughput (tok/s)",
        base.input_throughput_tok_s(),
        te.input_throughput_tok_s(),
        tnt.input_throughput_tok_s()
    );
    println!(
        "{:<26} {:>10.3} {:>10.3} {:>10.3}",
        "Average TTFT (s)",
        base.avg_ttft_s(),
        te.avg_ttft_s(),
        tnt.avg_ttft_s()
    );
    println!(
        "{:<26} {:>10.3} {:>10.3} {:>10.3}",
        "P90 TTFT (s)",
        base.p90_ttft_s(),
        te.p90_ttft_s(),
        tnt.p90_ttft_s()
    );
    for r in [1, turns / 2 + 1, turns] {
        println!(
            "{:<26} {:>10.3} {:>10.3} {:>10.3}",
            format!("R{r} Avg TTFT (s)"),
            base.round_avg_ttft_s(r),
            te.round_avg_ttft_s(r),
            tnt.round_avg_ttft_s(r)
        );
    }
    println!(
        "\nratios — TENT/Baseline throughput: {:.2}x (paper 3.79x at 10 turns)",
        tnt.input_throughput_tok_s() / base.input_throughput_tok_s()
    );
    println!(
        "ratios — TENT/TE throughput: {:.2}x (paper 1.36x) | P90 TTFT -{:.1}% (paper -26.4%)",
        tnt.input_throughput_tok_s() / te.input_throughput_tok_s(),
        (1.0 - tnt.p90_ttft_s() / te.p90_ttft_s()) * 100.0
    );
}
