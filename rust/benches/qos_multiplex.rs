//! QoS multiplexing — latency-class KV-fetch traffic concurrent with bulk
//! checkpoint traffic on one fabric.
//!
//! The paper's production deployments multiplex latency-critical KV-cache
//! fetches with bulk checkpoint/parameter movement on the same rails. This
//! bench reproduces that pressure: several threads run back-to-back bulk
//! transfers (checkpoint-engine shape) while one thread issues sparse,
//! small, synchronous latency-class fetches (KV-cache shape), and reports
//! the latency-class completion percentiles plus bulk goodput — once with
//! the dual-lane QoS datapath (`qos_lanes = true`, the default) and once
//! with the single-lane fallback.
//!
//! Expected shape: single-lane, each fetch queues behind the standing bulk
//! backlog in the shared ring (head-of-line blocking), inflating P99 by
//! orders of magnitude; dual-lane, fetches overtake the backlog and P99
//! collapses to ~service time while bulk goodput stays within a few
//! percent (the anti-starvation quantum costs bulk almost nothing at this
//! latency duty cycle).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferClass, TransferReq};
use tent::fabric::FabricConfig;
use tent::segment::Location;
use tent::util::cli::Args;
use tent::util::clock;
use tent::util::hist::Histogram;
use tent::util::json::Json;
use tent::util::{fmt_bw, fmt_ns};

const LAT_ITERS: usize = 150;
const LAT_WARMUP: usize = 15;
const LAT_BYTES: u64 = 256 << 10;
const BULK_THREADS: usize = 3;
const BULK_BYTES: u64 = 8 << 20;

struct ModeResult {
    p50: u64,
    p90: u64,
    p99: u64,
    bulk_rate: f64,
    ring_full_stalls: u64,
}

fn run_mode(qos: bool) -> tent::Result<ModeResult> {
    let fcfg = FabricConfig {
        time_compression: 4.0,
        ..Default::default()
    };
    let cluster = Cluster::from_profile_nodes("h800_hgx", 2, fcfg)?;
    let cfg = EngineConfig {
        qos_lanes: qos,
        ..Default::default()
    };
    let engine = Arc::new(TentEngine::new(&cluster, cfg)?);

    // Checkpoint-shaped background load: each thread keeps one bulk
    // transfer in flight at all times.
    let stop = Arc::new(AtomicBool::new(false));
    let bulk_moved = Arc::new(AtomicU64::new(0));
    let mut bulk_threads = Vec::new();
    for _ in 0..BULK_THREADS {
        let engine = Arc::clone(&engine);
        let stop = Arc::clone(&stop);
        let bulk_moved = Arc::clone(&bulk_moved);
        let src = engine.register_segment(Location::host(0, 0), BULK_BYTES)?;
        let dst = engine.register_segment(Location::host(1, 0), BULK_BYTES)?;
        bulk_threads.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                engine
                    .transfer_sync(
                        TransferReq::write(src, 0, dst, 0, BULK_BYTES)
                            .class(TransferClass::Bulk),
                        Duration::from_secs(120),
                    )
                    .expect("bulk transfer");
                bulk_moved.fetch_add(BULK_BYTES, Ordering::Relaxed);
            }
        }));
    }

    // KV-fetch-shaped foreground traffic: sparse synchronous latency-class
    // transfers, per-fetch completion time measured end to end.
    let lsrc = engine.register_segment(Location::host(0, 0), LAT_BYTES)?;
    let ldst = engine.register_segment(Location::host(1, 0), LAT_BYTES)?;
    let fetch = |hist: Option<&Histogram>| -> tent::Result<()> {
        let t = clock::now_ns();
        engine.transfer_sync(
            TransferReq::write(lsrc, 0, ldst, 0, LAT_BYTES).class(TransferClass::Latency),
            Duration::from_secs(120),
        )?;
        if let Some(h) = hist {
            h.record(clock::now_ns() - t);
        }
        // Sparse arrivals: the lane goes idle between fetches, so this also
        // exercises the worker wakeup path.
        std::thread::sleep(Duration::from_micros(500));
        Ok(())
    };
    for _ in 0..LAT_WARMUP {
        fetch(None)?;
    }
    let hist = Histogram::new();
    let window_start = clock::now_ns();
    let moved_start = bulk_moved.load(Ordering::Relaxed);
    for _ in 0..LAT_ITERS {
        fetch(Some(&hist))?;
    }
    let window_ns = clock::now_ns() - window_start;
    let moved = bulk_moved.load(Ordering::Relaxed) - moved_start;

    stop.store(true, Ordering::Release);
    for t in bulk_threads {
        t.join().unwrap();
    }
    Ok(ModeResult {
        p50: hist.p50(),
        p90: hist.p90(),
        p99: hist.p99(),
        bulk_rate: moved as f64 / (window_ns as f64 / 1e9),
        ring_full_stalls: engine.stats().ring_full_stalls,
    })
}

fn main() {
    let args = Args::from_env();
    println!("== QoS multiplex: latency-class fetches vs bulk checkpoint traffic ==");
    println!(
        "({BULK_THREADS} bulk threads x {} MiB sync loops, {} x {} KiB latency fetches)",
        BULK_BYTES >> 20,
        LAT_ITERS,
        LAT_BYTES >> 10
    );
    let on = run_mode(true).unwrap();
    let off = run_mode(false).unwrap();
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>14} {:>8}",
        "mode", "lat p50", "lat p90", "lat p99", "bulk goodput", "stalls"
    );
    for (name, r) in [("dual-lane (default)", &on), ("single-lane fallback", &off)] {
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>14} {:>8}",
            name,
            fmt_ns(r.p50),
            fmt_ns(r.p90),
            fmt_ns(r.p99),
            fmt_bw(r.bulk_rate),
            r.ring_full_stalls
        );
    }
    let impr = off.p99 as f64 / on.p99.max(1) as f64;
    let bulk_ratio = on.bulk_rate / off.bulk_rate.max(1.0);
    println!("\nlatency-class P99 improvement (single-lane / dual-lane): {impr:.1}x");
    println!("bulk goodput ratio (dual-lane / single-lane): {bulk_ratio:.2}");
    let pass = on.p99 < off.p99 && bulk_ratio >= 0.90;
    println!(
        "acceptance (dual-lane P99 strictly lower, bulk within 10%): {}",
        if pass { "PASS" } else { "FAIL" }
    );
    if let Some(path) = args.get("json") {
        let mode = |r: &ModeResult| {
            Json::obj(vec![
                ("lat_p50_ns", Json::num(r.p50 as f64)),
                ("lat_p90_ns", Json::num(r.p90 as f64)),
                ("lat_p99_ns", Json::num(r.p99 as f64)),
                ("bulk_goodput_bytes_per_sec", Json::num(r.bulk_rate)),
                ("ring_full_stalls", Json::num(r.ring_full_stalls as f64)),
            ])
        };
        let j = Json::obj(vec![
            ("bench", Json::str("qos_multiplex")),
            ("dual_lane", mode(&on)),
            ("single_lane", mode(&off)),
            ("p99_improvement", Json::num(impr)),
            ("bulk_goodput_ratio", Json::num(bulk_ratio)),
            ("pass", Json::Bool(pass)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!("results written to {path}");
    }
    if !pass {
        std::process::exit(1);
    }
}
