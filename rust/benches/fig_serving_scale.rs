//! Serving at scale — continuous batching vs turn-major FIFO on a fleet
//! (ROADMAP "millions of concurrent users"; the serving-layer claim on top
//! of Table 2's per-request KV movement).
//!
//! Drives `serving::batching::serve_fleet` with an arrival-driven
//! multi-turn session workload (Poisson arrivals, 50/50 interactive/batch
//! SLO classes, shared system prompt) over one engine per node, twice:
//! once with the iteration-level continuous-batching scheduler, once with
//! the same machinery degraded to strict-FIFO turn-major service. All
//! latencies are **virtual-clock** (modeled batch + fetch cost), so the
//! comparison is deterministic and machine-independent; the KV bytes still
//! move through the real engine data plane (tiered cache fetch/store).
//!
//! Gates (full run):
//! * continuous beats FIFO on P90 TTFT,
//! * at equal-or-better input throughput,
//! * and interactive P99 TTFT meets its SLO under continuous batching.
//!
//! `--smoke` runs a small fleet and reports without failing the build;
//! `--sessions N` / `--nodes N` override the workload size.

use std::sync::Arc;
use tent::cluster::{Fleet, FleetConfig};
use tent::runtime::{ModelExecutor, ModelMeta, SyntheticConfig, SyntheticModel};
use tent::serving::{
    build_sessions, BatchConfig, BatchReport, KvCacheConfig, RequestClass, SchedulePolicy,
    SessionWorkload,
};
use tent::util::cli::Args;
use tent::util::fmt_ns;
use tent::util::json::Json;

/// Serving shape: 128-token context in 32-token chunks, 64 KiB KV per
/// session (16 KiB cache blocks) — small enough that tens of thousands of
/// sessions fit one process, large enough that cache movement is real.
fn bench_meta() -> ModelMeta {
    ModelMeta::custom(2, 2, 16, 128, 32, 1024, 100_000)
}

fn run_policy(
    schedule: SchedulePolicy,
    nodes: u16,
    sessions: usize,
    seed: u64,
) -> (BatchReport, BatchConfig) {
    let meta = bench_meta();
    let w = SessionWorkload {
        sessions,
        turns: 2,
        interactive_share: 0.5,
        mean_interarrival_ns: 50_000,
        think_ns: 1_000_000,
        shared_system_prompt: true,
        seed,
    };
    let scripts = build_sessions(&[&meta], &w);
    let cfg = BatchConfig {
        schedule,
        max_running: 32,
        prefill_chunks_per_iter: 8,
        interactive_reserve: 8,
        decode_tokens: 4,
        cache: KvCacheConfig {
            gpus: 8,
            gpu_blocks_per_gpu: 3,
            cpu_blocks: 512,
            disk_blocks: 4096,
            ..KvCacheConfig::default()
        },
        ..BatchConfig::default()
    };
    let fleet = Fleet::new(FleetConfig::new("h800_hgx", nodes)).expect("fleet build");
    let model: Arc<dyn ModelExecutor> = Arc::new(SyntheticModel::new(
        meta,
        SyntheticConfig {
            pace: false,
            ..SyntheticConfig::default()
        },
    ));
    let report = fleet.serve_sessions(&[model], &scripts, &cfg).expect("serve");
    (report, cfg)
}

fn row(label: &str, r: &BatchReport, cfg: &BatchConfig) {
    let h = r.ttft_hist(None);
    println!(
        "{:<12} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12.0} {:>9.3}",
        label,
        r.rows.len(),
        fmt_ns(h.p50()),
        fmt_ns(h.p90()),
        fmt_ns(h.p99()),
        fmt_ns((r.p99_ttft_s(RequestClass::Interactive) * 1e9) as u64),
        fmt_ns(r.makespan_ns),
        r.input_throughput_tok_s(),
        r.slo_attainment(RequestClass::Interactive, &cfg.slo),
    );
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let nodes: u16 = match args.get("nodes") {
        Some(n) => n.parse().expect("--nodes"),
        None if smoke => 2,
        None => 4,
    };
    let sessions: usize = match args.get("sessions") {
        Some(n) => n.parse().expect("--sessions"),
        None if smoke => 300,
        None => 10_000,
    };

    println!("== fig_serving_scale: continuous batching vs FIFO turn-major ==");
    println!(
        "({sessions} sessions x 2 turns on {nodes} engines; Poisson arrivals, 50/50 \
         interactive/batch, virtual-clock latencies)"
    );
    println!();
    println!(
        "{:<12} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10} {:>12} {:>9}",
        "policy", "turns", "ttftP50", "ttftP90", "ttftP99", "intP99", "makespan", "tok/s", "sloAtt"
    );

    let (fifo, cfg) = run_policy(SchedulePolicy::Fifo, nodes, sessions, 7);
    row("fifo", &fifo, &cfg);
    let (cont, cfg) = run_policy(SchedulePolicy::Continuous, nodes, sessions, 7);
    row("continuous", &cont, &cfg);

    // ---- verdicts ----
    println!();
    let mut pass = true;

    let fifo_p90 = fifo.p90_ttft_s();
    let cont_p90 = cont.p90_ttft_s();
    let p90_ok = cont_p90 < fifo_p90;
    println!(
        "continuous beats FIFO on P90 TTFT: {} vs {} : {}",
        fmt_ns((cont_p90 * 1e9) as u64),
        fmt_ns((fifo_p90 * 1e9) as u64),
        if p90_ok { "PASS" } else { "FAIL" }
    );
    pass &= p90_ok;

    let fifo_tput = fifo.input_throughput_tok_s();
    let cont_tput = cont.input_throughput_tok_s();
    let tput_ok = cont_tput >= 0.98 * fifo_tput;
    println!(
        "at equal-or-better input throughput: {cont_tput:.0} vs {fifo_tput:.0} tok/s \
         (>= 0.98x): {}",
        if tput_ok { "PASS" } else { "FAIL" }
    );
    pass &= tput_ok;

    let int_p99_s = cont.p99_ttft_s(RequestClass::Interactive);
    let slo_s = cfg.slo.interactive_ttft_ns as f64 / 1e9;
    let slo_ok = int_p99_s <= slo_s;
    println!(
        "interactive P99 TTFT meets SLO under continuous: {} <= {} : {}",
        fmt_ns((int_p99_s * 1e9) as u64),
        fmt_ns(cfg.slo.interactive_ttft_ns),
        if slo_ok { "PASS" } else { "FAIL" }
    );
    pass &= slo_ok;

    if let Some(path) = args.get("json") {
        let cell = |label: &str, r: &BatchReport| {
            let h = r.ttft_hist(None);
            Json::obj(vec![
                ("policy", Json::str(label)),
                ("turns", Json::num(r.rows.len() as f64)),
                ("ttft_p50_ns", Json::num(h.p50() as f64)),
                ("ttft_p90_ns", Json::num(h.p90() as f64)),
                ("ttft_p99_ns", Json::num(h.p99() as f64)),
                (
                    "interactive_p99_ttft_ns",
                    Json::num(r.p99_ttft_s(RequestClass::Interactive) * 1e9),
                ),
                ("makespan_ns", Json::num(r.makespan_ns as f64)),
                ("input_tok_per_s", Json::num(r.input_throughput_tok_s())),
                (
                    "interactive_slo_attainment",
                    Json::num(r.slo_attainment(RequestClass::Interactive, &cfg.slo)),
                ),
            ])
        };
        let j = Json::obj(vec![
            ("bench", Json::str("fig_serving_scale")),
            ("smoke", Json::Bool(smoke)),
            ("sessions", Json::num(sessions as f64)),
            ("nodes", Json::num(nodes as f64)),
            (
                "cells",
                Json::arr([cell("fifo", &fifo), cell("continuous", &cont)]),
            ),
            ("pass", Json::Bool(pass)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!();
        println!("results written to {path}");
    }

    println!();
    println!("overall: {}", if pass { "PASS" } else { "FAIL" });
    // Smoke reports without failing the build (tiny fleets under-load the
    // scheduler); full runs hard-fail on a lost gate.
    if !pass && !smoke {
        std::process::exit(1);
    }
}
