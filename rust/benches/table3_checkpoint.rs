//! Table 3 — model parameter update time with the checkpoint engine,
//! Mooncake TE vs TENT, two model sizes.
//!
//! Paper: 8×H800 TP8 FP16; Qwen3-235B-A22B 12.87 s → 10.34 s (−19.7%),
//! GLM-4.5-Air 7.17 s → 5.30 s (−26.1%). Payloads here are scaled with the
//! same ~1.8:1 size ratio; absolute seconds are sim-scale, the *relative
//! improvement* is the reproduction target.

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::policy::PolicyKind;
use tent::runtime::{make_executor, ModelSelect};
use tent::serving::{CheckpointConfig, CheckpointEngine};

fn run_update(policy: PolicyKind, payload_bytes: u64) -> f64 {
    let cluster =
        Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default()).unwrap();
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy)).unwrap());
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )
    .unwrap();
    let payload: Vec<u8> = (0..payload_bytes).map(|i| (i % 249) as u8).collect();
    ce.stage_weights(&payload).unwrap();
    let rep = ce.update().unwrap();
    assert!(ce.verify().unwrap());
    rep.seconds()
}

fn main() {
    println!("== Table 3: parameter update time (8 ranks, pipelined broadcast) ==");
    let models: [(&str, u64); 2] = [
        ("Qwen3-235B-A22B (scaled)", 64 << 20),
        ("GLM-4.5-Air (scaled)", 36 << 20),
    ];
    println!(
        "{:<28} {:>12} {:>12} {:>10}",
        "Model", "Mooncake TE", "TENT", "delta"
    );
    for (name, bytes) in models {
        let te = run_update(PolicyKind::MooncakeTe, bytes);
        let tnt = run_update(PolicyKind::Tent, bytes);
        println!(
            "{:<28} {:>11.3}s {:>11.3}s {:>9.1}%",
            name,
            te,
            tnt,
            (1.0 - tnt / te) * 100.0
        );
    }
    println!("\npaper: -19.7% (Qwen3-235B), -26.1% (GLM-4.5-Air)");

    // Update-then-inference: broadcast an executor-sized checkpoint and
    // install it on rank 0 — the paper's in-place update, closed end to end
    // (runs with no artifacts: Auto falls back to the synthetic model).
    let mut model = make_executor(ModelSelect::Auto).unwrap();
    let param_bytes = model.meta().param_count as u64 * 4;
    let cluster =
        Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default()).unwrap();
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::default()).unwrap());
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes: param_bytes,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )
    .unwrap();
    let payload: Vec<u8> = (0..param_bytes).map(|i| (i % 251) as u8).collect();
    ce.stage_weights(&payload).unwrap();
    ce.update().unwrap();
    assert!(ce.verify().unwrap());
    ce.install_into(0, model.as_mut()).unwrap();
    let tokens: Vec<i32> = (0..model.meta().t_pre as i32).collect();
    let (tok, _) = model.prefill(&tokens, model.empty_kv().unwrap(), 0).unwrap();
    println!(
        "\nupdate-then-inference ({} model, {} payload): next token = {tok} — OK",
        model.name(),
        tent::util::fmt_bytes(param_bytes)
    );
}
