//! Resilience envelope — goodput under trace-driven chaos, and the
//! sub-50 ms self-healing acceptance gate (§6.3; ROADMAP "Failure-trace
//! replay at fleet scale").
//!
//! Each cell replays a deterministic fault schedule (Table 1 trace at
//! `eps` events/sec plus correlated storms, a flapping link expansion, a
//! slow drain, and a congestion ramp) against a live fleet while the mixed
//! KV-fetch / checkpoint workload runs. The healing probe times every
//! injected hard failure from the injection instant to the first rerouted-
//! slice completion on a surviving rail — the paper's headline resilience
//! quantity — plus goodput recovery to 90% of the pre-fault rate.
//!
//! Output: goodput vs events/sec × fleet size × policy, retained-goodput
//! fraction vs the no-fault baseline, healing P50/P99, and per-event
//! outcome counts. The verdict gates on the TENT policy: every fault that
//! touched traffic must heal, nothing may fail permanently, and P99
//! healing latency must beat 50 ms.
//!
//! `--smoke` runs the 8-node column at one intensity (CI). Other knobs:
//! `--nodes 8,16`, `--eps 0,3,8`, `--seed N`, `--policies tent,rr`,
//! `--dump-schedule path` (write the first generated schedule),
//! `--schedule path` (replay a saved schedule file in every chaos cell —
//! pair it with a single `--nodes` value so rail ids line up).

use std::time::Duration;
use tent::chaos::{self, ChaosSchedule, ProbeConfig, ScenarioMix};
use tent::cluster::{Fleet, FleetConfig, WorkloadConfig};
use tent::policy::PolicyKind;
use tent::util::cli::Args;
use tent::util::{fmt_bw, fmt_ns};

const HEAL_GATE_NS: u64 = 50_000_000; // the sub-50 ms claim

struct Cell {
    goodput: f64,
    fails: u64,
    healed: u64,
    untouched: u64,
    unhealed: u64,
    heal_p50: u64,
    heal_p99: u64,
    recovery_p99: u64,
    failed_batches: u64,
}

/// One sweep point: fleet shape + chaos intensity + schedule source.
struct CellSpec<'a> {
    nodes: u16,
    policy: PolicyKind,
    eps: f64,
    seed: u64,
    duration: Duration,
    horizon_ns: u64,
    loaded: Option<&'a ChaosSchedule>,
}

fn run_cell(spec: &CellSpec, dump: &mut Option<String>) -> Cell {
    let &CellSpec { nodes, policy, eps, seed, duration, horizon_ns, loaded } = spec;
    let mut cfg = FleetConfig::new("h800_hgx", nodes);
    cfg.policy = policy;
    let fleet = Fleet::new(cfg).expect("fleet build");
    let schedule = if eps == 0.0 {
        // No-fault baseline: empty schedule, same harness path.
        ChaosSchedule { seed, horizon_ns, events: Vec::new() }
    } else if let Some(s) = loaded {
        s.clone()
    } else {
        let mix = ScenarioMix {
            trace_events_per_sec: eps,
            ..Default::default()
        };
        ChaosSchedule::generate(&fleet.cluster.topo, seed, horizon_ns, &mix)
    };
    if eps > 0.0 {
        if let Some(path) = dump.take() {
            schedule.save(&path).expect("--dump-schedule write");
            eprintln!("(schedule dumped to {path}: {} events)", schedule.events.len());
        }
    }
    let w = WorkloadConfig {
        duration,
        ..Default::default()
    };
    let r = chaos::run(&fleet, &schedule, &w, ProbeConfig::default()).expect("chaos run");
    Cell {
        goodput: r.fleet.aggregate_goodput(),
        fails: r.outcome.fails_injected,
        healed: r.outcome.healed,
        untouched: r.outcome.untouched,
        unhealed: r.outcome.unhealed,
        heal_p50: r.fleet.healing_hist.p50(),
        heal_p99: r.fleet.healing_hist.p99(),
        recovery_p99: r.fleet.recovery_hist.p99(),
        failed_batches: r.fleet.failed_batches,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let seed: u64 = args
        .get("seed")
        .map(|s| s.parse().expect("--seed"))
        .unwrap_or(0xC4A0_5EED);
    let nodes_sweep: Vec<u16> = match args.get("nodes") {
        Some(list) => list.split(',').map(|s| s.trim().parse().expect("--nodes list")).collect(),
        None if smoke => vec![8],
        None => vec![8, 16],
    };
    let eps_sweep: Vec<f64> = match args.get("eps") {
        Some(list) => list.split(',').map(|s| s.trim().parse().expect("--eps list")).collect(),
        None if smoke => vec![0.0, 5.0],
        None => vec![0.0, 3.0, 8.0],
    };
    let policies: Vec<PolicyKind> = match args.get("policies") {
        Some(list) => list
            .split(',')
            .map(|s| PolicyKind::parse(s.trim()).expect("--policies list"))
            .collect(),
        None if smoke => vec![PolicyKind::Tent],
        None => vec![PolicyKind::Tent, PolicyKind::MooncakeTe],
    };
    let loaded = args.get("schedule").map(|p| {
        ChaosSchedule::load(p).expect("--schedule load")
    });
    let mut dump = args.get("dump-schedule").map(|s| s.to_string());

    let duration = if smoke {
        Duration::from_millis(700)
    } else {
        Duration::from_millis(1500)
    };
    // Schedule horizon ends before submission stops, so late faults still
    // see traffic to disturb (and their heals are observable).
    let horizon_ns = duration.as_nanos() as u64 - 250_000_000;

    println!("== fig_resilience: goodput under trace-driven chaos + healing gate ==");
    println!("(h800_hgx, Table 1 trace + storms/flaps/drains/ramps; 20x time compression)");
    println!();
    println!(
        "{:<7} {:<13} {:>5} {:>10} {:>7} {:>6} {:>6} {:>6} {:>6} {:>10} {:>10} {:>10}",
        "nodes", "policy", "eps", "goodput", "retain", "fails", "healed", "quiet", "unheal",
        "healP50", "healP99", "recovP99"
    );

    let mut gate_pass = true;
    let mut gated_cells = 0u32;
    for &n in &nodes_sweep {
        for policy in &policies {
            let mut baseline: Option<f64> = None;
            for &eps in &eps_sweep {
                let spec = CellSpec {
                    nodes: n,
                    policy: *policy,
                    eps,
                    seed,
                    duration,
                    horizon_ns,
                    loaded: loaded.as_ref(),
                };
                let c = run_cell(&spec, &mut dump);
                let retain = match baseline {
                    Some(b) if b > 0.0 => c.goodput / b,
                    _ => 1.0,
                };
                if eps == 0.0 {
                    baseline = Some(c.goodput);
                }
                println!(
                    "{:<7} {:<13} {:>5} {:>10} {:>6.1}% {:>6} {:>6} {:>6} {:>6} {:>10} {:>10} {:>10}",
                    n,
                    policy.name(),
                    eps,
                    fmt_bw(c.goodput),
                    100.0 * retain,
                    c.fails,
                    c.healed,
                    c.untouched,
                    c.unhealed,
                    if c.healed > 0 { fmt_ns(c.heal_p50) } else { "-".into() },
                    if c.healed > 0 { fmt_ns(c.heal_p99) } else { "-".into() },
                    if c.recovery_p99 > 0 { fmt_ns(c.recovery_p99) } else { "-".into() },
                );
                // The healing gate scores the TENT policy's chaos cells.
                if *policy == PolicyKind::Tent && eps > 0.0 {
                    gated_cells += 1;
                    let cell_ok = c.unhealed == 0
                        && c.failed_batches == 0
                        && (c.healed == 0 || c.heal_p99 < HEAL_GATE_NS);
                    if !cell_ok {
                        eprintln!(
                            "  gate violation at nodes={n} eps={eps}: unhealed={} failed_batches={} healP99={}",
                            c.unhealed,
                            c.failed_batches,
                            fmt_ns(c.heal_p99)
                        );
                    }
                    gate_pass &= cell_ok;
                }
            }
        }
    }

    println!();
    println!(
        "self-healing gate (tent, {} chaos cell{}): every touched fault healed, zero lost \
         batches, P99 heal < {}: {}",
        gated_cells,
        if gated_cells == 1 { "" } else { "s" },
        fmt_ns(HEAL_GATE_NS),
        if gate_pass { "PASS" } else { "FAIL" }
    );
    // Smoke reports on shared CI runners without failing the build (a
    // crash or hang still does); full runs hard-fail, fig_scaling-style.
    if !gate_pass && !smoke {
        std::process::exit(1);
    }
}
