//! Figure 6 — GPU-to-GPU write throughput and P99 latency across nodes.
//!
//! Paper setup: one-to-one GPU writes between two nodes; each GPU has one
//! tier-1 NIC (same PCIe root) and three tier-2 NICs (same NUMA node).
//! Mooncake TE / UCCL pin GPU traffic to the tier-1 NIC; TENT recruits
//! tier-2 rails once the tier-1 NIC saturates (paper: 2.1× throughput,
//! P99 to 46.7%). Per-NIC byte counters confirm roughly half the bytes ride
//! the tier-1 NIC, the rest spread across tier-2.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::policy::PolicyKind;
use tent::segment::Location;
use tent::util::{fmt_bw, fmt_bytes, fmt_ns};

const POLICIES: [PolicyKind; 4] = [
    PolicyKind::Tent,
    PolicyKind::MooncakeTe,
    PolicyKind::Nixl,
    PolicyKind::UcclP2p,
];
const BLOCKS: [u64; 5] = [256 << 10, 1 << 20, 4 << 20, 16 << 20, 64 << 20];

fn bench_one(policy: PolicyKind, block: u64) -> tent::Result<(f64, u64, Vec<(String, u64)>)> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let seg_len = (block * 4).max(16 << 20);
    let src = engine.register_segment(Location::device(0, 0), seg_len)?;
    let dst = engine.register_segment(Location::device(1, 0), seg_len)?;
    let pairs = [ThreadPair { src, dst, seg_len }];
    let iters = ((128u64 << 20) / block).clamp(6, 128) as usize;
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: 1,
        iters,
        warmup: 2,
        op: TransferOp::Write,
        time_limit: Duration::from_secs(25),
    };
    let r = bench::run(&engine, &pairs, &cfg)?;
    let per_nic = engine
        .rail_snapshots()
        .into_iter()
        .filter(|s| s.fabric == "rdma" && s.bytes_carried > 0)
        .map(|s| (s.name, s.bytes_carried))
        .collect();
    Ok((r.throughput(), r.latency.p99(), per_nic))
}

fn main() {
    println!("== Figure 6: cross-node GPU-to-GPU write throughput + P99 ==");
    print!("{:<10}", "block");
    for p in POLICIES {
        print!(" {:>22}", p.name());
    }
    println!();
    let mut tent_counters = Vec::new();
    for block in BLOCKS {
        print!("{:<10}", fmt_bytes(block));
        for p in POLICIES {
            let (bw, p99, nics) = bench_one(p, block).unwrap();
            print!(" {:>11} {:>10}", fmt_bw(bw), fmt_ns(p99));
            if p == PolicyKind::Tent && block == 64 << 20 {
                tent_counters = nics;
            }
        }
        println!();
    }
    println!("\nTENT per-NIC byte counters at 64 MiB (tier-1 = n0-mlx0):");
    let total: u64 = tent_counters.iter().map(|(_, b)| b).sum();
    for (name, bytes) in &tent_counters {
        println!(
            "  {:<12} {:>10}  ({:.0}%)",
            name,
            fmt_bytes(*bytes),
            *bytes as f64 / total.max(1) as f64 * 100.0
        );
    }
    println!("\nexpected shape: TE/UCCL capped at the tier-1 NIC; TENT recruits tier-2");
    println!("rails for large blocks (~half the bytes on tier-1, rest spread).");
}
