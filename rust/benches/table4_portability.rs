//! Table 4 — peak vs theoretical read bandwidth across transfer modes.
//!
//! Same BatchTransfer calls everywhere; only the cluster profile differs.
//! Theoretical columns are the paper's hardware numbers divided by the
//! 1:100 sim scale (DESIGN.md). The measured/theoretical *ratio* is the
//! reproduction target (paper: NVLink 172/204.5, MNNVL 781.8/956.2, …).

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::segment::Location;
use tent::topology::profile::{gbps_paper, theoretical};
use tent::util::fmt_bw;

struct Row {
    name: &'static str,
    profile: &'static str,
    src: Location,
    dst: Location,
    threads: usize,
    /// Theoretical bytes/sec (sim scale); None → measured-native (†).
    theoretical: Option<f64>,
}

fn measure(row: &Row) -> tent::Result<f64> {
    let cluster =
        Cluster::from_profile_nodes(row.profile, 2, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::default())?);
    let seg_len = 64u64 << 20;
    let pairs: Vec<ThreadPair> = (0..row.threads)
        .map(|i| {
            let bump = |l: &Location| match l {
                Location::Device { node, gpu } => {
                    Location::device(node.0, (gpu + i as u8) % 8)
                }
                other => other.clone(),
            };
            let (s, d) = (bump(&row.src), bump(&row.dst));
            let src = if s.is_storage() {
                engine.register_file_segment(s, seg_len)?
            } else {
                engine.register_segment(s, seg_len)?
            };
            let dst = if d.is_storage() {
                engine.register_file_segment(d, seg_len)?
            } else {
                engine.register_segment(d, seg_len)?
            };
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;
    let cfg = TeBenchConfig {
        block_size: 16 << 20,
        batch_size: 2,
        iters: 6,
        warmup: 1,
        op: TransferOp::Read,
        time_limit: Duration::from_secs(30),
    };
    Ok(bench::run(&engine, &pairs, &cfg)?.throughput())
}

fn main() {
    println!("== Table 4: peak vs theoretical read bandwidth per transport (sim 1:100) ==");
    let tmp = std::env::temp_dir();
    let file_path = tmp.join(format!("tent_t4_{}.bin", std::process::id()));
    let rows = vec![
        Row {
            name: "RDMA: GPU->GPU (8 rails)",
            profile: "h800_hgx",
            src: Location::device(0, 0),
            dst: Location::device(1, 0),
            threads: 8,
            theoretical: Some(8.0 * gbps_paper(theoretical::RDMA_RAIL_GBPS)),
        },
        Row {
            name: "RDMA: GPU->Host (staged)",
            profile: "no_gpudirect",
            src: Location::device(0, 0),
            dst: Location::host(1, 0),
            threads: 4,
            theoretical: None,
        },
        Row {
            name: "RDMA: GPU->GPU (staged)",
            profile: "no_gpudirect",
            src: Location::device(0, 0),
            dst: Location::device(1, 0),
            threads: 4,
            theoretical: None,
        },
        Row {
            name: "NVLink: GPU->GPU",
            profile: "h800_hgx",
            src: Location::device(0, 0),
            dst: Location::device(0, 4),
            threads: 1,
            theoretical: Some(gbps_paper(theoretical::NVLINK_GBPS)),
        },
        Row {
            name: "io_uring: Host->File",
            profile: "h800_hgx",
            src: Location::host(0, 0),
            dst: Location::storage(0, file_path.clone()),
            threads: 1,
            theoretical: Some(gbps_paper(6.0)),
        },
        Row {
            name: "MNNVL: GPU->GPU",
            profile: "mnnvl_rack",
            src: Location::device(0, 0),
            dst: Location::device(1, 0),
            threads: 1,
            theoretical: Some(gbps_paper(theoretical::MNNVL_GBPS)),
        },
        Row {
            name: "Ascend: GPU->GPU",
            profile: "ascend_ub",
            src: Location::device(0, 0),
            dst: Location::device(0, 4),
            threads: 1,
            theoretical: Some(gbps_paper(theoretical::ASCEND_GBPS)),
        },
    ];
    println!(
        "{:<28} {:>14} {:>14} {:>8}",
        "Transport", "Measured BW", "Theoretical", "ratio"
    );
    for row in rows {
        let bw = measure(&row).unwrap();
        match row.theoretical {
            Some(t) => println!(
                "{:<28} {:>14} {:>14} {:>7.0}%",
                row.name,
                fmt_bw(bw),
                fmt_bw(t),
                bw / t * 100.0
            ),
            None => println!("{:<28} {:>14} {:>14} {:>8}", row.name, fmt_bw(bw), "-", "-"),
        }
    }
    std::fs::remove_file(file_path).ok();
    println!("\npaper ratios: NVLink 84%, MNNVL 82%, Ascend 69%, RDMA near line rate;");
    println!("staged modes substantially below direct (bounce-buffer hops).");
}
