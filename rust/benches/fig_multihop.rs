//! fig_multihop — staged routing across heterogeneous silos: direct vs
//! single-bounce vs k-hop relay goodput, plus the relay-cost ablation.
//!
//! Three device-to-device streams over increasingly partitioned fabrics:
//!
//! * **direct** (`h800_hgx`) — GPUDirect RDMA spans the nodes, no staging;
//! * **1-bounce** (`no_gpudirect`) — the classic staged synthesis: D2H,
//!   one H2H leg, H2D;
//! * **k-hop** (`silo_fleet`) — the silos share no fabric at all, so the
//!   planner routes through a dual-fabric gateway's host memory and the
//!   relay ledger must balance (every byte in, every byte out).
//!
//! The relay-cost ablation then drives the fleet-level cross-silo handoff
//! with `SchedParams::relay_cost` ∈ {0, 1, 4}: pricing the store-and-forward
//! term is a ranking knob, so correctness (zero failed batches, balanced
//! gateway ledgers) must hold at every setting.
//!
//! Hard gates: zero failures everywhere, k-hop relay conservation, and the
//! direct stream out-running the TCP-bottlenecked k-hop stream. The goodput
//! spread itself is reported, not gated (wall-clock, machine-dependent).
//!
//! Flags: --smoke         shrink payloads/durations for CI
//!        --json <path>   write BENCH_multihop.json

use std::sync::Arc;
use std::time::{Duration, Instant};
use tent::cluster::{Cluster, CrossSiloConfig, Fleet, FleetConfig};
use tent::engine::{EngineConfig, TentEngine, TransferReq};
use tent::fabric::FabricConfig;
use tent::segment::Location;
use tent::topology::NodeId;
use tent::util::cli::Args;
use tent::util::json::Json;

const TIMEOUT: Duration = Duration::from_secs(120);

struct Scenario {
    name: &'static str,
    goodput: f64,
    relay_in: u64,
    relay_out: u64,
    failures: u64,
}

/// Stream `iters` device-to-device payloads node 0 → node 1 and measure
/// wall-clock goodput; the relay ledger is read at `relay_node` (the
/// gateway on `silo_fleet`, a no-op node elsewhere).
fn stream(
    name: &'static str,
    profile: &str,
    nodes: u16,
    payload: u64,
    iters: usize,
    relay_node: u16,
) -> tent::Result<Scenario> {
    let c = Cluster::from_profile_nodes(profile, nodes, FabricConfig::default())?;
    let e = Arc::new(TentEngine::new(&c, EngineConfig::default())?);
    let src = e.register_segment(Location::device(0, 0), payload)?;
    let dst = e.register_segment(Location::device(1, 0), payload)?;
    e.transfer_sync(TransferReq::write(src, 0, dst, 0, payload), TIMEOUT)?; // warm-up
    let t0 = Instant::now();
    for _ in 0..iters {
        e.transfer_sync(TransferReq::write(src, 0, dst, 0, payload), TIMEOUT)?;
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let (relay_in, relay_out) = c.fabric.relay_bytes(NodeId(relay_node));
    Ok(Scenario {
        name,
        goodput: payload as f64 * iters as f64 / wall,
        relay_in,
        relay_out,
        failures: e.stats().permanent_failures,
    })
}

struct AblationRow {
    relay_cost: f64,
    goodput: f64,
    relayed: u64,
    balanced: bool,
    failed_batches: u64,
}

/// Fleet-level cross-silo handoff on 6 nodes (two gateways) with the
/// store-and-forward pricing term set to `relay_cost`.
fn ablate(relay_cost: f64, duration: Duration) -> tent::Result<AblationRow> {
    let mut fc = FleetConfig::new("silo_fleet", 6);
    fc.engine.sched.relay_cost = relay_cost;
    let fleet = Fleet::new(fc)?;
    let cfg = CrossSiloConfig {
        duration,
        block: 128 << 10,
        window: 2,
        ..Default::default()
    };
    let r = fleet.run_cross_silo(&cfg)?;
    let mut relayed = 0u64;
    let mut balanced = true;
    for gw in [2u16, 5] {
        let (inb, outb) = fleet.cluster.fabric.relay_bytes(NodeId(gw));
        balanced &= inb == outb;
        relayed += inb;
    }
    Ok(AblationRow {
        relay_cost,
        goodput: r.aggregate_goodput(),
        relayed,
        balanced,
        failed_batches: r.failed_batches,
    })
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let (payload, iters) = if smoke { (256u64 << 10, 6) } else { (1u64 << 20, 32) };
    let duration = if smoke {
        Duration::from_millis(250)
    } else {
        Duration::from_millis(700)
    };

    println!("== fig_multihop: direct vs 1-bounce vs k-hop staged routing ==");
    println!("(device-to-device node 0 -> node 1, {iters} x {} per stream)", tent::util::fmt_bytes(payload));
    let scenarios = vec![
        stream("direct(h800_hgx)", "h800_hgx", 2, payload, iters, 0).unwrap(),
        stream("1-bounce(no_gpudirect)", "no_gpudirect", 2, payload, iters, 0).unwrap(),
        stream("k-hop(silo_fleet)", "silo_fleet", 3, payload, iters, 2).unwrap(),
    ];
    println!(
        "{:<24} {:>14} {:>12} {:>12} {:>6}",
        "scenario", "goodput", "relay_in", "relay_out", "fails"
    );
    for s in &scenarios {
        println!(
            "{:<24} {:>12}/s {:>12} {:>12} {:>6}",
            s.name,
            tent::util::fmt_bytes(s.goodput as u64),
            tent::util::fmt_bytes(s.relay_in),
            tent::util::fmt_bytes(s.relay_out),
            s.failures
        );
    }

    println!("\n-- relay-cost ablation (6-node silo fleet, cross-silo handoff) --");
    println!(
        "{:<12} {:>14} {:>12} {:>9} {:>7}",
        "relay_cost", "goodput", "relayed", "balanced", "failed"
    );
    let ablation: Vec<AblationRow> = [0.0, 1.0, 4.0]
        .iter()
        .map(|&rc| ablate(rc, duration).unwrap())
        .collect();
    for a in &ablation {
        println!(
            "{:<12} {:>12}/s {:>12} {:>9} {:>7}",
            a.relay_cost,
            tent::util::fmt_bytes(a.goodput as u64),
            tent::util::fmt_bytes(a.relayed),
            a.balanced,
            a.failed_batches
        );
    }

    // Hard gates: correctness everywhere, and the fabric hierarchy showing
    // through (a direct GPUDirect stream beats a TCP-bottlenecked relay).
    let khop = &scenarios[2];
    let total = payload * (iters as u64 + 1); // warm-up included
    let mut failures: Vec<String> = Vec::new();
    if scenarios.iter().any(|s| s.failures > 0) {
        failures.push("a stream saw permanent failures".into());
    }
    if khop.relay_in != khop.relay_out {
        failures.push(format!(
            "k-hop relay ledger imbalanced ({} in, {} out)",
            khop.relay_in, khop.relay_out
        ));
    }
    if khop.relay_in < total {
        failures.push(format!(
            "k-hop relayed {} < moved {total}: the stream skipped the gateway",
            khop.relay_in
        ));
    }
    if scenarios[0].goodput <= khop.goodput {
        failures.push("direct stream did not out-run the k-hop relay".into());
    }
    if ablation.iter().any(|a| a.failed_batches > 0 || !a.balanced || a.relayed == 0) {
        failures.push("relay-cost ablation broke conservation or dropped batches".into());
    }
    let pass = failures.is_empty();
    for f in &failures {
        eprintln!("GATE: {f}");
    }
    println!(
        "\nacceptance (zero failures, balanced relay ledgers, direct > k-hop): {}",
        if pass { "PASS" } else { "FAIL" }
    );

    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("bench", Json::str("fig_multihop")),
            ("smoke", Json::Bool(smoke)),
            (
                "scenarios",
                Json::arr(scenarios.iter().map(|s| {
                    Json::obj(vec![
                        ("name", Json::str(s.name)),
                        ("goodput_bytes_per_sec", Json::num(s.goodput)),
                        ("relay_in", Json::num(s.relay_in as f64)),
                        ("relay_out", Json::num(s.relay_out as f64)),
                        ("failures", Json::num(s.failures as f64)),
                    ])
                })),
            ),
            (
                "relay_cost_ablation",
                Json::arr(ablation.iter().map(|a| {
                    Json::obj(vec![
                        ("relay_cost", Json::num(a.relay_cost)),
                        ("goodput_bytes_per_sec", Json::num(a.goodput)),
                        ("relayed", Json::num(a.relayed as f64)),
                        ("balanced", Json::Bool(a.balanced)),
                        ("failed_batches", Json::num(a.failed_batches as f64)),
                    ])
                })),
            ),
            ("pass", Json::Bool(pass)),
        ]);
        std::fs::write(path, format!("{j}\n")).expect("write --json");
        println!("results written to {path}");
    }
    if !pass {
        std::process::exit(1);
    }
}
