//! Figure 8 — sensitivity to the tier-2 topology penalty P₁ (the paper's
//! notation for the penalty separating tier-1 from tier-2 rails).
//!
//! Fig. 6 setup (cross-node GPU write), varying the penalty while holding
//! everything else fixed. Paper: too large → degenerates to single-rail
//! (Mooncake-TE-like); too small → overuses expensive tier-2 rails; best
//! around P₁ = 3, and mis-setting degrades only modestly because the
//! feedback loop corrects.

use std::sync::Arc;
use std::time::Duration;
use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferOp};
use tent::segment::Location;
use tent::util::{fmt_bytes, fmt_ns};

const P1S: [f64; 5] = [1.0, 1.5, 3.0, 8.0, 64.0];
const BLOCKS: [u64; 4] = [1 << 20, 4 << 20, 16 << 20, 64 << 20];

fn bench_one(p1: f64, block: u64) -> tent::Result<u64> {
    let cluster = Cluster::from_profile("h800_hgx")?;
    let mut cfg = EngineConfig::default();
    cfg.sched.tier_penalties = [1.0, p1, f64::INFINITY];
    let engine = Arc::new(TentEngine::new(&cluster, cfg)?);
    let seg_len = (block * 4).max(16 << 20);
    let src = engine.register_segment(Location::device(0, 0), seg_len)?;
    let dst = engine.register_segment(Location::device(1, 0), seg_len)?;
    let pairs = [ThreadPair { src, dst, seg_len }];
    let iters = ((96u64 << 20) / block).clamp(6, 64) as usize;
    let bcfg = TeBenchConfig {
        block_size: block,
        batch_size: 1,
        iters,
        warmup: 2,
        op: TransferOp::Read,
        time_limit: Duration::from_secs(20),
    };
    let r = bench::run(&engine, &pairs, &bcfg)?;
    Ok(r.latency.p99())
}

fn main() {
    println!("== Figure 8: GPU-to-GPU P99 read latency vs tier-2 penalty P1 ==");
    print!("{:<10}", "block");
    for p1 in P1S {
        print!(" {:>12}", format!("P1={p1}"));
    }
    println!();
    for block in BLOCKS {
        print!("{:<10}", fmt_bytes(block));
        for p1 in P1S {
            let p99 = bench_one(p1, block).unwrap();
            print!(" {:>12}", fmt_ns(p99));
        }
        println!();
    }
    println!("\nexpected shape: large P1 -> single-rail latency at big blocks;");
    println!("tiny P1 -> overuse of tier-2; best around P1=3 (the default).");
}
