//! Figure 10 — instantaneous throughput under rail failure + recovery,
//! plus the Table 1 failure-mix generator (`--table1` style section).
//!
//! Script: continuous 64 MB transfers; NIC 0 hard-fails at t = 1000 ms and
//! recovers at t = 3000 ms. Paper expectations: a throughput dip shorter
//! than 50 ms at failure, a degraded-but-stable plateau, re-admission
//! within ~26 ms of recovery, and no application-visible error.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferReq};
use tent::fabric::trace::{FailureEvent, TraceGenerator};
use tent::segment::Location;
use tent::topology::{FabricKind, NodeId};
use tent::util::clock;

fn main() {
    println!("== Figure 10: throughput timeline under rail failure/recovery ==");
    let cluster = Cluster::from_profile("h800_hgx").unwrap();
    let cfg = EngineConfig {
        probe_interval: Duration::from_millis(10),
        ..Default::default()
    };
    let engine = Arc::new(TentEngine::new(&cluster, cfg).unwrap());

    let len = 64u64 << 20;
    let src = engine.register_segment(Location::host(0, 0), len).unwrap();
    let dst = engine.register_segment(Location::host(1, 0), len).unwrap();
    let rail = cluster.topo.rails_of(NodeId(0), FabricKind::Rdma)[0];

    let stop = Arc::new(AtomicBool::new(false));
    // Sample completed bytes in 25 ms windows on a separate thread.
    let sampler = {
        let engine = Arc::clone(&engine);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut series: Vec<(u64, u64)> = Vec::new();
            let t0 = clock::now_ns();
            let mut last_bytes = 0u64;
            while !stop.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(25));
                let bytes: u64 = engine
                    .rail_snapshots()
                    .iter()
                    .map(|r| r.bytes_carried)
                    .sum();
                let t_ms = (clock::now_ns() - t0) / 1_000_000;
                series.push((t_ms, (bytes - last_bytes) * 40)); // bytes/s
                last_bytes = bytes;
            }
            series
        })
    };

    // Fault injection script.
    let injector = {
        let fabric = Arc::clone(&cluster.fabric);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(1000));
            fabric.inject_failure(rail);
            std::thread::sleep(Duration::from_millis(2000));
            fabric.recover(rail);
        })
    };

    // Continuous 64 MiB transfers for 4 s; the API must never error.
    let t_start = clock::now_ns();
    let mut transfer_failures = 0;
    while clock::now_ns() - t_start < 4_000_000_000 {
        if engine
            .transfer_sync(TransferReq::write(src, 0, dst, 0, len), Duration::from_secs(30))
            .is_err()
        {
            transfer_failures += 1;
        }
    }
    injector.join().unwrap();
    stop.store(true, Ordering::Release);
    let series = sampler.join().unwrap();

    println!("\n t(ms)   goodput        (fail @1000ms, recover @3000ms)");
    let peak = series.iter().map(|&(_, b)| b).max().unwrap_or(1).max(1);
    for (t, bps) in &series {
        let bar = "#".repeat((bps * 40 / peak) as usize);
        println!("{t:>6}   {:>12} {bar}", tent::util::fmt_bw(*bps as f64));
    }

    // Quantify the dip + recovery.
    let healthy: Vec<u64> = series
        .iter()
        .filter(|&&(t, _)| (400..950).contains(&t))
        .map(|&(_, b)| b)
        .collect();
    let healthy_avg = healthy.iter().sum::<u64>() / healthy.len().max(1) as u64;
    let dip_windows = series
        .iter()
        .filter(|&&(t, b)| (1000..3000).contains(&t) && b < healthy_avg / 3)
        .count();
    let recover_at = series
        .iter()
        .filter(|&&(t, b)| t >= 3000 && b >= healthy_avg * 9 / 10)
        .map(|&(t, _)| t)
        .next();

    let s = engine.stats();
    println!("\napplication-visible transfer failures: {transfer_failures}");
    println!(
        "deep-dip windows during outage (25 ms each): {dip_windows}  (paper: dip < 50 ms)"
    );
    if let Some(t) = recover_at {
        println!("throughput back to >=90% of healthy at t={t} ms (recovery at 3000 ms)");
    }
    println!(
        "engine: retries={} exclusions={} probes={} readmissions={}",
        s.retries, s.exclusions, s.probes, s.readmissions
    );
    assert_eq!(transfer_failures, 0, "failures must be masked in-band");

    // ---- Table 1 companion: the failure-mix driving resilience tests ----
    println!("\n== Table 1: sampled datacenter failure mix (100k events) ==");
    let mut gen = TraceGenerator::new(42);
    let mut counts = std::collections::HashMap::new();
    for _ in 0..100_000 {
        *counts.entry(gen.sample_event().name()).or_insert(0u32) += 1;
    }
    println!("{:<42} {:>7} {:>8}", "Failure Event", "paper%", "sampled%");
    for (e, pct) in FailureEvent::TABLE1 {
        let got = *counts.get(e.name()).unwrap_or(&0) as f64 / 1000.0;
        println!("{:<42} {:>6.1}% {:>7.2}%", e.name(), pct, got);
    }
}
