//! **End-to-end driver**: serve multi-turn LLM conversations with the full
//! three-layer stack —
//!
//!   L1/L2 a pluggable model executor: the deterministic synthetic model
//!         (default, no artifacts needed) or the AOT-compiled TinyGPT via
//!         PJRT (`--model pjrt`, requires `make artifacts`)
//!   L3    TENT moving KV-cache blocks between GPU / CPU / SSD tiers
//!
//! and report the Table-2 metrics (input throughput, avg/P90 TTFT,
//! per-round TTFT) for three configurations: no-HiCache baseline,
//! HiCache + Mooncake TE, and HiCache + TENT.
//!
//! Run:
//!   `cargo run --release --example kvcache_serving [-- --clients 6 --turns 4]`

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::log;
use tent::policy::PolicyKind;
use tent::runtime::{make_executor, ModelExecutor, ModelSelect};
use tent::serving::{build_for, run_serving, ServeConfig, ServeMode, ServeReport};
use tent::util::cli::Args;
use tent::util::TempPool;

fn run_config(
    model: &dyn ModelExecutor,
    policy: PolicyKind,
    cfg: &ServeConfig,
) -> tent::Result<ServeReport> {
    // Fresh cluster per configuration so cache state never leaks across runs.
    let cluster = Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let convs = build_for(model.meta(), cfg);
    run_serving(&engine, model, &convs, cfg)
}

fn main() -> tent::Result<()> {
    tent::util::logging::init(log::Level::Warn);
    let args = Args::from_env();
    let base_cfg = ServeConfig {
        clients: args.get_usize("clients", 6),
        turns: args.get_usize("turns", 4),
        decode_tokens: args.get_usize("decode", 2),
        seed: args.get_u64("seed", 7),
        model: ModelSelect::parse(&args.get_str("model", "auto"))
            .ok_or_else(|| tent::Error::Config("unknown --model (synthetic|pjrt|auto)".into()))?,
        ..Default::default()
    };
    let turns = base_cfg.turns;
    // The config is the single source of truth for executor selection.
    let model = make_executor(base_cfg.model)?;
    let meta = model.meta();
    println!(
        "model: {} ({} params, KV {}/request, {} tok/block)",
        model.name(),
        meta.param_count,
        tent::util::fmt_bytes(meta.kv_bytes),
        meta.t_pre
    );

    let configs = [
        ("Baseline (no HiCache)", PolicyKind::Tent, ServeMode::Baseline),
        ("HiCache + Mooncake TE", PolicyKind::MooncakeTe, ServeMode::HiCache),
        ("HiCache + TENT", PolicyKind::Tent, ServeMode::HiCache),
    ];

    let mut reports = Vec::new();
    for (label, policy, mode) in configs {
        println!("\n=== {label} ===");
        // Per-run disk pool, removed on drop even if a run errors.
        let pool = TempPool::new("ex_kv");
        let mut cfg = ServeConfig { mode, ..base_cfg.clone() };
        cfg.cache.disk_path = pool.path();
        let rep = run_config(model.as_ref(), policy, &cfg)?;
        println!(
            "  input throughput {:>8.0} tok/s | avg TTFT {:.3}s | P90 TTFT {:.3}s",
            rep.input_throughput_tok_s(),
            rep.avg_ttft_s(),
            rep.p90_ttft_s()
        );
        for r in 1..=turns {
            println!("  round {r}: avg TTFT {:.3}s", rep.round_avg_ttft_s(r));
        }
        reports.push((label, rep));
    }

    // Table 2 shape check.
    println!("\n=== summary (Table 2 shape) ===");
    println!(
        "{:<24} {:>12} {:>10} {:>10}",
        "config", "tok/s", "avgTTFT", "p90TTFT"
    );
    for (label, rep) in &reports {
        println!(
            "{:<24} {:>12.0} {:>9.3}s {:>9.3}s",
            label,
            rep.input_throughput_tok_s(),
            rep.avg_ttft_s(),
            rep.p90_ttft_s()
        );
    }
    let (_, base) = &reports[0];
    let (_, te) = &reports[1];
    let (_, tent_r) = &reports[2];
    println!(
        "\nTENT vs baseline: {:.2}x throughput | TENT vs TE: {:.2}x throughput, {:.1}% lower P90 TTFT",
        tent_r.input_throughput_tok_s() / base.input_throughput_tok_s(),
        tent_r.input_throughput_tok_s() / te.input_throughput_tok_s(),
        (1.0 - tent_r.p90_ttft_s() / te.p90_ttft_s()) * 100.0
    );
    Ok(())
}
