//! Self-healing demo (§4.3 / Fig. 10): continuous transfers survive a NIC
//! hard-failure with no application-side error handling, and the rail is
//! re-admitted within tens of milliseconds of recovery.
//!
//! Run: `cargo run --release --example failover_demo`

use std::sync::Arc;
use std::time::{Duration, Instant};
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferReq};
use tent::log;
use tent::segment::Location;
use tent::topology::{FabricKind, NodeId};

fn main() -> tent::Result<()> {
    tent::util::logging::init(log::Level::Info);
    let cluster = Cluster::from_profile("h800_hgx")?;
    let cfg = EngineConfig {
        probe_interval: Duration::from_millis(10), // Fig 10: fast re-admission
        ..Default::default()
    };
    let engine = Arc::new(TentEngine::new(&cluster, cfg)?);

    let len = 64u64 << 20;
    let src = engine.register_segment(Location::host(0, 0), len)?;
    let dst = engine.register_segment(Location::host(1, 0), len)?;

    // Fail NIC 0 at t=1000 ms, recover at t=3000 ms (the Fig. 10 script).
    let rail = cluster.topo.rails_of(NodeId(0), FabricKind::Rdma)[0];
    let fabric = Arc::clone(&cluster.fabric);
    let injector = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(1000));
        println!(">>> t=1000ms: injecting hard failure on rail 0");
        fabric.inject_failure(rail);
        std::thread::sleep(Duration::from_millis(2000));
        println!(">>> t=3000ms: rail 0 recovered");
        fabric.recover(rail);
    });

    // Continuous 64 MiB transfers; the app never sees a failure.
    let start = Instant::now();
    while start.elapsed() < Duration::from_millis(4000) {
        let t0 = Instant::now();
        engine.transfer_sync(
            TransferReq::write(src, 0, dst, 0, len),
            Duration::from_secs(30),
        )?;
        let dt = t0.elapsed();
        println!(
            "t={:>5}ms  64 MiB in {:>6.1}ms  ({:>7.1} MB/s)",
            start.elapsed().as_millis(),
            dt.as_secs_f64() * 1e3,
            (len as f64 / dt.as_secs_f64()) / 1e6
        );
    }
    injector.join().unwrap();

    let s = engine.stats();
    println!(
        "\nengine events: retries={} exclusions={} probes={} readmissions={} permanent_failures={}",
        s.retries, s.exclusions, s.probes, s.readmissions, s.permanent_failures
    );
    assert_eq!(s.permanent_failures, 0, "the data plane must mask the fault");
    println!("no transfer ever failed at the API — in-band recovery only.");
    Ok(())
}
