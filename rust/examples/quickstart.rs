//! Quickstart: the declarative BatchTransfer API in ~40 lines.
//!
//! Registers segments on two simulated H800 nodes, declares a batch of
//! transfers (intent only — no transport binding), and lets TENT spray
//! slices across the 8-rail RDMA fabric.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use std::time::Duration;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine, TransferReq};
use tent::log;
use tent::segment::Location;

fn main() -> tent::Result<()> {
    tent::util::logging::init(log::Level::Info);

    // A 2-node H800 cluster: 8 GPUs + 8×200 Gbps rails + NVLink per node.
    let cluster = Cluster::from_profile("h800_hgx")?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::default())?);

    // Declare *where data lives*, not how it moves.
    let len = 16u64 << 20;
    let src = engine.register_segment(Location::host(0, 0), len)?;
    let dst = engine.register_segment(Location::host(1, 0), len)?;
    let gpu_src = engine.register_segment(Location::device(0, 0), len)?;
    let gpu_dst = engine.register_segment(Location::device(0, 5), len)?;

    // Fill the sources with a pattern.
    let pattern: Vec<u8> = (0..len as usize).map(|i| (i % 251) as u8).collect();
    engine.segment(src)?.write_at(0, &pattern)?;
    engine.segment(gpu_src)?.write_at(0, &pattern)?;

    // One batch, two elephant flows: host→host inter-node (sprayed over the
    // RDMA rails) and GPU→GPU intra-node (NVLink, first-class).
    let batch = engine.allocate_batch();
    engine.submit(
        batch,
        &[
            TransferReq::write(src, 0, dst, 0, len),
            TransferReq::write(gpu_src, 0, gpu_dst, 0, len),
        ],
    )?;
    let status = engine.wait(batch, Duration::from_secs(60))?;
    println!("batch done: {status:?}");

    // Verify the bytes really moved.
    let mut buf = vec![0u8; len as usize];
    engine.segment(dst)?.read_at(0, &mut buf)?;
    assert_eq!(buf, pattern, "host copy mismatch");
    engine.segment(gpu_dst)?.read_at(0, &mut buf)?;
    assert_eq!(buf, pattern, "gpu copy mismatch");
    println!("payload verified on both destinations");

    // Where did the bytes go? (per-NIC byte counters, §5.1.3)
    println!("\nrail           fabric       bytes");
    for r in engine.rail_snapshots() {
        if r.bytes_carried > 0 {
            println!(
                "{:<14} {:<9} {:>10}",
                r.name,
                r.fabric,
                tent::util::fmt_bytes(r.bytes_carried)
            );
        }
    }
    Ok(())
}
