//! RL-pipeline weight update (Table 3 scenario): push the *real* TinyGPT
//! checkpoint (`artifacts/params.bin`) from trainer host memory to 8
//! inference ranks through the engine's pipelined ring broadcast, install
//! the weights into the PJRT runtime on rank 0, and prove inference still
//! works — comparing Mooncake TE vs TENT end to end.
//!
//! Requires `make artifacts`. Run:
//!   `cargo run --release --example checkpoint_update`

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::log;
use tent::policy::PolicyKind;
use tent::runtime::Runtime;
use tent::serving::{CheckpointConfig, CheckpointEngine};

fn run_update(policy: PolicyKind, payload: &[u8]) -> tent::Result<f64> {
    let cluster = Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes: payload.len() as u64,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )?;
    ce.stage_weights(payload)?;
    let rep = ce.update()?;
    assert!(ce.verify()?, "all ranks must hold the new weights");
    Ok(rep.seconds())
}

fn main() -> tent::Result<()> {
    tent::util::logging::init(log::Level::Warn);
    let dir = tent::runtime::default_artifacts_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!(
            "model runtime unavailable: needs AOT artifacts in {} AND a real PJRT \
             backend (this offline build stubs PJRT — see README \"Model runtime status\")",
            dir.display()
        );
        std::process::exit(2);
    }
    let mut rt = Runtime::load(&dir)?;
    let payload = std::fs::read(dir.join("params.bin"))?;
    println!(
        "checkpoint payload: {} (real TinyGPT weights)",
        tent::util::fmt_bytes(payload.len() as u64)
    );

    let te = run_update(PolicyKind::MooncakeTe, &payload)?;
    let tent_s = run_update(PolicyKind::Tent, &payload)?;
    println!("\nparameter update time (8 ranks, pipelined broadcast):");
    println!("  Mooncake TE : {te:.3}s");
    println!("  TENT        : {tent_s:.3}s   ({:.1}% faster)", (1.0 - tent_s / te) * 100.0);

    // Close the loop: install the broadcast weights into the runtime and
    // run a real forward pass.
    let cluster = Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::default())?);
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes: payload.len() as u64,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )?;
    ce.stage_weights(&payload)?;
    ce.update()?;
    let new_params = ce.rank_params_f32(0)?;
    rt.install_params(&new_params)?;
    let tokens: Vec<i32> = (0..rt.meta.t_pre as i32).collect();
    let (tok, _) = rt.prefill(&tokens, rt.empty_kv()?, 0)?;
    println!("\nrank-0 inference after in-place update: next token = {tok} — OK");
    Ok(())
}
