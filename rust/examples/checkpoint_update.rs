//! RL-pipeline weight update (Table 3 scenario): push a full checkpoint
//! from trainer host memory to 8 inference ranks through the engine's
//! pipelined ring broadcast, install the weights into a model executor on
//! rank 0, and prove inference still works — comparing Mooncake TE vs TENT
//! end to end.
//!
//! The payload is the real TinyGPT checkpoint (`artifacts/params.bin`) when
//! the AOT artifacts exist, otherwise a deterministic synthetic checkpoint
//! of exactly the executor's `param_count` — either way the bytes really
//! ride the engine and really land in the model. Run:
//!   `cargo run --release --example checkpoint_update`

use std::sync::Arc;
use tent::cluster::Cluster;
use tent::engine::{EngineConfig, TentEngine};
use tent::log;
use tent::policy::PolicyKind;
use tent::runtime::{make_executor, ModelSelect};
use tent::serving::{CheckpointConfig, CheckpointEngine};

fn run_update(policy: PolicyKind, payload: &[u8]) -> tent::Result<f64> {
    let cluster = Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes: payload.len() as u64,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )?;
    ce.stage_weights(payload)?;
    let rep = ce.update()?;
    assert!(ce.verify()?, "all ranks must hold the new weights");
    Ok(rep.seconds())
}

fn main() -> tent::Result<()> {
    tent::util::logging::init(log::Level::Warn);
    let mut model = make_executor(ModelSelect::Auto)?;
    let dir = tent::runtime::default_artifacts_dir();
    let payload = if model.name() == "pjrt" {
        std::fs::read(dir.join("params.bin"))?
    } else {
        // Deterministic synthetic checkpoint: the executor's full flat
        // param vector as little-endian f32 bytes.
        let mut out = Vec::with_capacity(model.meta().param_count * 4);
        for i in 0..model.meta().param_count {
            out.extend_from_slice(&(i as f32 * 1e-6).to_le_bytes());
        }
        out
    };
    println!(
        "checkpoint payload: {} ({} weights)",
        tent::util::fmt_bytes(payload.len() as u64),
        model.name()
    );

    let te = run_update(PolicyKind::MooncakeTe, &payload)?;
    let tent_s = run_update(PolicyKind::Tent, &payload)?;
    println!("\nparameter update time (8 ranks, pipelined broadcast):");
    println!("  Mooncake TE : {te:.3}s");
    println!("  TENT        : {tent_s:.3}s   ({:.1}% faster)", (1.0 - tent_s / te) * 100.0);

    // Close the loop: install the broadcast weights into the executor and
    // run a forward pass.
    let cluster = Cluster::from_profile_nodes("h800_hgx", 1, tent::fabric::FabricConfig::default())?;
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::default())?);
    let ce = CheckpointEngine::new(
        Arc::clone(&engine),
        CheckpointConfig {
            payload_bytes: payload.len() as u64,
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        },
    )?;
    ce.stage_weights(&payload)?;
    ce.update()?;
    ce.install_into(0, model.as_mut())?;
    let tokens: Vec<i32> = (0..model.meta().t_pre as i32).collect();
    let (tok, _) = model.prefill(&tokens, model.empty_kv()?, 0)?;
    println!("\nrank-0 inference after in-place update: next token = {tok} — OK");
    Ok(())
}
