//! The segment abstraction (§3.1): a unified, transport-agnostic way to name
//! data wherever it lives — host DRAM, accelerator HBM, or persistent
//! storage.
//!
//! Applications interact exclusively with `(SegmentId, offset, len)` triples;
//! device-specific metadata (the sim analogue of RDMA rkeys / GPU memory
//! handles / fds) is encapsulated inside the segment and opaque to the core
//! engine — only backends look at it.

use crate::topology::NodeId;
use crate::{Error, Result};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Unique id of a registered segment.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct SegmentId(pub u64);

impl std::fmt::Display for SegmentId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "seg{}", self.0)
    }
}

/// Where a segment's bytes physically live.
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum Location {
    /// Host DRAM on `node`, NUMA domain `numa`.
    Host { node: NodeId, numa: u8 },
    /// Accelerator memory (sim HBM) on `node`, device `gpu`.
    Device { node: NodeId, gpu: u8 },
    /// A file on `node`'s local SSD.
    Storage { node: NodeId, path: PathBuf },
}

impl Location {
    pub fn host(node: u16, numa: u8) -> Location {
        Location::Host {
            node: NodeId(node),
            numa,
        }
    }
    pub fn device(node: u16, gpu: u8) -> Location {
        Location::Device {
            node: NodeId(node),
            gpu,
        }
    }
    pub fn storage(node: u16, path: impl Into<PathBuf>) -> Location {
        Location::Storage {
            node: NodeId(node),
            path: path.into(),
        }
    }

    pub fn node(&self) -> NodeId {
        match self {
            Location::Host { node, .. }
            | Location::Device { node, .. }
            | Location::Storage { node, .. } => *node,
        }
    }

    /// NUMA affinity of the location (GPUs: their root's socket).
    pub fn numa(&self) -> u8 {
        match self {
            Location::Host { numa, .. } => *numa,
            Location::Device { gpu, .. } => gpu / 4,
            Location::Storage { .. } => 0,
        }
    }

    /// PCIe root complex, if the location is behind one.
    pub fn pcie_root(&self) -> Option<u8> {
        match self {
            Location::Device { gpu, .. } => Some(*gpu),
            _ => None,
        }
    }

    pub fn is_device(&self) -> bool {
        matches!(self, Location::Device { .. })
    }
    pub fn is_storage(&self) -> bool {
        matches!(self, Location::Storage { .. })
    }
}

/// The physical backing of a segment.
pub enum Backing {
    /// Heap memory we own (simulated DRAM or HBM). Accessed by raw pointer
    /// from rail workers — the engine, like RDMA hardware, performs
    /// one-sided reads/writes without synchronizing overlapping app access.
    Memory(MemRegion),
    /// A real file, accessed with positional I/O (io_uring analogue).
    File(File),
}

/// Raw owned memory region, shareable across worker threads.
pub struct MemRegion {
    ptr: *mut u8,
    len: usize,
    layout: std::alloc::Layout,
}

unsafe impl Send for MemRegion {}
unsafe impl Sync for MemRegion {}

impl MemRegion {
    pub fn alloc(len: usize) -> MemRegion {
        let layout = std::alloc::Layout::from_size_align(len.max(1), 64).unwrap();
        let ptr = unsafe { std::alloc::alloc_zeroed(layout) };
        assert!(!ptr.is_null(), "allocation of {len} bytes failed");
        MemRegion { ptr, len, layout }
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    /// Raw base pointer — used by backends for one-sided copies.
    #[inline]
    pub fn as_ptr(&self) -> *mut u8 {
        self.ptr
    }
}

impl Drop for MemRegion {
    fn drop(&mut self) {
        unsafe { std::alloc::dealloc(self.ptr, self.layout) };
    }
}

/// Transport-opaque per-segment metadata (§3.1 "Building Segment Metadata").
/// The sim analogue of rkeys / dmabuf handles / fds; backends downcast by
/// field, the core engine never reads it.
#[derive(Clone, Debug, Default)]
pub struct TransportMeta {
    /// Sim-RDMA "rkey" (existence = memory is registered with the RNIC).
    pub rdma_rkey: Option<u64>,
    /// Sim GPU memory handle (existence = P2P-mappable).
    pub gpu_handle: Option<u64>,
    /// File descriptor number for storage segments.
    pub fd: Option<i32>,
}

/// A registered segment.
pub struct Segment {
    pub id: SegmentId,
    pub loc: Location,
    pub len: u64,
    pub backing: Backing,
    pub meta: TransportMeta,
}

impl Segment {
    /// Bounds-check an access.
    pub fn check(&self, off: u64, len: u64) -> Result<()> {
        if off.checked_add(len).map(|end| end <= self.len) != Some(true) {
            return Err(Error::OutOfBounds(format!(
                "{}: off={off} len={len} seg_len={}",
                self.id, self.len
            )));
        }
        Ok(())
    }

    /// Read bytes into `dst`. For memory segments this is a raw copy
    /// (one-sided semantics); for storage it is positional file I/O.
    pub fn read_at(&self, off: u64, dst: &mut [u8]) -> Result<()> {
        self.check(off, dst.len() as u64)?;
        match &self.backing {
            Backing::Memory(m) => {
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        m.as_ptr().add(off as usize),
                        dst.as_mut_ptr(),
                        dst.len(),
                    );
                }
                Ok(())
            }
            Backing::File(f) => {
                f.read_exact_at(dst, off)?;
                Ok(())
            }
        }
    }

    /// Write bytes from `src` at `off` (one-sided; absolute destination
    /// offset, so retried slices are idempotent — §4.3).
    pub fn write_at(&self, off: u64, src: &[u8]) -> Result<()> {
        self.check(off, src.len() as u64)?;
        match &self.backing {
            Backing::Memory(m) => {
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src.as_ptr(),
                        m.as_ptr().add(off as usize),
                        src.len(),
                    );
                }
                Ok(())
            }
            Backing::File(f) => {
                f.write_all_at(src, off)?;
                Ok(())
            }
        }
    }

    /// Direct memory-to-memory copy between two memory segments (zero
    /// intermediate buffer). Errors if either side is a file.
    pub fn copy_mem_to_mem(
        src: &Segment,
        src_off: u64,
        dst: &Segment,
        dst_off: u64,
        len: u64,
    ) -> Result<()> {
        src.check(src_off, len)?;
        dst.check(dst_off, len)?;
        match (&src.backing, &dst.backing) {
            (Backing::Memory(s), Backing::Memory(d)) => {
                unsafe {
                    // May overlap if src==dst with overlapping ranges; use memmove.
                    std::ptr::copy(
                        s.as_ptr().add(src_off as usize),
                        d.as_ptr().add(dst_off as usize),
                        len as usize,
                    );
                }
                Ok(())
            }
            _ => Err(Error::TransferFailed(
                "copy_mem_to_mem on non-memory segment".into(),
            )),
        }
    }
}

/// The segment manager: registry + metadata authority (§3.1).
pub struct SegmentManager {
    next_id: AtomicU64,
    segments: RwLock<HashMap<SegmentId, Arc<Segment>>>,
}

impl Default for SegmentManager {
    fn default() -> Self {
        Self::new()
    }
}

impl SegmentManager {
    pub fn new() -> Self {
        SegmentManager {
            next_id: AtomicU64::new(1),
            segments: RwLock::new(HashMap::new()),
        }
    }

    /// Register a memory segment (host or device); allocates backing.
    pub fn register_memory(&self, loc: Location, len: u64) -> Result<Arc<Segment>> {
        if loc.is_storage() {
            return Err(Error::Config(
                "use register_file for storage locations".into(),
            ));
        }
        let id = SegmentId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let meta = TransportMeta {
            rdma_rkey: Some(0x7000_0000 + id.0),
            gpu_handle: loc.is_device().then(|| 0x6000_0000 + id.0),
            fd: None,
        };
        let seg = Arc::new(Segment {
            id,
            loc,
            len,
            backing: Backing::Memory(MemRegion::alloc(len as usize)),
            meta,
        });
        self.segments.write().unwrap().insert(id, Arc::clone(&seg));
        Ok(seg)
    }

    /// Register a file-backed segment (created/truncated to `len`).
    pub fn register_file(&self, loc: Location, len: u64) -> Result<Arc<Segment>> {
        let path = match &loc {
            Location::Storage { path, .. } => path.clone(),
            _ => return Err(Error::Config("register_file needs Storage location".into())),
        };
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)?;
        f.set_len(len)?;
        let id = SegmentId(self.next_id.fetch_add(1, Ordering::Relaxed));
        use std::os::unix::io::AsRawFd;
        let fd = f.as_raw_fd();
        let seg = Arc::new(Segment {
            id,
            loc,
            len,
            backing: Backing::File(f),
            meta: TransportMeta {
                rdma_rkey: None,
                gpu_handle: None,
                fd: Some(fd),
            },
        });
        self.segments.write().unwrap().insert(id, Arc::clone(&seg));
        Ok(seg)
    }

    pub fn get(&self, id: SegmentId) -> Result<Arc<Segment>> {
        self.segments
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or(Error::UnknownSegment(id.0))
    }

    pub fn unregister(&self, id: SegmentId) -> Result<()> {
        self.segments
            .write()
            .unwrap()
            .remove(&id)
            .map(|_| ())
            .ok_or(Error::UnknownSegment(id.0))
    }

    pub fn count(&self) -> usize {
        self.segments.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> SegmentManager {
        SegmentManager::new()
    }

    #[test]
    fn register_and_rw_host_segment() {
        let m = mgr();
        let s = m.register_memory(Location::host(0, 0), 4096).unwrap();
        s.write_at(100, b"hello").unwrap();
        let mut buf = [0u8; 5];
        s.read_at(100, &mut buf).unwrap();
        assert_eq!(&buf, b"hello");
    }

    #[test]
    fn device_segment_has_gpu_handle() {
        let m = mgr();
        let s = m.register_memory(Location::device(0, 3), 1024).unwrap();
        assert!(s.meta.gpu_handle.is_some());
        assert!(s.meta.rdma_rkey.is_some());
        assert_eq!(s.loc.pcie_root(), Some(3));
        assert_eq!(s.loc.numa(), 0);
    }

    #[test]
    fn bounds_are_enforced() {
        let m = mgr();
        let s = m.register_memory(Location::host(0, 0), 100).unwrap();
        assert!(s.check(90, 10).is_ok());
        assert!(s.check(90, 11).is_err());
        assert!(s.check(u64::MAX, 2).is_err()); // overflow
        let mut buf = [0u8; 32];
        assert!(s.read_at(80, &mut buf).is_err());
    }

    #[test]
    fn file_segment_roundtrip() {
        let m = mgr();
        let path = std::env::temp_dir().join(format!("tent_seg_test_{}", std::process::id()));
        let s = m
            .register_file(Location::storage(0, path.clone()), 8192)
            .unwrap();
        s.write_at(4000, b"persist").unwrap();
        let mut buf = [0u8; 7];
        s.read_at(4000, &mut buf).unwrap();
        assert_eq!(&buf, b"persist");
        assert!(s.meta.fd.is_some());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn mem_to_mem_copy() {
        let m = mgr();
        let a = m.register_memory(Location::host(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::device(0, 1), 1024).unwrap();
        a.write_at(0, &[7u8; 512]).unwrap();
        Segment::copy_mem_to_mem(&a, 0, &b, 256, 512).unwrap();
        let mut buf = [0u8; 512];
        b.read_at(256, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 7));
    }

    #[test]
    fn lookup_and_unregister() {
        let m = mgr();
        let s = m.register_memory(Location::host(1, 1), 64).unwrap();
        assert_eq!(m.get(s.id).unwrap().id, s.id);
        assert_eq!(m.count(), 1);
        m.unregister(s.id).unwrap();
        assert!(m.get(s.id).is_err());
        assert!(m.unregister(s.id).is_err());
    }

    #[test]
    fn zeroed_on_alloc() {
        let m = mgr();
        let s = m.register_memory(Location::host(0, 0), 4096).unwrap();
        let mut buf = vec![1u8; 4096];
        s.read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 0));
    }
}
