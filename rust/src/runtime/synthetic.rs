//! Deterministic, artifact-free model executor.
//!
//! [`SyntheticModel`] implements [`ModelExecutor`] without any forward
//! pass, AOT artifacts, or PJRT backend — which is what lets the entire
//! HiCache serving stack (router, tiered KV cache, checkpoint install,
//! Table-2 driver) run inside tier-1. RAPID-LLM-style reasoning applies:
//! the data-movement behaviour under study (KV-tier movement ratios,
//! cache-hit semantics, TTFT deltas between transfer policies) depends on
//! the transfer engine, not on real logits. What the serving layer *does*
//! need from a model is provided exactly:
//!
//! * **Bit-reproducible KV bytes.** A prefill chunk's KV content is a pure
//!   function of (chunk tokens, chunk position, installed params): a PRNG
//!   stream seeded from the FNV-1a hash of those inputs fills the chunk's
//!   rows across all `2·L·H` planes of the working `[L, 2, H, T, D]`
//!   layout. Recomputing a chunk therefore produces byte-identical cache
//!   blocks to refetching it from any tier — the invariant every cache
//!   roundtrip/transparency test asserts.
//! * **KV-dependent predictions.** The next token hashes a strided sample
//!   of the valid KV prefix (every plane, every 13th row), so continuing
//!   from a cache-fetched KV state predicts identically to continuing from
//!   a recomputed one, and a checkpoint update (new `params` digest)
//!   changes the prediction function deterministically.
//! * **Analytical compute delays.** Prefill/decode pace wall-clock by a
//!   FLOPs model over `ModelMeta` (`2·param_count` MACs per token plus a
//!   `4·L·H·D·position` attention-context term) against a configurable
//!   synthetic accelerator rate, so TTFT comparisons (HiCache fetch vs
//!   baseline recompute) remain meaningful at the fabric's 1:100 sim
//!   scale.

use super::{DecodeStep, KvCache, ModelExecutor, ModelMeta, PrefillStep};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Synthetic-executor knobs.
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    /// Synthetic accelerator rate in FLOP/s, in the fabric's 1:100 sim
    /// units (default 1e11 ≈ 10 TFLOPS paper-scale — deliberately the
    /// per-request share of an accelerator under continuous batching, not
    /// peak, so TinyGPT-sized chunks keep the paper's compute:movement
    /// ratio: one 128-token prefill chunk ≈ 11 ms vs ≈ 1–3 ms to fetch its
    /// 1 MiB block over the simulated NVLink/PCIe tiers).
    pub gpu_flops: f64,
    /// Fixed per-call launch overhead (ns).
    pub launch_overhead_ns: u64,
    /// Pace calls by the FLOPs model. Disable for property tests that only
    /// need cache/prediction semantics, not timing.
    pub pace: bool,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            gpu_flops: 1e11,
            launch_overhead_ns: 20_000,
            pace: true,
        }
    }
}

/// FNV-1a over a byte slice, chained from `h`.
fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// The deterministic executor. See the module docs for the contract.
pub struct SyntheticModel {
    pub meta: ModelMeta,
    cfg: SyntheticConfig,
    /// FNV digest of the installed flat param vector's f32 bit patterns —
    /// the only state a weight update needs to perturb predictions.
    params_digest: AtomicU64,
}

impl Default for SyntheticModel {
    fn default() -> Self {
        SyntheticModel::new(ModelMeta::tiny_gpt(), SyntheticConfig::default())
    }
}

impl SyntheticModel {
    pub fn new(meta: ModelMeta, cfg: SyntheticConfig) -> SyntheticModel {
        SyntheticModel {
            meta,
            cfg,
            params_digest: AtomicU64::new(FNV_OFFSET),
        }
    }

    /// TinyGPT-shaped model with pacing disabled — for tests that assert
    /// semantics (determinism, cache bytes) and shouldn't burn wall-clock.
    pub fn unpaced() -> SyntheticModel {
        SyntheticModel::new(
            ModelMeta::tiny_gpt(),
            SyntheticConfig {
                pace: false,
                ..SyntheticConfig::default()
            },
        )
    }

    fn planes(&self) -> usize {
        self.meta.layers * 2 * self.meta.heads
    }

    fn host_kv(&self, kv: KvCache) -> Result<Vec<u8>> {
        match kv {
            KvCache::Host(raw) if raw.len() as u64 == self.meta.kv_bytes => Ok(raw),
            KvCache::Host(raw) => Err(Error::Config(format!(
                "kv bytes {} != expected {}",
                raw.len(),
                self.meta.kv_bytes
            ))),
            KvCache::Literal(_) => Err(Error::Runtime(
                "KV state was produced by a different executor (literal, not host bytes)".into(),
            )),
        }
    }

    /// Fill rows `[row, row + rows)` of every plane with the PRNG stream
    /// derived from `seed` (plane index selects the stream). Every byte of
    /// the region is written — including a sub-8-byte tail when `head_dim`
    /// isn't even — so the recompute == refetch contract holds for any
    /// `ModelMeta`, not just the built-in one.
    fn fill_rows(&self, kv: &mut [u8], seed: u64, row: usize, rows: usize) {
        let d4 = self.meta.head_dim * 4;
        let plane_len = self.meta.t_max * d4;
        for plane in 0..self.planes() {
            let start = plane * plane_len + row * d4;
            let mut rng = Pcg64::new(seed, plane as u64);
            let mut words = kv[start..start + rows * d4].chunks_exact_mut(8);
            for w in words.by_ref() {
                w.copy_from_slice(&rng.next_u64().to_le_bytes());
            }
            let tail = words.into_remainder();
            if !tail.is_empty() {
                let last = rng.next_u64().to_le_bytes();
                tail.copy_from_slice(&last[..tail.len()]);
            }
        }
    }

    /// Next-token prediction: hash the call inputs plus a strided sample of
    /// the valid KV prefix (every plane, every 13th row), so the prediction
    /// depends on cache *content* — a byte-exact tier refetch continues
    /// identically to a recompute, and a corrupted fetch would not.
    fn predict(&self, kv: &[u8], seq_len: usize, call_digest: u64) -> i32 {
        let d4 = self.meta.head_dim * 4;
        let plane_len = self.meta.t_max * d4;
        let mut h = call_digest ^ self.params_digest.load(Ordering::Relaxed);
        for plane in 0..self.planes() {
            let base = plane * plane_len;
            for t in (0..seq_len).step_by(13) {
                let off = base + t * d4;
                let end = (off + 8).min(kv.len());
                h = fnv(h, &kv[off..end]);
            }
        }
        (h % self.meta.vocab as u64) as i32
    }

    /// Analytical FLOPs for `count` tokens starting at absolute position
    /// `offset`: `2·param_count` MACs per token through the weights plus an
    /// attention-context term linear in the attended prefix length.
    fn flops(&self, offset: usize, count: usize) -> f64 {
        let weights = 2.0 * self.meta.param_count as f64 * count as f64;
        let attn_coef = 4.0 * (self.meta.layers * self.meta.heads * self.meta.head_dim) as f64;
        // sum of positions offset .. offset+count
        let sum_pos = count as f64 * (2 * offset + count - 1) as f64 / 2.0;
        weights + attn_coef * sum_pos
    }

    /// Modeled wall-clock for one kernel launch covering `flops` work:
    /// fixed launch overhead plus compute time at the synthetic rate.
    fn modeled_ns(&self, flops: f64) -> u64 {
        (self.cfg.launch_overhead_ns as f64 + flops / self.cfg.gpu_flops.max(1.0) * 1e9) as u64
    }

    fn pace(&self, flops: f64) {
        if !self.cfg.pace {
            return;
        }
        clock::sleep_ns(self.modeled_ns(flops));
    }

    /// Prefill semantics without pacing; returns the FLOPs of the chunk so
    /// batch callers can amortize one launch over many chunks.
    fn prefill_unpaced(&self, tokens: &[i32], kv: KvCache, offset: i32) -> Result<(i32, KvCache, f64)> {
        let t_pre = self.meta.t_pre;
        if tokens.len() != t_pre {
            return Err(Error::Config(format!(
                "prefill needs {} tokens, got {}",
                t_pre,
                tokens.len()
            )));
        }
        let offset = offset as usize;
        if offset % t_pre != 0 || offset + t_pre > self.meta.t_max {
            return Err(Error::Config(format!(
                "prefill offset {offset} not a chunk boundary within t_max {}",
                self.meta.t_max
            )));
        }
        let mut raw = self.host_kv(kv)?;
        // Chunk KV bytes = f(chunk tokens, chunk position, params) only —
        // independent of surrounding KV content, so recompute == refetch.
        let mut seed = self.params_digest.load(Ordering::Relaxed) ^ (offset as u64).rotate_left(32);
        for t in tokens {
            seed = fnv(seed, &t.to_le_bytes());
        }
        self.fill_rows(&mut raw, seed, offset, t_pre);
        let next = self.predict(&raw, offset + t_pre, seed.rotate_left(7));
        Ok((next, KvCache::Host(raw), self.flops(offset, t_pre)))
    }

    /// Decode semantics without pacing; returns the step's FLOPs.
    fn decode_unpaced(&self, token: i32, kv: KvCache, pos: i32) -> Result<(i32, KvCache, f64)> {
        let pos = pos as usize;
        if pos >= self.meta.t_max {
            return Err(Error::Config(format!(
                "decode position {pos} past t_max {}",
                self.meta.t_max
            )));
        }
        let mut raw = self.host_kv(kv)?;
        let mut seed = self.params_digest.load(Ordering::Relaxed) ^ (pos as u64).rotate_left(32);
        seed = fnv(seed, &token.to_le_bytes());
        self.fill_rows(&mut raw, seed, pos, 1);
        let next = self.predict(&raw, pos + 1, seed.rotate_left(7));
        Ok((next, KvCache::Host(raw), self.flops(pos, 1)))
    }
}

impl ModelExecutor for SyntheticModel {
    fn name(&self) -> &'static str {
        "synthetic"
    }

    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn empty_kv(&self) -> Result<KvCache> {
        Ok(KvCache::Host(vec![0u8; self.meta.kv_bytes as usize]))
    }

    fn kv_from_bytes(&self, raw: &[u8]) -> Result<KvCache> {
        if raw.len() as u64 != self.meta.kv_bytes {
            return Err(Error::Config(format!(
                "kv bytes {} != expected {}",
                raw.len(),
                self.meta.kv_bytes
            )));
        }
        Ok(KvCache::Host(raw.to_vec()))
    }

    fn prefill(&self, tokens: &[i32], kv: KvCache, offset: i32) -> Result<(i32, KvCache)> {
        let (next, kv, flops) = self.prefill_unpaced(tokens, kv, offset)?;
        self.pace(flops);
        Ok((next, kv))
    }

    fn decode(&self, token: i32, kv: KvCache, pos: i32) -> Result<(i32, KvCache)> {
        let (next, kv, flops) = self.decode_unpaced(token, kv, pos)?;
        self.pace(flops);
        Ok((next, kv))
    }

    /// Batched prefill: one kernel launch amortized over every chunk in the
    /// iteration (compute-bound, so FLOPs still sum across chunks).
    fn prefill_batch(&self, steps: Vec<PrefillStep<'_>>) -> Result<(Vec<(i32, KvCache)>, u64)> {
        if steps.is_empty() {
            return Ok((Vec::new(), 0));
        }
        let mut out = Vec::with_capacity(steps.len());
        let mut flops = 0.0;
        for s in steps {
            let (next, kv, f) = self.prefill_unpaced(s.tokens, s.kv, s.offset)?;
            flops += f;
            out.push((next, kv));
        }
        let ns = self.modeled_ns(flops);
        if self.cfg.pace {
            clock::sleep_ns(ns);
        }
        Ok((out, ns))
    }

    /// Batched decode: one launch, one shared weight pass (`2·param_count`
    /// MACs — decode is memory-bound on the weight stream, so batching
    /// reads the weights once for the whole batch), plus each request's own
    /// attention-context term. This is the continuous-batching throughput
    /// win the router's virtual clock measures.
    fn decode_batch(&self, steps: Vec<DecodeStep>) -> Result<(Vec<(i32, KvCache)>, u64)> {
        if steps.is_empty() {
            return Ok((Vec::new(), 0));
        }
        let weight_pass = 2.0 * self.meta.param_count as f64;
        let mut out = Vec::with_capacity(steps.len());
        let mut attn = 0.0;
        for s in steps {
            let (next, kv, f) = self.decode_unpaced(s.token, s.kv, s.pos)?;
            attn += f - weight_pass;
            out.push((next, kv));
        }
        let ns = self.modeled_ns(weight_pass + attn);
        if self.cfg.pace {
            clock::sleep_ns(ns);
        }
        Ok((out, ns))
    }

    fn install_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.meta.param_count {
            return Err(Error::Config(format!(
                "param vector has {} elements, expected {}",
                flat.len(),
                self.meta.param_count
            )));
        }
        let mut h = FNV_OFFSET;
        for x in flat {
            h = fnv(h, &x.to_bits().to_le_bytes());
        }
        self.params_digest.store(h, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(meta: &ModelMeta, salt: i32) -> Vec<i32> {
        (0..meta.t_pre as i32).map(|i| (i * 7 + salt) % meta.vocab as i32).collect()
    }

    #[test]
    fn prefill_is_deterministic() {
        let m = SyntheticModel::unpaced();
        let t = tokens(&m.meta, 1);
        let (a, kv_a) = m.prefill(&t, m.empty_kv().unwrap(), 0).unwrap();
        let (b, kv_b) = m.prefill(&t, m.empty_kv().unwrap(), 0).unwrap();
        assert_eq!(a, b);
        assert_eq!(kv_a.to_bytes().unwrap(), kv_b.to_bytes().unwrap());
        assert!((0..m.meta.vocab as i32).contains(&a));
    }

    #[test]
    fn different_tokens_different_kv() {
        let m = SyntheticModel::unpaced();
        let (_, kv_a) = m.prefill(&tokens(&m.meta, 1), m.empty_kv().unwrap(), 0).unwrap();
        let (_, kv_b) = m.prefill(&tokens(&m.meta, 2), m.empty_kv().unwrap(), 0).unwrap();
        assert_ne!(kv_a.to_bytes().unwrap(), kv_b.to_bytes().unwrap());
    }

    #[test]
    fn kv_roundtrip_preserves_prediction() {
        let m = SyntheticModel::unpaced();
        let t1 = tokens(&m.meta, 1);
        let t2 = tokens(&m.meta, 2);
        let t_pre = m.meta.t_pre as i32;
        let (_, kv) = m.prefill(&t1, m.empty_kv().unwrap(), 0).unwrap();
        let bytes = kv.to_bytes().unwrap();
        assert_eq!(bytes.len() as u64, m.meta.kv_bytes);
        // Continuing from the roundtripped cache must match continuing from
        // the original.
        let kv2 = m.kv_from_bytes(&bytes).unwrap();
        let (a, _) = m.prefill(&t2, kv2, t_pre).unwrap();
        let (_, kv_orig) = m.prefill(&t1, m.empty_kv().unwrap(), 0).unwrap();
        let (b, _) = m.prefill(&t2, kv_orig, t_pre).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn prediction_depends_on_cached_prefix_content() {
        let m = SyntheticModel::unpaced();
        let t_pre = m.meta.t_pre as i32;
        let (_, kv) = m.prefill(&tokens(&m.meta, 1), m.empty_kv().unwrap(), 0).unwrap();
        let mut bytes = kv.to_bytes().unwrap();
        // Corrupt one sampled byte of the chunk-0 prefix: continuations must
        // notice (a real tier would have returned wrong bytes). Predictions
        // live in `% vocab` space, so check several independent
        // continuations — a collision across all of them is impossible in
        // practice (1 in vocab^4) and the run is fully deterministic.
        let continue_with = |raw: &[u8], salt: i32| {
            let (tok, _) = m
                .prefill(&tokens(&m.meta, salt), m.kv_from_bytes(raw).unwrap(), t_pre)
                .unwrap();
            tok
        };
        let clean: Vec<i32> = (2..6).map(|s| continue_with(&bytes, s)).collect();
        bytes[0] ^= 0xFF;
        let corrupt: Vec<i32> = (2..6).map(|s| continue_with(&bytes, s)).collect();
        assert_ne!(clean, corrupt, "corrupted prefix bytes went unnoticed");
    }

    #[test]
    fn decode_chains_deterministically() {
        let m = SyntheticModel::unpaced();
        let t_pre = m.meta.t_pre as i32;
        let run = || {
            let (tok, kv) = m.prefill(&tokens(&m.meta, 3), m.empty_kv().unwrap(), 0).unwrap();
            let (t1, kv) = m.decode(tok, kv, t_pre).unwrap();
            let (t2, _) = m.decode(t1, kv, t_pre + 1).unwrap();
            (tok, t1, t2)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn install_params_changes_predictions() {
        let mut m = SyntheticModel::unpaced();
        let t = tokens(&m.meta, 4);
        let (_, kv_old) = m.prefill(&t, m.empty_kv().unwrap(), 0).unwrap();
        assert!(m.install_params(&[0.0; 3]).is_err());
        let params = vec![0.5f32; m.meta.param_count];
        m.install_params(&params).unwrap();
        let (after1, _) = m.prefill(&t, m.empty_kv().unwrap(), 0).unwrap();
        let (after2, kv_new) = m.prefill(&t, m.empty_kv().unwrap(), 0).unwrap();
        // Same weights → same prediction; the function itself moved, which
        // shows up in the KV bytes even if `% vocab` happens to collide.
        assert_eq!(after1, after2);
        assert_ne!(kv_new.to_bytes().unwrap(), kv_old.to_bytes().unwrap());
    }

    #[test]
    fn shape_and_bounds_are_enforced() {
        let m = SyntheticModel::unpaced();
        assert!(m.prefill(&[1, 2, 3], m.empty_kv().unwrap(), 0).is_err());
        let t = tokens(&m.meta, 5);
        assert!(m.prefill(&t, m.empty_kv().unwrap(), 1).is_err());
        assert!(m.prefill(&t, m.empty_kv().unwrap(), m.meta.t_max as i32).is_err());
        assert!(m.decode(1, m.empty_kv().unwrap(), m.meta.t_max as i32).is_err());
        assert!(m.kv_from_bytes(&[0u8; 8]).is_err());
    }

    #[test]
    fn batch_matches_sequential_and_amortizes_weights() {
        let m = SyntheticModel::unpaced();
        let t_pre = m.meta.t_pre as i32;
        // Two independent requests, one prefill chunk each: batch results
        // must be byte-identical to the scalar path, in input order.
        let seqs = [tokens(&m.meta, 6), tokens(&m.meta, 7)];
        let seq: Vec<(i32, KvCache)> = seqs
            .iter()
            .map(|t| m.prefill(t, m.empty_kv().unwrap(), 0).unwrap())
            .collect();
        let steps = seqs
            .iter()
            .map(|t| PrefillStep {
                tokens: t,
                kv: m.empty_kv().unwrap(),
                offset: 0,
            })
            .collect();
        let (batch, pre_ns) = m.prefill_batch(steps).unwrap();
        assert!(pre_ns > 0, "modeled ns must be returned even unpaced");
        let mut decode_steps = Vec::new();
        for ((ta, kva), (tb, kvb)) in seq.into_iter().zip(batch) {
            assert_eq!(ta, tb);
            assert_eq!(kva.to_bytes().unwrap(), kvb.to_bytes().unwrap());
            // Scalar decode result to compare the batch path against.
            let (da, _) = m.decode(ta, kva, t_pre).unwrap();
            decode_steps.push((da, DecodeStep { token: tb, kv: kvb, pos: t_pre }));
        }
        // One single-step launch vs a 2-wide batch at the same positions:
        // the weight pass is shared, so 2-wide costs less than 2 launches.
        let (expected, steps): (Vec<i32>, Vec<DecodeStep>) = decode_steps.into_iter().unzip();
        let one = DecodeStep {
            token: expected[0],
            kv: m.empty_kv().unwrap(),
            pos: t_pre,
        };
        let (_, one_ns) = m.decode_batch(vec![one]).unwrap();
        let (dec, wide_ns) = m.decode_batch(steps).unwrap();
        for (d, e) in dec.iter().zip(&expected) {
            assert_eq!(d.0, *e, "batched decode must match the scalar path");
        }
        assert!(
            wide_ns < 2 * one_ns,
            "2-wide decode {wide_ns} ns must beat 2 serial launches {} ns",
            2 * one_ns
        );
        let (empty, zero_ns) = m.decode_batch(Vec::new()).unwrap();
        assert!(empty.is_empty() && zero_ns == 0, "empty batch is free (no launch)");
    }

    #[test]
    fn flops_grow_with_context() {
        let m = SyntheticModel::unpaced();
        let early = m.flops(0, m.meta.t_pre);
        let late = m.flops(m.meta.t_max - m.meta.t_pre, m.meta.t_pre);
        assert!(late > early, "attention term must grow with position");
    }
}
