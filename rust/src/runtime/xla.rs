//! Offline stand-in for the `xla` PJRT binding crate.
//!
//! The real binding (PJRT CPU client + HLO compilation) is not in the
//! vendor set, so this module keeps the exact call surface `runtime` needs
//! while making the capability split explicit:
//!
//! * **Literal data ops** (`vec1`, `scalar`, `reshape`, `to_vec`,
//!   `get_first_element`, `to_tuple2`) are fully functional — the KV-cache
//!   byte plumbing and checkpoint payload paths exercise these.
//! * **Compilation/execution** (`PjRtClient::cpu`, `compile`, `execute`)
//!   return [`Error::Unavailable`]. [`is_available`] reports `false`, and
//!   `Runtime::artifacts_available` folds that in, so the PJRT-gated test
//!   variants skip gracefully while everything else serves through the
//!   synthetic `ModelExecutor` (see `runtime::synthetic`).
//!
//! Swapping in a real PJRT FFI binding means replacing this module and
//! flipping `is_available()`; no caller changes (see ROADMAP "Open items").

#![allow(dead_code)]

/// Shim-level error. Only ever formatted with `{:?}` by the runtime.
#[derive(Debug)]
pub enum Error {
    /// The operation needs a real PJRT runtime, which is not vendored here.
    Unavailable(&'static str),
    /// Literal shape/type mismatch.
    Shape(String),
}

const NO_PJRT: &str =
    "PJRT is not available in this offline build (no `xla` binding vendored); \
     model execution requires a real PJRT backend";

/// Does this build have a working PJRT backend? (Shim: never.)
pub fn is_available() -> bool {
    false
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }
}

/// Element types the shim can hold in a [`Literal`].
pub trait NativeType: Copy + 'static {
    fn data_from(slice: &[Self]) -> Data;
    fn data_to(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn data_from(slice: &[f32]) -> Data {
        Data::F32(slice.to_vec())
    }
    fn data_to(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn data_from(slice: &[i32]) -> Data {
        Data::I32(slice.to_vec())
    }
    fn data_to(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-resident typed array (the xla crate's `Literal`).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::data_from(v),
            dims: vec![v.len() as i64],
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            data: T::data_from(&[v]),
            dims: Vec::new(),
        }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, Error> {
        let want = dims
            .iter()
            .try_fold(1u64, |acc, &d| {
                if d < 0 {
                    None
                } else {
                    acc.checked_mul(d as u64)
                }
            });
        if want != Some(self.data.len() as u64) {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data,
            dims: dims.to_vec(),
        })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::data_to(&self.data)
            .ok_or_else(|| Error::Shape("literal element type mismatch".into()))
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        T::data_to(&self.data)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error::Shape("empty or mistyped literal".into()))
    }

    /// Destructure a 2-tuple literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        match self.data {
            Data::Tuple(mut v) if v.len() == 2 => {
                let b = v.pop().expect("len checked");
                let a = v.pop().expect("len checked");
                Ok((a, b))
            }
            _ => Err(Error::Shape("literal is not a 2-tuple".into())),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. The shim refuses to parse (no HLO parser
/// without XLA), which fails `Runtime::load` before any compilation.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::Unavailable(NO_PJRT))
    }
}

/// Computation wrapper (proto → compilable form).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. `cpu()` is the only constructor and it reports the
/// backend as unavailable, so the executable/buffer types below are
/// unreachable at runtime — they exist to keep the call sites compiling.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::Unavailable(NO_PJRT))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::Unavailable(NO_PJRT))
    }

    pub fn platform_name(&self) -> &'static str {
        "null-pjrt"
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Returns one buffer list
    /// per device (the runtime reads `outs[0][0]`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::Unavailable(NO_PJRT))
    }
}

/// Device-side buffer handle.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(r.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_first_element() {
        let s = Literal::scalar(42i32);
        assert!(s.dims().is_empty());
        assert_eq!(s.get_first_element::<i32>().unwrap(), 42);
    }

    #[test]
    fn pjrt_is_gated_off() {
        assert!(!is_available());
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
