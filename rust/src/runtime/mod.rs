//! Model execution behind the [`ModelExecutor`] boundary.
//!
//! The serving layer (router, tiered KV cache, checkpoint engine) never
//! talks to a concrete model runner: it programs against [`ModelExecutor`]
//! — prefill / decode / meta / params-install — and two implementations
//! plug in underneath:
//!
//! * [`Runtime`] — the PJRT path: load the AOT-compiled JAX/Pallas
//!   artifacts (HLO **text** → `HloModuleProto::from_text_file` →
//!   `XlaComputation` → `client.compile`, compiled once per phase) and
//!   execute them from Rust. Python never runs at request time. This build
//!   ships an offline stand-in for the `xla` binding (see [`xla`]): literal
//!   data ops work, compilation/execution report PJRT as unavailable, and
//!   [`Runtime::artifacts_available`] folds that in so the PJRT-gated
//!   tests, benches, and examples skip instead of failing.
//! * [`SyntheticModel`] — a deterministic, artifact-free executor: built-in
//!   TinyGPT-shaped [`ModelMeta`], PRNG-generated KV bytes and next-token
//!   predictions seeded from the input-token hash (bit-reproducible cache
//!   semantics), and prefill/decode delays derived analytically from the
//!   model dims so TTFT comparisons stay meaningful without a forward pass.
//!
//! [`make_executor`] picks one via [`ModelSelect`] (`--model
//! synthetic|pjrt|auto` on the CLI); `Auto` falls back to the synthetic
//! model whenever the PJRT artifacts are absent, which is what keeps the
//! whole serving stack inside tier-1.

pub mod synthetic;
pub mod xla;

pub use synthetic::{SyntheticConfig, SyntheticModel};

use crate::log;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Model dimensions read from `artifacts/model_meta.json` (written by
/// `python -m compile.aot`).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub t_max: usize,
    pub t_pre: usize,
    pub param_count: usize,
    pub kv_shape: Vec<i64>,
    pub kv_bytes: u64,
    pub kv_bytes_per_token: u64,
}

impl ModelMeta {
    /// Built-in TinyGPT-shaped dimensions for the artifact-free
    /// [`SyntheticModel`]: identical KV geometry to the AOT pipeline's
    /// TinyGPT (128-token prefill chunks of exactly 1 MiB of cache, the
    /// block size the HiCache tiers are built around), and a `param_count`
    /// matching the default checkpoint payload
    /// (`serving::CheckpointConfig::default().payload_bytes`).
    pub fn tiny_gpt() -> ModelMeta {
        let (layers, heads, head_dim) = (4usize, 4usize, 64usize);
        let (t_max, t_pre) = (1024usize, 128usize);
        let kv_bytes = (layers * 2 * heads * t_max * head_dim * 4) as u64;
        ModelMeta {
            vocab: 4096,
            d_model: 256,
            layers,
            heads,
            head_dim,
            t_max,
            t_pre,
            param_count: 4_360_448,
            kv_shape: vec![layers as i64, 2, heads as i64, t_max as i64, head_dim as i64],
            kv_bytes,
            kv_bytes_per_token: kv_bytes / t_max as u64,
        }
    }

    /// Arbitrary synthetic model dimensions with the derived KV geometry
    /// filled in. This is what lets several `ModelMeta` shapes share one
    /// fabric (multi-model serving) and lets scale benches pick a KV
    /// footprint small enough for 10k+ concurrent sessions. `t_max` must be
    /// a whole number of `t_pre` chunks.
    pub fn custom(
        layers: usize,
        heads: usize,
        head_dim: usize,
        t_max: usize,
        t_pre: usize,
        vocab: usize,
        param_count: usize,
    ) -> ModelMeta {
        assert!(t_pre > 0 && t_max % t_pre == 0, "t_max must be a multiple of t_pre");
        let kv_bytes = (layers * 2 * heads * t_max * head_dim * 4) as u64;
        ModelMeta {
            vocab,
            d_model: heads * head_dim,
            layers,
            heads,
            head_dim,
            t_max,
            t_pre,
            param_count,
            kv_shape: vec![layers as i64, 2, heads as i64, t_max as i64, head_dim as i64],
            kv_bytes,
            kv_bytes_per_token: kv_bytes / t_max as u64,
        }
    }

    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(dir.join("model_meta.json"))?;
        let j = Json::parse(&text).map_err(Error::Config)?;
        let get = |k: &str| -> Result<u64> {
            j.get(k)
                .as_u64()
                .ok_or_else(|| Error::Config(format!("model_meta missing {k}")))
        };
        Ok(ModelMeta {
            vocab: get("vocab")? as usize,
            d_model: get("d_model")? as usize,
            layers: get("layers")? as usize,
            heads: get("heads")? as usize,
            head_dim: get("head_dim")? as usize,
            t_max: get("t_max")? as usize,
            t_pre: get("t_pre")? as usize,
            param_count: get("param_count")? as usize,
            kv_shape: j
                .get("kv_shape")
                .as_arr()
                .ok_or_else(|| Error::Config("model_meta missing kv_shape".into()))?
                .iter()
                .map(|v| v.as_u64().unwrap_or(0) as i64)
                .collect(),
            kv_bytes: get("kv_bytes")?,
            kv_bytes_per_token: get("kv_bytes_per_token")?,
        })
    }
}

/// A request's KV cache on the executor side (the serving layer owns where
/// its *bytes of record* live in the tiered store).
///
/// Each executor keeps its native representation behind this enum: the PJRT
/// path holds an `xla::Literal`, the synthetic path holds the raw
/// little-endian f32 bytes directly (no float parse on the request path).
pub enum KvCache {
    /// PJRT-side literal (shape `meta.kv_shape`).
    Literal(xla::Literal),
    /// Raw little-endian f32 bytes in the working `[L, 2, H, T, D]` layout.
    Host(Vec<u8>),
}

impl KvCache {
    /// Raw little-endian f32 bytes of the cache (for segment upload).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        match self {
            KvCache::Literal(lit) => {
                let v: Vec<f32> = lit
                    .to_vec()
                    .map_err(|e| Error::Runtime(format!("kv to_vec: {e:?}")))?;
                let mut out = vec![0u8; v.len() * 4];
                for (i, x) in v.iter().enumerate() {
                    out[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
                }
                Ok(out)
            }
            KvCache::Host(raw) => Ok(raw.clone()),
        }
    }

    /// Borrow the raw bytes when the executor already holds them host-side
    /// (the synthetic path) — saves an 8 MiB clone per turn in the serving
    /// store path. `None` for literals; fall back to [`KvCache::to_bytes`].
    pub fn as_host_bytes(&self) -> Option<&[u8]> {
        match self {
            KvCache::Host(raw) => Some(raw),
            KvCache::Literal(_) => None,
        }
    }

    fn into_literal(self) -> Result<xla::Literal> {
        match self {
            KvCache::Literal(lit) => Ok(lit),
            KvCache::Host(_) => Err(Error::Runtime(
                "KV state was produced by a different executor (host bytes, not a literal)".into(),
            )),
        }
    }
}

/// One prefill-chunk step inside an iteration-level batch: exactly
/// `meta().t_pre` tokens at chunk-aligned `offset`, carrying the request's
/// KV state through the call.
pub struct PrefillStep<'a> {
    pub tokens: &'a [i32],
    pub kv: KvCache,
    pub offset: i32,
}

/// One decode step inside an iteration-level batch.
pub struct DecodeStep {
    pub token: i32,
    pub kv: KvCache,
    pub pos: i32,
}

/// The executor boundary the serving layer programs against: everything a
/// router / checkpoint consumer needs from a model, and nothing about how
/// (or whether) a forward pass actually runs. [`Runtime`] (PJRT) and
/// [`SyntheticModel`] (deterministic, artifact-free) both implement it, so
/// the "Real PJRT binding" ROADMAP item un-skips with no caller changes.
pub trait ModelExecutor: Send + Sync {
    /// Short executor name for reports ("pjrt" / "synthetic").
    fn name(&self) -> &'static str;
    /// Model dimensions (KV geometry, chunk size, vocab).
    fn meta(&self) -> &ModelMeta;
    /// Fresh zero KV cache.
    fn empty_kv(&self) -> Result<KvCache>;
    /// KV cache from raw little-endian f32 bytes (fetched from the tiered
    /// store over TENT).
    fn kv_from_bytes(&self, raw: &[u8]) -> Result<KvCache>;
    /// Run a prefill chunk (exactly `meta().t_pre` tokens) at `offset`.
    fn prefill(&self, tokens: &[i32], kv: KvCache, offset: i32) -> Result<(i32, KvCache)>;
    /// Run one decode step at `pos`.
    fn decode(&self, token: i32, kv: KvCache, pos: i32) -> Result<(i32, KvCache)>;
    /// Replace the weights in place (checkpoint-engine integration).
    fn install_params(&mut self, flat: &[f32]) -> Result<()>;

    /// Execute a batch of prefill chunks as one iteration-level step
    /// (continuous batching). Returns per-step results in input order plus
    /// the **modeled** batch latency in ns — the continuous-batching router
    /// advances its deterministic virtual clock by that value. The default
    /// implementation runs the steps sequentially and reports measured
    /// wall time; [`SyntheticModel`] overrides it with the analytical
    /// FLOPs model (one launch overhead for the whole batch).
    fn prefill_batch(&self, steps: Vec<PrefillStep<'_>>) -> Result<(Vec<(i32, KvCache)>, u64)> {
        let t0 = crate::util::clock::now_ns();
        let mut out = Vec::with_capacity(steps.len());
        for s in steps {
            out.push(self.prefill(s.tokens, s.kv, s.offset)?);
        }
        Ok((out, crate::util::clock::now_ns() - t0))
    }

    /// Execute a batch of decode steps as one iteration-level step. Same
    /// contract as [`ModelExecutor::prefill_batch`]; the synthetic override
    /// additionally shares the weight pass across the batch (decode is
    /// memory-bound — the continuous-batching throughput win).
    fn decode_batch(&self, steps: Vec<DecodeStep>) -> Result<(Vec<(i32, KvCache)>, u64)> {
        let t0 = crate::util::clock::now_ns();
        let mut out = Vec::with_capacity(steps.len());
        for s in steps {
            out.push(self.decode(s.token, s.kv, s.pos)?);
        }
        Ok((out, crate::util::clock::now_ns() - t0))
    }
}

/// Which model executor a run should use.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum ModelSelect {
    /// PJRT when the AOT artifacts + a real backend are available,
    /// otherwise the synthetic model. The tier-1 default.
    #[default]
    Auto,
    /// Always the deterministic artifact-free model.
    Synthetic,
    /// Always PJRT; errors out when unavailable.
    Pjrt,
}

impl ModelSelect {
    /// Parse a `--model` CLI value.
    pub fn parse(s: &str) -> Option<ModelSelect> {
        match s {
            "auto" => Some(ModelSelect::Auto),
            "synthetic" | "syn" => Some(ModelSelect::Synthetic),
            "pjrt" => Some(ModelSelect::Pjrt),
            _ => None,
        }
    }
}

/// Build the selected executor. `Auto` prefers PJRT when
/// [`Runtime::artifacts_available`] holds and otherwise falls back to
/// [`SyntheticModel`], so serving runs need no artifacts on disk.
pub fn make_executor(sel: ModelSelect) -> Result<Box<dyn ModelExecutor>> {
    let dir = default_artifacts_dir();
    match sel {
        ModelSelect::Synthetic => Ok(Box::new(SyntheticModel::default())),
        ModelSelect::Pjrt => Ok(Box::new(Runtime::load(&dir)?)),
        ModelSelect::Auto => {
            if Runtime::artifacts_available(&dir) {
                Ok(Box::new(Runtime::load(&dir)?))
            } else {
                log::info!("runtime: PJRT unavailable, using the synthetic model executor");
                Ok(Box::new(SyntheticModel::default()))
            }
        }
    }
}

/// The compiled model: PJRT CPU client + one executable per phase.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    params: xla::Literal,
    pub meta: ModelMeta,
    pub artifacts_dir: PathBuf,
}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(format!("{e:?}"))
}

impl Runtime {
    /// Load artifacts (HLO text + params.bin + meta) and compile.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Config("bad artifacts path".into()))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(xerr)
        };
        let prefill_exe = compile("prefill.hlo.txt")?;
        let decode_exe = compile("decode.hlo.txt")?;
        let params = Self::load_params(&dir.join("params.bin"), meta.param_count)?;
        log::info!(
            "runtime: loaded TinyGPT ({} params, kv {} per request) on {}",
            meta.param_count,
            crate::util::fmt_bytes(meta.kv_bytes),
            client.platform_name()
        );
        Ok(Runtime {
            client,
            prefill_exe,
            decode_exe,
            params,
            meta,
            artifacts_dir: dir.to_path_buf(),
        })
    }

    /// Can `Runtime::load` succeed? Requires both the AOT artifacts on disk
    /// *and* a working PJRT backend (absent in the offline shim build) —
    /// tests/examples skip gracefully when either is missing.
    pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
        let d = dir.as_ref();
        xla::is_available()
            && ["prefill.hlo.txt", "decode.hlo.txt", "params.bin", "model_meta.json"]
                .iter()
                .all(|f| d.join(f).exists())
    }

    fn load_params(path: &Path, count: usize) -> Result<xla::Literal> {
        let raw = std::fs::read(path)?;
        if raw.len() != count * 4 {
            return Err(Error::Config(format!(
                "params.bin is {} bytes, expected {}",
                raw.len(),
                count * 4
            )));
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(xla::Literal::vec1(&floats))
    }

    /// Replace the weights (checkpoint-engine integration: the new flat
    /// param vector just arrived over TENT).
    pub fn install_params(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.meta.param_count {
            return Err(Error::Config(format!(
                "param vector has {} elements, expected {}",
                flat.len(),
                self.meta.param_count
            )));
        }
        self.params = xla::Literal::vec1(flat);
        Ok(())
    }

    /// Current weights as raw f32 (checkpoint source payload).
    pub fn params_f32(&self) -> Result<Vec<f32>> {
        self.params
            .to_vec()
            .map_err(|e| Error::Runtime(format!("{e:?}")))
    }

    /// Fresh zero KV cache.
    pub fn empty_kv(&self) -> Result<KvCache> {
        let zeros = vec![0f32; (self.meta.kv_bytes / 4) as usize];
        Ok(KvCache::Literal(
            xla::Literal::vec1(&zeros)
                .reshape(&self.meta.kv_shape)
                .map_err(xerr)?,
        ))
    }

    /// KV cache from raw little-endian f32 bytes (fetched from the tiered
    /// store over TENT).
    pub fn kv_from_bytes(&self, raw: &[u8]) -> Result<KvCache> {
        if raw.len() as u64 != self.meta.kv_bytes {
            return Err(Error::Config(format!(
                "kv bytes {} != expected {}",
                raw.len(),
                self.meta.kv_bytes
            )));
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(KvCache::Literal(
            xla::Literal::vec1(&floats)
                .reshape(&self.meta.kv_shape)
                .map_err(xerr)?,
        ))
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        tokens: &[i32],
        kv: KvCache,
        offset: i32,
    ) -> Result<(i32, KvCache)> {
        let tok_lit = xla::Literal::vec1(tokens);
        let off_lit = xla::Literal::scalar(offset);
        let kv_lit = kv.into_literal()?;
        let outs = exe
            .execute::<xla::Literal>(&[self.params.clone_literal()?, tok_lit, kv_lit, off_lit])
            .map_err(xerr)?;
        let result = outs[0][0].to_literal_sync().map_err(xerr)?;
        let (next, kv_out) = result.to_tuple2().map_err(xerr)?;
        let next_token = next
            .get_first_element::<i32>()
            .map_err(xerr)?;
        Ok((next_token, KvCache::Literal(kv_out)))
    }

    /// Run a prefill chunk (exactly `t_pre` tokens) at `offset`.
    pub fn prefill(&self, tokens: &[i32], kv: KvCache, offset: i32) -> Result<(i32, KvCache)> {
        if tokens.len() != self.meta.t_pre {
            return Err(Error::Config(format!(
                "prefill needs {} tokens, got {}",
                self.meta.t_pre,
                tokens.len()
            )));
        }
        self.run(&self.prefill_exe, tokens, kv, offset)
    }

    /// Run one decode step at `pos`.
    pub fn decode(&self, token: i32, kv: KvCache, pos: i32) -> Result<(i32, KvCache)> {
        self.run(&self.decode_exe, &[token], kv, pos)
    }
}

impl ModelExecutor for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }
    fn empty_kv(&self) -> Result<KvCache> {
        Runtime::empty_kv(self)
    }
    fn kv_from_bytes(&self, raw: &[u8]) -> Result<KvCache> {
        Runtime::kv_from_bytes(self, raw)
    }
    fn prefill(&self, tokens: &[i32], kv: KvCache, offset: i32) -> Result<(i32, KvCache)> {
        Runtime::prefill(self, tokens, kv, offset)
    }
    fn decode(&self, token: i32, kv: KvCache, pos: i32) -> Result<(i32, KvCache)> {
        Runtime::decode(self, token, kv, pos)
    }
    fn install_params(&mut self, flat: &[f32]) -> Result<()> {
        Runtime::install_params(self, flat)
    }
}

/// Helper used by Runtime::run — the xla crate's Literal has no public
/// clone; round-trip through raw data.
trait CloneLiteral {
    fn clone_literal(&self) -> Result<xla::Literal>;
}

impl CloneLiteral for xla::Literal {
    fn clone_literal(&self) -> Result<xla::Literal> {
        let v: Vec<f32> = self.to_vec().map_err(|e| Error::Runtime(format!("{e:?}")))?;
        Ok(xla::Literal::vec1(&v))
    }
}

/// Default artifacts directory: `$TENT_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TENT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        // Unit tests run from the crate root.
        default_artifacts_dir()
    }

    #[test]
    fn meta_parses_when_artifacts_exist() {
        // Needs only the on-disk artifacts, not PJRT — gate on the file, so
        // this coverage fires as soon as `python -m compile.aot` has run.
        if !dir().join("model_meta.json").exists() {
            eprintln!("skipping: model_meta.json not built (run `python -m compile.aot`)");
            return;
        }
        let m = ModelMeta::load(&dir()).unwrap();
        assert_eq!(m.kv_shape.len(), 5);
        assert_eq!(m.kv_bytes_per_token * m.t_max as u64, m.kv_bytes);
    }

    #[test]
    fn prefill_and_decode_execute() {
        if !Runtime::artifacts_available(dir()) {
            eprintln!("skipping: model runtime unavailable (AOT artifacts + real PJRT backend required)");
            return;
        }
        let rt = Runtime::load(dir()).unwrap();
        let kv = rt.empty_kv().unwrap();
        let tokens: Vec<i32> = (0..rt.meta.t_pre as i32).collect();
        let (next, kv) = rt.prefill(&tokens, kv, 0).unwrap();
        assert!((0..rt.meta.vocab as i32).contains(&next));
        let (next2, _kv) = rt.decode(next, kv, rt.meta.t_pre as i32).unwrap();
        assert!((0..rt.meta.vocab as i32).contains(&next2));
    }

    #[test]
    fn determinism_across_runs() {
        if !Runtime::artifacts_available(dir()) {
            eprintln!("skipping: model runtime unavailable (AOT artifacts + real PJRT backend required)");
            return;
        }
        let rt = Runtime::load(dir()).unwrap();
        let tokens: Vec<i32> = (0..rt.meta.t_pre as i32).map(|i| i * 7 % 4096).collect();
        let (a, _) = rt.prefill(&tokens, rt.empty_kv().unwrap(), 0).unwrap();
        let (b, _) = rt.prefill(&tokens, rt.empty_kv().unwrap(), 0).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn kv_roundtrip_preserves_prediction() {
        if !Runtime::artifacts_available(dir()) {
            eprintln!("skipping: model runtime unavailable (AOT artifacts + real PJRT backend required)");
            return;
        }
        let rt = Runtime::load(dir()).unwrap();
        let tokens: Vec<i32> = (0..rt.meta.t_pre as i32).collect();
        let (_, kv) = rt.prefill(&tokens, rt.empty_kv().unwrap(), 0).unwrap();
        let bytes = kv.to_bytes().unwrap();
        assert_eq!(bytes.len() as u64, rt.meta.kv_bytes);
        let kv2 = rt.kv_from_bytes(&bytes).unwrap();
        // Continuing from the roundtripped cache must match.
        let t2: Vec<i32> = (0..rt.meta.t_pre as i32).map(|i| (i * 13) % 4096).collect();
        let (a, _) = rt.prefill(&t2, kv2, rt.meta.t_pre as i32).unwrap();
        let (_, kv_orig) = rt.prefill(&tokens, rt.empty_kv().unwrap(), 0).unwrap();
        let (b, _) = rt.prefill(&t2, kv_orig, rt.meta.t_pre as i32).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_gpt_meta_is_self_consistent() {
        let m = ModelMeta::tiny_gpt();
        assert_eq!(m.kv_shape.len(), 5);
        let elems: i64 = m.kv_shape.iter().product();
        assert_eq!(elems as u64 * 4, m.kv_bytes);
        assert_eq!(m.kv_bytes_per_token * m.t_max as u64, m.kv_bytes);
        assert_eq!(m.t_max % m.t_pre, 0);
        // One prefill chunk is exactly 1 MiB of cache — the HiCache block.
        assert_eq!(m.kv_bytes_per_token * m.t_pre as u64, 1 << 20);
        // The default checkpoint payload is this model's flat f32 params.
        assert_eq!(
            m.param_count as u64 * 4,
            crate::serving::CheckpointConfig::default().payload_bytes
        );
    }

    #[test]
    fn model_select_parses() {
        assert_eq!(ModelSelect::parse("auto"), Some(ModelSelect::Auto));
        assert_eq!(ModelSelect::parse("synthetic"), Some(ModelSelect::Synthetic));
        assert_eq!(ModelSelect::parse("pjrt"), Some(ModelSelect::Pjrt));
        assert_eq!(ModelSelect::parse("tinygpt"), None);
    }

    #[test]
    fn auto_executor_needs_no_artifacts() {
        // In the offline build PJRT is stubbed out, so Auto must fall back
        // to the synthetic executor instead of erroring.
        let m = make_executor(ModelSelect::Auto).unwrap();
        assert!(m.name() == "synthetic" || m.name() == "pjrt");
        assert!(m.meta().t_pre > 0);
    }

    #[test]
    fn install_params_validates_length() {
        if !Runtime::artifacts_available(dir()) {
            eprintln!("skipping: model runtime unavailable (AOT artifacts + real PJRT backend required)");
            return;
        }
        let mut rt = Runtime::load(dir()).unwrap();
        assert!(rt.install_params(&[0.0; 3]).is_err());
        let p = rt.params_f32().unwrap();
        rt.install_params(&p).unwrap();
    }
}
