//! Pluggable slice-scheduling policies.
//!
//! [`TentPolicy`] implements the paper's Algorithm 1 (telemetry-driven slice
//! spraying). The other policies re-implement the baselines exactly as the
//! paper characterizes them (§2.2, §5.1.3), on the *same* substrate, so the
//! benches isolate the scheduling variable:
//!
//! * [`MooncakePolicy`] — Mooncake TE: static binding to RDMA (GPU↔GPU never
//!   uses NVLink), fixed GPU→tier-1-NIC mapping, randomized striping among
//!   NUMA-local NICs for host buffers, no telemetry, no automatic failover.
//! * [`NixlPolicy`] — NIXL/UCX: a small static set of "best" NICs (two by
//!   default), multi-rail only above a size threshold.
//! * [`UcclPolicy`] — UCCL-P2P: each registered memory region pinned to a
//!   single NIC; no cross-NIC aggregation.
//! * [`RoundRobinPolicy`] — plain state-blind round-robin (the Fig. 2
//!   baseline).

mod mooncake;
mod nixl;
mod round_robin;
mod tent;
mod uccl;

pub use mooncake::MooncakePolicy;
pub use nixl::NixlPolicy;
pub use round_robin::RoundRobinPolicy;
pub use tent::TentPolicy;
pub use uccl::UcclPolicy;

use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::segment::Segment;
use crate::topology::{RailId, Topology};

/// Which policy an engine runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PolicyKind {
    /// The paper's contribution: declarative telemetry-driven slice spraying.
    Tent,
    /// State-blind round-robin striping.
    RoundRobin,
    /// Mooncake Transfer Engine baseline.
    MooncakeTe,
    /// NIXL (UCX-based) baseline.
    Nixl,
    /// UCCL-P2P baseline.
    UcclP2p,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        Some(match s {
            "tent" => PolicyKind::Tent,
            "rr" | "round_robin" => PolicyKind::RoundRobin,
            "mooncake" | "te" | "mooncake_te" => PolicyKind::MooncakeTe,
            "nixl" => PolicyKind::Nixl,
            "uccl" | "uccl_p2p" => PolicyKind::UcclP2p,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Tent => "TENT",
            PolicyKind::RoundRobin => "Round-Robin",
            PolicyKind::MooncakeTe => "Mooncake TE",
            PolicyKind::Nixl => "NIXL",
            PolicyKind::UcclP2p => "UCCL-P2P",
        }
    }
}

/// The policy interface: shape the plan once per transfer (static-binding
/// emulation for baselines), then pick a candidate per slice.
pub trait SlicePolicy: Send + Sync {
    fn kind(&self) -> PolicyKind;

    /// Restrict/reorder the candidate set at plan time. TENT keeps the full
    /// pool (late binding); baselines emulate their static commitments here.
    fn shape_plan(
        &self,
        _plan: &mut TransferPlan,
        _src: &Segment,
        _dst: &Segment,
        _topo: &Topology,
    ) {
    }

    /// Choose one of `viable` (indices into `plan.candidates`) for a slice
    /// of `len` bytes. `None` means no eligible device (Algorithm 1 line 2).
    fn pick(&self, plan: &TransferPlan, viable: &[usize], len: u64, ctx: &SchedCtx)
        -> Option<usize>;

    /// Completion feedback hook (TENT's EWMA update; baselines ignore it).
    fn on_complete(
        &self,
        _rail: RailId,
        _predicted_ns: f64,
        _serial_ns: f64,
        _observed_ns: f64,
        _ctx: &SchedCtx,
    ) {
    }

    /// Coalesced completion feedback: `n` slices finished on `rail` within
    /// one datapath drain pass, with the given *mean* predicted / serial /
    /// observed times. The default forwards one averaged
    /// [`SlicePolicy::on_complete`] call, so every policy stays correct;
    /// TENT overrides it to apply the weight-equivalent batched EWMA
    /// update (`SchedulerState::observe_batch`) directly.
    fn on_complete_batch(
        &self,
        rail: RailId,
        n: u64,
        mean_predicted_ns: f64,
        mean_serial_ns: f64,
        mean_observed_ns: f64,
        ctx: &SchedCtx,
    ) {
        if n > 0 {
            self.on_complete(rail, mean_predicted_ns, mean_serial_ns, mean_observed_ns, ctx);
        }
    }

    /// Whether the engine performs in-band per-slice failover for this
    /// policy (§4.3). Baselines surface transport faults to the caller.
    fn failover(&self) -> bool;
}

/// Instantiate a policy.
pub fn make_policy(kind: PolicyKind) -> Box<dyn SlicePolicy> {
    match kind {
        PolicyKind::Tent => Box::new(TentPolicy::default()),
        PolicyKind::RoundRobin => Box::new(RoundRobinPolicy::default()),
        PolicyKind::MooncakeTe => Box::new(MooncakePolicy::default()),
        PolicyKind::Nixl => Box::new(NixlPolicy::default()),
        PolicyKind::UcclP2p => Box::new(UcclPolicy::default()),
    }
}

/// Shared helper: drop every candidate that is not sim-RDMA, if any RDMA
/// candidate exists (the baselines' "commit to the RDMA stack" behaviour).
pub(crate) fn restrict_to_rdma(plan: &mut TransferPlan) -> bool {
    let has = plan
        .candidates
        .iter()
        .any(|c| c.backend.name() == "rdma_sim");
    if has {
        plan.candidates.retain(|c| c.backend.name() == "rdma_sim");
    }
    has
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(PolicyKind::parse("tent"), Some(PolicyKind::Tent));
        assert_eq!(PolicyKind::parse("mooncake"), Some(PolicyKind::MooncakeTe));
        assert_eq!(PolicyKind::parse("rr"), Some(PolicyKind::RoundRobin));
        assert_eq!(PolicyKind::parse("nixl"), Some(PolicyKind::Nixl));
        assert_eq!(PolicyKind::parse("uccl"), Some(PolicyKind::UcclP2p));
        assert_eq!(PolicyKind::parse("bogus"), None);
    }

    #[test]
    fn factory_builds_each() {
        for k in [
            PolicyKind::Tent,
            PolicyKind::RoundRobin,
            PolicyKind::MooncakeTe,
            PolicyKind::Nixl,
            PolicyKind::UcclP2p,
        ] {
            let p = make_policy(k);
            assert_eq!(p.kind(), k);
        }
    }

    #[test]
    fn only_tent_failover_by_default() {
        assert!(make_policy(PolicyKind::Tent).failover());
        assert!(!make_policy(PolicyKind::MooncakeTe).failover());
        assert!(!make_policy(PolicyKind::Nixl).failover());
        assert!(!make_policy(PolicyKind::UcclP2p).failover());
        assert!(!make_policy(PolicyKind::RoundRobin).failover());
    }
}
