//! Algorithm 1 — telemetry-driven slice scheduling, verbatim:
//!
//! ```text
//! Require: request length L, source location ℓ
//! 1:  D ← candidate devices reachable from ℓ           (the plan)
//! 2:  if D is empty then return ERROR(NoEligibleDevice)
//! 3:  s_min ← +∞
//! 4:  for each device d ∈ D do
//! 5:      get queue length A_d, bandwidth B_d, model (β0_d, β1_d)
//! 6:      t̂_d ← β0_d + β1_d · (A_d + L)/B_d
//! 7:      s_d ← P_tier(d) · t̂_d                        (topology penalty)
//! 8:      s_min ← min(s_min, s_d)
//! 9:  C ← { d ∈ D | s_d ≤ (1+γ)·s_min }                (tolerance window)
//! 10: choose d* from C via round-robin
//! 11: A_d* ← A_d* + L
//! 12: return d*
//! ```
//!
//! Plus the feedback loop: on completion the prediction error updates
//! (β0, β1) via EWMA, and the maintenance thread periodically resets state
//! so degraded paths are re-admitted (§4.2).

use super::{PolicyKind, SlicePolicy};
use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::topology::RailId;
use std::sync::atomic::Ordering;

#[derive(Default)]
pub struct TentPolicy;

impl SlicePolicy for TentPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Tent
    }

    fn pick(
        &self,
        plan: &TransferPlan,
        viable: &[usize],
        len: u64,
        ctx: &SchedCtx,
    ) -> Option<usize> {
        if viable.is_empty() {
            return None; // line 2: ERROR(NoEligibleDevice)
        }
        let sched = ctx.sched;
        // Bandwidth-class gating: fallback links an order of magnitude
        // slower than the best candidate (e.g. the TCP rail next to an RDMA
        // pool) serve as *substitution* targets (§4.3), not spillover
        // targets — queue-equalizing onto an 80x-slower link would trade a
        // tiny bandwidth gain for massive tail latency. Keep them out of the
        // spray unless every fast link is gone.
        let max_bw = viable
            .iter()
            .map(|&i| plan.candidates[i].bw)
            .fold(0.0f64, f64::max);
        let gated: Vec<usize> = viable
            .iter()
            .copied()
            .filter(|&i| plan.candidates[i].bw >= max_bw / 10.0)
            .collect();
        let viable: &[usize] = if gated.is_empty() { viable } else { &gated };
        // Lines 3–8: score every candidate.
        let mut scores: Vec<(usize, f64, f64)> = Vec::with_capacity(viable.len());
        let mut s_min = f64::INFINITY;
        let mut t_min = f64::INFINITY;
        for &i in viable {
            let c = &plan.candidates[i];
            let (t_hat, _serial) = sched.predict_ns_to(
                ctx.fabric,
                c.rail,
                len,
                c.bw,
                ctx.class,
                Some(plan.dst_node),
                c.relays(),
            );
            let s = sched.penalty(c.tier) * t_hat;
            s_min = s_min.min(s);
            t_min = t_min.min(t_hat);
            scores.push((i, s, t_hat));
        }
        let gamma = sched.params.gamma;
        // Line 9: the tolerance window. If every score is infinite (all
        // candidates are tier-3 / P=∞), fall back to comparing raw t̂ so
        // NUMA-crossing rails still work when they are the only option.
        let window: Vec<usize> = if s_min.is_finite() {
            scores
                .iter()
                .filter(|&&(_, s, _)| s <= (1.0 + gamma) * s_min)
                .map(|&(i, _, _)| i)
                .collect()
        } else {
            scores
                .iter()
                .filter(|&&(_, _, t)| t <= (1.0 + gamma) * t_min)
                .map(|&(i, _, _)| i)
                .collect()
        };
        // Line 10: round-robin within the window.
        let k = sched.rr.fetch_add(1, Ordering::Relaxed) % window.len();
        Some(window[k])
        // Line 11 (A_d* += L) is applied by the dispatcher via add_queued.
    }

    fn on_complete(
        &self,
        rail: RailId,
        predicted_ns: f64,
        serial_ns: f64,
        observed_ns: f64,
        ctx: &SchedCtx,
    ) {
        ctx.sched.observe(rail, predicted_ns, serial_ns, observed_ns);
    }

    fn on_complete_batch(
        &self,
        rail: RailId,
        n: u64,
        _mean_predicted_ns: f64,
        mean_serial_ns: f64,
        mean_observed_ns: f64,
        ctx: &SchedCtx,
    ) {
        // Weight-equivalent coalesced EWMA update: one atomic round-trip
        // for the whole drain pass instead of one per slice.
        ctx.sched
            .observe_batch(rail, n, mean_observed_ns, mean_serial_ns);
    }

    fn failover(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::plan::build_plan;
    use crate::engine::sched::{SchedParams, SchedulerState};
    use crate::engine::TransferClass;
    use crate::segment::Location;
    use crate::topology::Tier;

    fn ctx_for<'a>(
        c: &'a Cluster,
        sched: &'a SchedulerState,
    ) -> SchedCtx<'a> {
        SchedCtx {
            sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: TransferClass::Bulk,
        }
    }

    fn h2h_plan(c: &Cluster) -> TransferPlan {
        let a = c.segments.register_memory(Location::host(0, 0), 1 << 26).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 26).unwrap();
        build_plan(&c.transports, &c.topo, &a, &b, 1 << 26).unwrap()
    }

    #[test]
    fn empty_viable_is_no_eligible_device() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let plan = h2h_plan(&c);
        assert!(TentPolicy.pick(&plan, &[], 4096, &ctx_for(&c, &sched)).is_none());
    }

    #[test]
    fn idle_pick_prefers_tier1() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let plan = h2h_plan(&c);
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let ctx = ctx_for(&c, &sched);
        for _ in 0..32 {
            let i = TentPolicy.pick(&plan, &viable, 64 << 10, &ctx).unwrap();
            assert_eq!(plan.candidates[i].tier, Tier::T1);
            assert_eq!(plan.candidates[i].backend.name(), "rdma_sim");
        }
    }

    #[test]
    fn round_robin_spreads_within_window() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let plan = h2h_plan(&c);
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let ctx = ctx_for(&c, &sched);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..64 {
            let i = TentPolicy.pick(&plan, &viable, 64 << 10, &ctx).unwrap();
            seen.insert(plan.candidates[i].rail);
        }
        // 4 tier-1 NICs for a NUMA-0 host buffer.
        assert_eq!(seen.len(), 4, "expected all 4 tier-1 rails used: {seen:?}");
    }

    #[test]
    fn saturated_tier1_spills_to_tier3_window_fallback() {
        // Host memory: tiers are 1 or 3 in our model. Load tier-1 rails
        // heavily; the infinite-penalty fallback must then use raw t̂ and
        // pick an idle remote-socket NIC rather than queueing forever.
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let plan = h2h_plan(&c);
        let ctx = ctx_for(&c, &sched);
        let viable: Vec<usize> = (0..plan.candidates.len())
            .filter(|&i| plan.candidates[i].backend.name() == "rdma_sim")
            .collect();
        // Pile 64 MiB onto every tier-1 rail.
        for &i in &viable {
            if plan.candidates[i].tier == Tier::T1 {
                sched.add_queued(
                    &c.fabric,
                    plan.candidates[i].rail,
                    64 << 20,
                    TransferClass::Bulk,
                );
            }
        }
        // tier-3 candidates only.
        let t3: Vec<usize> = viable
            .iter()
            .copied()
            .filter(|&i| plan.candidates[i].tier == Tier::T3)
            .collect();
        let picked = TentPolicy.pick(&plan, &t3, 1 << 20, &ctx).unwrap();
        assert_eq!(plan.candidates[picked].tier, Tier::T3);
    }

    #[test]
    fn feedback_steers_away_from_degraded_rail() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let plan = h2h_plan(&c);
        let ctx = ctx_for(&c, &sched);
        let viable: Vec<usize> = (0..plan.candidates.len())
            .filter(|&i| {
                plan.candidates[i].backend.name() == "rdma_sim"
                    && plan.candidates[i].tier == Tier::T1
            })
            .collect();
        // Rail of the first tier-1 candidate reports 10x-slow completions.
        let bad = plan.candidates[viable[0]].rail;
        let bw = plan.candidates[viable[0]].bw;
        for _ in 0..30 {
            let serial = (1u64 << 20) as f64 / bw * 1e9;
            sched.observe(bad, serial, serial, 10.0 * serial);
        }
        // The spray must now avoid `bad`.
        let mut picks_bad = 0;
        for _ in 0..64 {
            let i = TentPolicy.pick(&plan, &viable, 1 << 20, &ctx).unwrap();
            if plan.candidates[i].rail == bad {
                picks_bad += 1;
            }
        }
        assert_eq!(picks_bad, 0, "degraded rail must be avoided");
    }

    #[test]
    fn bulk_flood_does_not_move_latency_rail_choice() {
        // Regression for class-blind global diffusion: with ω > 0 the
        // latency-class spray used to read the rail-level queued-bytes
        // pool, which a peer engine's Bulk flood inflates — shifting
        // latency picks off an otherwise perfectly healthy rail. With
        // per-class fabric lanes the flood must be invisible to Latency.
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let params = SchedParams {
            omega: 0.5,
            ..Default::default()
        };
        let sched = SchedulerState::new(c.topo.rails.len(), params.clone());
        let flooder = SchedulerState::new(c.topo.rails.len(), params);
        let plan = h2h_plan(&c);
        let viable: Vec<usize> = (0..plan.candidates.len())
            .filter(|&i| {
                plan.candidates[i].backend.name() == "rdma_sim"
                    && plan.candidates[i].tier == Tier::T1
            })
            .collect();
        let lat_ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: TransferClass::Latency,
        };
        let baseline: Vec<usize> = (0..32)
            .map(|_| TentPolicy.pick(&plan, &viable, 64 << 10, &lat_ctx).unwrap())
            .collect();
        // A peer engine floods ONE tier-1 rail with Bulk backlog.
        let victim = plan.candidates[viable[0]].rail;
        flooder.add_queued(&c.fabric, victim, 256 << 20, TransferClass::Bulk);
        sched.rr.store(0, std::sync::atomic::Ordering::Relaxed);
        let flooded: Vec<usize> = (0..32)
            .map(|_| TentPolicy.pick(&plan, &viable, 64 << 10, &lat_ctx).unwrap())
            .collect();
        assert_eq!(
            baseline, flooded,
            "Bulk flood moved the Latency rail choice through global diffusion"
        );
        // Sanity: a Bulk spray *does* see the flood and avoids the victim.
        let bulk_ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: TransferClass::Bulk,
        };
        for _ in 0..32 {
            let i = TentPolicy.pick(&plan, &viable, 64 << 10, &bulk_ctx).unwrap();
            assert_ne!(
                plan.candidates[i].rail, victim,
                "Bulk must steer around the flooded rail"
            );
        }
    }

    #[test]
    fn d2d_large_blocks_recruit_tier2() {
        // Fig 6 behaviour: tier-1 NIC saturates, tier-2 NICs are recruited
        // once P2 · t̂_idle < t̂_tier1_queued.
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let g0 = c.segments.register_memory(Location::device(0, 0), 1 << 26).unwrap();
        let g1 = c.segments.register_memory(Location::device(1, 0), 1 << 26).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &g0, &g1, 1 << 26).unwrap();
        let ctx = ctx_for(&c, &sched);
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let mut tiers_used = std::collections::HashSet::new();
        // Spray a 64 MiB flow in 1 MiB slices, accounting the queue like the
        // dispatcher would.
        for _ in 0..64 {
            let i = TentPolicy.pick(&plan, &viable, 1 << 20, &ctx).unwrap();
            let cnd = &plan.candidates[i];
            sched.add_queued(&c.fabric, cnd.rail, 1 << 20, TransferClass::Bulk);
            tiers_used.insert(cnd.tier);
        }
        assert!(tiers_used.contains(&Tier::T1));
        assert!(
            tiers_used.contains(&Tier::T2),
            "large flow must spill to tier-2: {tiers_used:?}"
        );
    }
}
