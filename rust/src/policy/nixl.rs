//! NIXL (UCX-based) baseline, as characterized in §5.1.3 / Fig. 9:
//!
//! * selects a small static set of "best" NICs — two by default — ranked by
//!   static transport properties (nominal bandwidth, then id);
//! * multi-rail striping only kicks in above a size threshold; a 4 MB block
//!   "is too small to trigger its multi-rail mechanism" and rides one NIC;
//! * no queue-depth visibility, no failover.

use super::{restrict_to_rdma, PolicyKind, SlicePolicy};
use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::segment::Segment;
use crate::topology::Topology;
use std::sync::atomic::{AtomicUsize, Ordering};

pub struct NixlPolicy {
    cursor: AtomicUsize,
    /// How many "best" NICs UCX keeps (default 2).
    pub max_rails: usize,
    /// Transfers below this stay single-rail (default 8 MiB).
    pub multirail_threshold: u64,
}

impl Default for NixlPolicy {
    fn default() -> Self {
        NixlPolicy {
            cursor: AtomicUsize::new(0),
            max_rails: 2,
            multirail_threshold: 8 << 20,
        }
    }
}

impl SlicePolicy for NixlPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Nixl
    }

    fn shape_plan(&self, plan: &mut TransferPlan, _s: &Segment, _d: &Segment, _t: &Topology) {
        if !restrict_to_rdma(plan) {
            return;
        }
        // Static bandwidth ranking, id as tie-break; keep the top-N.
        plan.candidates.sort_by(|a, b| {
            b.bw.partial_cmp(&a.bw)
                .unwrap()
                .then(a.rail.0.cmp(&b.rail.0))
        });
        plan.candidates.truncate(self.max_rails);
    }

    fn pick(
        &self,
        plan: &TransferPlan,
        viable: &[usize],
        _len: u64,
        _ctx: &SchedCtx,
    ) -> Option<usize> {
        if viable.is_empty() {
            return None;
        }
        if plan.transfer_len < self.multirail_threshold {
            // Below the threshold: single best NIC.
            return Some(viable[0]);
        }
        let k = self.cursor.fetch_add(1, Ordering::Relaxed) % viable.len();
        Some(viable[k])
    }

    fn failover(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::plan::build_plan;
    use crate::engine::sched::{SchedParams, SchedulerState};
    use crate::segment::Location;

    fn plan_of(c: &Cluster, len: u64) -> (TransferPlan, SchedulerState) {
        let a = c.segments.register_memory(Location::host(0, 0), 64 << 20).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 64 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, len).unwrap();
        let p = NixlPolicy::default();
        p.shape_plan(&mut plan, &a, &b, &c.topo);
        (
            plan,
            SchedulerState::new(c.topo.rails.len(), SchedParams::default()),
        )
    }

    #[test]
    fn keeps_two_best_nics() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let (plan, _) = plan_of(&c, 64 << 20);
        assert_eq!(plan.candidates.len(), 2);
    }

    #[test]
    fn small_blocks_single_rail() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let (plan, sched) = plan_of(&c, 4 << 20); // 4 MiB < threshold
        let p = NixlPolicy::default();
        let ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: crate::engine::TransferClass::Bulk,
        };
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            seen.insert(p.pick(&plan, &viable, 64 << 10, &ctx).unwrap());
        }
        assert_eq!(seen.len(), 1, "4 MiB must not trigger multi-rail");
    }

    #[test]
    fn large_blocks_stripe_over_the_pair() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let (plan, sched) = plan_of(&c, 64 << 20);
        let p = NixlPolicy::default();
        let ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: crate::engine::TransferClass::Bulk,
        };
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            seen.insert(p.pick(&plan, &viable, 1 << 20, &ctx).unwrap());
        }
        assert_eq!(seen.len(), 2);
    }
}
