//! State-blind round-robin striping — the Fig. 2 baseline: fixed-size
//! chunks dealt to NICs in order, no congestion signal, no failover.

use super::{restrict_to_rdma, PolicyKind, SlicePolicy};
use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::segment::Segment;
use crate::topology::Topology;
use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Default)]
pub struct RoundRobinPolicy {
    cursor: AtomicUsize,
}

impl SlicePolicy for RoundRobinPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::RoundRobin
    }

    fn shape_plan(&self, plan: &mut TransferPlan, _s: &Segment, _d: &Segment, _t: &Topology) {
        // Stripe over the NIC pool; ignore affinity entirely (state-blind).
        restrict_to_rdma(plan);
    }

    fn pick(
        &self,
        _plan: &TransferPlan,
        viable: &[usize],
        _len: u64,
        _ctx: &SchedCtx,
    ) -> Option<usize> {
        if viable.is_empty() {
            return None;
        }
        let k = self.cursor.fetch_add(1, Ordering::Relaxed) % viable.len();
        Some(viable[k])
    }

    fn failover(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::plan::build_plan;
    use crate::engine::sched::{SchedParams, SchedulerState};
    use crate::segment::Location;

    #[test]
    fn cycles_through_all_rails_evenly() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let a = c.segments.register_memory(Location::host(0, 0), 1 << 20).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
        let p = RoundRobinPolicy::default();
        p.shape_plan(&mut plan, &a, &b, &c.topo);
        assert_eq!(plan.candidates.len(), 8, "rdma only after shaping");
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: crate::engine::TransferClass::Bulk,
        };
        let mut counts = vec![0u32; plan.candidates.len()];
        for _ in 0..80 {
            counts[p.pick(&plan, &viable, 64 << 10, &ctx).unwrap()] += 1;
        }
        assert!(counts.iter().all(|&n| n == 10), "{counts:?}");
    }
}
