//! Mooncake Transfer Engine baseline, as characterized in §2.2 / §5.1:
//!
//! * commits to the RDMA stack at init — GPU↔GPU traffic **always** rides
//!   RDMA, never NVLink (the Table 2 behavioural difference);
//! * fixed GPU→NIC mapping: device buffers use the NIC on their own PCIe
//!   root complex ("tier-1 NIC dictates service time", Fig. 6);
//! * host buffers stripe with randomized selection among the NUMA-local
//!   (static-priority tier-1) NICs, ignoring instantaneous load (Fig. 9);
//! * no automatic cross-transport failover — path faults surface to the
//!   application (§2.3).

use super::{restrict_to_rdma, PolicyKind, SlicePolicy};
use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::segment::Segment;
use crate::topology::{Tier, Topology};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct MooncakePolicy {
    state: AtomicU64,
}

impl Default for MooncakePolicy {
    fn default() -> Self {
        MooncakePolicy {
            state: AtomicU64::new(0x9E3779B97F4A7C15),
        }
    }
}

impl MooncakePolicy {
    /// Randomized selection (xorshift on a shared counter) — "round-robin or
    /// hashing based solely on static NUMA priorities".
    fn rand(&self) -> u64 {
        let mut x = self.state.fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x
    }
}

impl SlicePolicy for MooncakePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::MooncakeTe
    }

    fn shape_plan(&self, plan: &mut TransferPlan, src: &Segment, dst: &Segment, _t: &Topology) {
        if !restrict_to_rdma(plan) {
            return; // no RDMA on this pair; leave whatever exists
        }
        if src.loc.is_device() || dst.loc.is_device() {
            // Fixed GPU-NIC mapping: only the root-local (tier-1) NIC.
            let dev_root = if src.loc.is_device() {
                src.loc.pcie_root()
            } else {
                dst.loc.pcie_root()
            };
            if let Some(root) = dev_root {
                let before = plan.candidates.len();
                plan.candidates.retain(|c| c.tier == Tier::T1);
                // tier-1 relative to the device == same root; keep exactly it.
                plan.candidates.truncate(1.min(plan.candidates.len()));
                if plan.candidates.is_empty() && before > 0 {
                    // Shouldn't happen on GPUDirect profiles; be permissive.
                }
                let _ = root;
            }
        } else {
            // Host buffers: static NUMA priority — NUMA-local NICs only.
            let has_t1 = plan.candidates.iter().any(|c| c.tier == Tier::T1);
            if has_t1 {
                plan.candidates.retain(|c| c.tier == Tier::T1);
            }
        }
    }

    fn pick(
        &self,
        _plan: &TransferPlan,
        viable: &[usize],
        _len: u64,
        _ctx: &SchedCtx,
    ) -> Option<usize> {
        if viable.is_empty() {
            return None;
        }
        Some(viable[(self.rand() % viable.len() as u64) as usize])
    }

    fn failover(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::plan::build_plan;
    use crate::segment::Location;

    #[test]
    fn gpu_traffic_never_uses_nvlink() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = c.segments.register_memory(Location::device(0, 1), 1 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
        assert!(plan.candidates.iter().any(|x| x.backend.name() == "nvlink_sim"));
        MooncakePolicy::default().shape_plan(&mut plan, &a, &b, &c.topo);
        assert!(plan.candidates.iter().all(|x| x.backend.name() == "rdma_sim"));
        // Fixed mapping: exactly the one root-local NIC.
        assert_eq!(plan.candidates.len(), 1);
        assert_eq!(plan.candidates[0].tier, Tier::T1);
    }

    #[test]
    fn host_buffers_stripe_numa_local() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 1), 1 << 20).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
        MooncakePolicy::default().shape_plan(&mut plan, &a, &b, &c.topo);
        assert_eq!(plan.candidates.len(), 4); // socket-1 NICs
        assert!(plan.candidates.iter().all(|x| x.tier == Tier::T1));
    }

    #[test]
    fn randomized_pick_covers_pool_unevenly_but_fully() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let sched = crate::engine::sched::SchedulerState::new(
            c.topo.rails.len(),
            crate::engine::sched::SchedParams::default(),
        );
        let a = c.segments.register_memory(Location::host(0, 0), 1 << 20).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
        let p = MooncakePolicy::default();
        p.shape_plan(&mut plan, &a, &b, &c.topo);
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        let ctx = SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: crate::engine::TransferClass::Bulk,
        };
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(p.pick(&plan, &viable, 64 << 10, &ctx).unwrap());
        }
        assert_eq!(seen.len(), viable.len());
    }
}
