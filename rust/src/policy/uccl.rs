//! UCCL-P2P baseline, as characterized in §5.1.3: each registered memory
//! region is bound to a single NIC (per-region pinning), so throughput is
//! capped at per-NIC limits and there is no cross-NIC aggregation.

use super::{restrict_to_rdma, PolicyKind, SlicePolicy};
use crate::engine::plan::TransferPlan;
use crate::engine::sched::SchedCtx;
use crate::segment::Segment;
use crate::topology::{Tier, Topology};

#[derive(Default)]
pub struct UcclPolicy;

impl SlicePolicy for UcclPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::UcclP2p
    }

    fn shape_plan(&self, plan: &mut TransferPlan, src: &Segment, _d: &Segment, _t: &Topology) {
        if !restrict_to_rdma(plan) {
            return;
        }
        // Deterministic region→NIC pinning: hash the source segment id over
        // its NUMA-local NICs (or the whole pool if none are local).
        let local: Vec<usize> = (0..plan.candidates.len())
            .filter(|&i| plan.candidates[i].tier == Tier::T1)
            .collect();
        let pool = if local.is_empty() {
            (0..plan.candidates.len()).collect::<Vec<_>>()
        } else {
            local
        };
        let pin = pool[(src.id.0 as usize) % pool.len()];
        let chosen = plan.candidates.swap_remove(pin);
        plan.candidates.clear();
        plan.candidates.push(chosen);
    }

    fn pick(
        &self,
        _plan: &TransferPlan,
        viable: &[usize],
        _len: u64,
        _ctx: &SchedCtx,
    ) -> Option<usize> {
        viable.first().copied()
    }

    fn failover(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::plan::build_plan;
    use crate::segment::Location;

    #[test]
    fn region_is_pinned_to_one_nic() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1 << 20).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
        UcclPolicy.shape_plan(&mut plan, &a, &b, &c.topo);
        assert_eq!(plan.candidates.len(), 1);
        assert_eq!(plan.candidates[0].tier, Tier::T1);
    }

    #[test]
    fn different_regions_may_pin_to_different_nics() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        let mut rails = std::collections::HashSet::new();
        for _ in 0..8 {
            let a = c.segments.register_memory(Location::host(0, 0), 1 << 20).unwrap();
            let mut plan = build_plan(&c.transports, &c.topo, &a, &b, 1 << 20).unwrap();
            UcclPolicy.shape_plan(&mut plan, &a, &b, &c.topo);
            rails.insert(plan.candidates[0].rail);
        }
        assert!(rails.len() > 1, "hashing should spread distinct regions");
    }
}
