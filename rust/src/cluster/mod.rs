//! The simulated cluster: topology + fabric + segment manager + transports,
//! wired together. One `Cluster` hosts all the "nodes" of a deployment; the
//! engine and benches borrow it.
//!
//! The cluster also anchors the **shared datapath** — the per-rail worker
//! threads and rings every engine instance enqueues into (see
//! [`crate::engine::datapath`]). It is created when the first engine comes
//! up and torn down (workers drained and joined) when its last owner —
//! the cluster or the last engine core — drops.
//! [`fleet`] builds the multi-engine deployment shape on top: one engine
//! per node over this shared substrate.

pub mod fleet;

use crate::engine::datapath::{DatapathConfig, SharedDatapath};
use crate::fabric::{Fabric, FabricConfig};
use crate::segment::SegmentManager;
use crate::topology::profile::build_profile;
use crate::topology::Topology;
use crate::transport::TransportRegistry;
use crate::Result;
use std::sync::{Arc, OnceLock};

pub use fleet::{CrossSiloConfig, Fleet, FleetConfig, FleetReport, WorkloadConfig};

pub struct Cluster {
    pub topo: Arc<Topology>,
    pub fabric: Arc<Fabric>,
    pub segments: Arc<SegmentManager>,
    pub transports: Arc<TransportRegistry>,
    /// Cluster-shared datapath, created by the first engine.
    datapath: OnceLock<Arc<SharedDatapath>>,
}

impl Cluster {
    /// Build a cluster from a named profile with the profile's default node
    /// count (2 — enough for inter-node paths).
    pub fn from_profile(name: &str) -> Result<Cluster> {
        Cluster::from_profile_nodes(name, 2, FabricConfig::default())
    }

    /// Build with explicit node count and fabric config.
    pub fn from_profile_nodes(name: &str, nodes: u16, cfg: FabricConfig) -> Result<Cluster> {
        Self::from_topology(Arc::new(build_profile(name, nodes)?), cfg)
    }

    /// Build from a custom JSON profile file (see `topology::json_profile`).
    pub fn from_profile_file(path: impl AsRef<std::path::Path>, cfg: FabricConfig) -> Result<Cluster> {
        Self::from_topology(
            Arc::new(crate::topology::json_profile::load_profile_file(path.as_ref())?),
            cfg,
        )
    }

    /// Build from an already-constructed topology.
    pub fn from_topology(topo: Arc<Topology>, cfg: FabricConfig) -> Result<Cluster> {
        let fabric = Arc::new(Fabric::new(&topo, cfg));
        let segments = Arc::new(SegmentManager::new());
        let transports = Arc::new(TransportRegistry::load_all(&topo, Arc::clone(&segments)));
        Ok(Cluster {
            topo,
            fabric,
            segments,
            transports,
            datapath: OnceLock::new(),
        })
    }

    /// The cluster-shared datapath, created on first call. The first
    /// caller's `DatapathConfig` fixes ring capacity and wakeup knobs for
    /// every engine sharing this cluster.
    pub fn shared_datapath(&self, cfg: DatapathConfig) -> Arc<SharedDatapath> {
        Arc::clone(
            self.datapath
                .get_or_init(|| SharedDatapath::new(&self.topo, cfg)),
        )
    }

    /// The shared datapath, if an engine has brought it up yet.
    pub fn datapath(&self) -> Option<&Arc<SharedDatapath>> {
        self.datapath.get()
    }
}

// No `Drop for Cluster`: the shared datapath tears itself down (workers
// drained and joined) when its *last* owning `Arc` goes — the cluster's
// `OnceLock` plus every engine core hold one, so an engine that outlives
// its `Cluster` struct (a common test-helper shape) keeps working.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_builds_and_exposes_parts() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        assert_eq!(c.topo.nodes.len(), 2);
        assert_eq!(c.fabric.rails.len(), c.topo.rails.len());
        assert!(!c.transports.all().is_empty());
    }

    #[test]
    fn custom_node_count() {
        let c = Cluster::from_profile_nodes("legacy_tcp", 3, FabricConfig::default()).unwrap();
        assert_eq!(c.topo.nodes.len(), 3);
    }
}
