//! Fleet-scale deployment shape: one engine per node over one shared
//! fabric (ROADMAP "Fabric scaling"; §2.3's cluster-scale claim).
//!
//! A [`Fleet`] stands up N engine instances — one per topology node, the
//! way real disaggregated deployments run one transfer engine per host —
//! all sharing a single [`Cluster`]: same fabric, same per-rail workers
//! (`engine::datapath::SharedDatapath`), same segment manager. The fleet
//! sizes the shared substrate for its engine count: queued-bytes counter
//! shards ≥ engines (each engine writes a private cache-padded stripe, see
//! `Fabric::register_engine`) and ring capacity scaled to the number of
//! producers pushing into each rail's rings.
//!
//! [`Fleet::run_workload`] drives the production traffic mix the paper
//! motivates: **Latency**-class KV-fetches (each engine pulls KV blocks
//! from random peers — the pull dispatches onto the *owner's* rails, so
//! every node's NICs carry slices from many engines at once) multiplexed
//! with **Bulk**-class checkpoint pushes to the ring neighbour. The report
//! carries per-engine goodput (fairness), per-class transfer latency, and
//! the contention counters the datapath work is judged by.

use super::Cluster;
use crate::engine::{EngineConfig, TentEngine, TransferClass, TransferReq};
use crate::fabric::FabricConfig;
use crate::policy::PolicyKind;
use crate::segment::{Location, SegmentId};
use crate::util::clock;
use crate::util::hist::Histogram;
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use crate::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fleet deployment knobs.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Topology profile name (node-count-parametric).
    pub profile: String,
    /// Node count == engine count.
    pub nodes: u16,
    /// Scheduling policy for every engine.
    pub policy: PolicyKind,
    /// Fabric knobs. `counter_shards` is overridden from
    /// `sharded_counters`; set `time_compression` to taste.
    pub fabric: FabricConfig,
    /// Engine template. Per-engine copies get distinct seeds;
    /// `ring_capacity` is re-scaled for the engine count.
    pub engine: EngineConfig,
    /// `true` (default): stripe the per-rail queued-bytes counters across
    /// engines. `false`: the single-counter baseline (`fig_scaling`'s
    /// ablation axis).
    pub sharded_counters: bool,
    /// NUMA domains for shard placement (`Fabric::register_engine` maps
    /// each engine's counter stripe into its domain's shard block, see
    /// `ShardedU64::shard_of_domain`). 1 (default) keeps the historical
    /// round-robin placement.
    pub numa_domains: usize,
}

impl FleetConfig {
    /// FNV digest of the deployment-shaping knobs (canonical JSON via
    /// `util::canon`) — the config identity the report headers print, so
    /// two result files are comparable at a glance.
    pub fn digest(&self) -> u64 {
        crate::util::canon::digest_json(&Json::obj(vec![
            ("profile", Json::str(&self.profile)),
            ("nodes", Json::num(self.nodes as f64)),
            ("policy", Json::str(self.policy.name())),
            ("sharded_counters", Json::Bool(self.sharded_counters)),
            ("numa_domains", Json::num(self.numa_domains as f64)),
            ("time_compression", Json::num(self.fabric.time_compression)),
        ]))
    }

    /// A fleet of `nodes` engines on `profile`, with bench-friendly time
    /// compression.
    pub fn new(profile: &str, nodes: u16) -> FleetConfig {
        FleetConfig {
            profile: profile.to_string(),
            nodes,
            policy: PolicyKind::Tent,
            fabric: FabricConfig {
                time_compression: 20.0,
                ..Default::default()
            },
            engine: EngineConfig::default(),
            sharded_counters: true,
            numa_domains: 1,
        }
    }
}

/// One engine per node over a single shared fabric.
///
/// Field order matters: engines drop (and drain their in-flight slices)
/// against still-running rail workers; the cluster's datapath handle goes
/// last, tearing the workers down.
pub struct Fleet {
    engines: Vec<Arc<TentEngine>>,
    pub cluster: Cluster,
    pub config: FleetConfig,
}

impl Fleet {
    pub fn new(mut config: FleetConfig) -> Result<Fleet> {
        let nodes = config.nodes.max(1);
        config.nodes = nodes;
        // Size the shared substrate for the engine count.
        config.fabric.counter_shards = if config.sharded_counters {
            (nodes as usize).next_power_of_two()
        } else {
            1
        };
        config.fabric.numa_domains = config.numa_domains.max(1);
        // Shared per-rail rings: capacity scales with the number of engines
        // pushing into them (floor absorbs single-engine bursts, ceiling
        // bounds memory — a ring slot is ~128 B, two lanes per rail, and
        // hundreds of rails go live on big fleets; rails spawn lazily).
        config.engine.ring_capacity = (32 * nodes as usize).clamp(1024, 4096);
        config.engine.policy = config.policy;
        let cluster = Cluster::from_profile_nodes(&config.profile, nodes, config.fabric.clone())?;
        let engines = (0..nodes)
            .map(|n| {
                let mut ecfg = config.engine.clone();
                ecfg.seed = config
                    .engine
                    .seed
                    .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(n as u64 + 1));
                Ok(Arc::new(TentEngine::new(&cluster, ecfg)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Fleet {
            engines,
            cluster,
            config,
        })
    }

    pub fn nodes(&self) -> usize {
        self.engines.len()
    }

    /// The engine homed on `node`.
    pub fn engine(&self, node: u16) -> &Arc<TentEngine> {
        &self.engines[node as usize]
    }

    pub fn engines(&self) -> &[Arc<TentEngine>] {
        &self.engines
    }

    /// Total payload bytes carried by every rail (per-NIC byte counters,
    /// §5.1.3) — the conservation side of the slice ledger.
    pub fn carried_bytes(&self) -> u64 {
        self.cluster.fabric.byte_counters().iter().map(|&(_, b)| b).sum()
    }

    /// Serve an arrival-driven session workload with the continuous-batching
    /// scheduler (`serving::batching`): one scheduling lane per engine,
    /// engine `j` running `models[j % models.len()]`.
    pub fn serve_sessions(
        &self,
        models: &[Arc<dyn crate::runtime::ModelExecutor>],
        sessions: &[crate::serving::SessionScript],
        cfg: &crate::serving::BatchConfig,
    ) -> Result<crate::serving::BatchReport> {
        crate::serving::serve_fleet(self, models, sessions, cfg)
    }

    /// Merged slice-latency histogram for one QoS class across all rails.
    pub fn class_slice_latency(&self, class: TransferClass) -> Histogram {
        let h = Histogram::new();
        for r in &self.cluster.fabric.rails {
            h.merge(&r.class_latency[class.index()]);
        }
        h
    }

    /// Execute a compiled transfer plan (see [`crate::plan`]): waves of
    /// stages whose every op was decided at compile time, with a
    /// deterministic replay journal in the returned report.
    pub fn run_plan(&self, dag: &crate::plan::PlanDag) -> Result<crate::plan::PlanReport> {
        crate::plan::exec::run(self, dag)
    }

    /// Drive the mixed KV-fetch / checkpoint workload across the fleet.
    pub fn run_workload(&self, cfg: &WorkloadConfig) -> Result<FleetReport> {
        let n = self.nodes();
        let window = cfg.window.max(1);
        // Per-node KV store: fetch source for every peer plus checkpoint
        // source; sized so random slice-aligned reads fit.
        let store_len = (cfg.bulk_block.max(cfg.latency_block)) * 2;
        let stores: Vec<SegmentId> = (0..n)
            .map(|i| self.engines[i].register_segment(Location::host(i as u16, 0), store_len))
            .collect::<Result<_>>()?;
        // Checkpoint destination: each engine pushes to its ring neighbour.
        // One window of slots per submitter thread, so concurrent bulk
        // writes (across submitters and within a window) stay disjoint.
        let submitters = cfg.submitters_per_engine.max(1);
        let ckpt_dsts: Vec<SegmentId> = (0..n)
            .map(|j| {
                let peer = ((j + 1) % n) as u16;
                self.engines[j].register_segment(
                    Location::host(peer, 0),
                    cfg.bulk_block * (window * submitters) as u64,
                )
            })
            .collect::<Result<_>>()?;

        let per_engine_bytes: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let lat_hist = Histogram::new();
        let bulk_hist = Histogram::new();
        let total_batches = AtomicU64::new(0);
        let failed_batches = AtomicU64::new(0);
        let deadline = clock::now_ns() + cfg.duration.as_nanos() as u64;

        let start = clock::now_ns();
        std::thread::scope(|scope| {
            for (j, engine) in self.engines.iter().enumerate() {
                for t in 0..cfg.submitters_per_engine.max(1) {
                    let engine = Arc::clone(engine);
                    let stores = &stores;
                    let ckpt_dsts = &ckpt_dsts;
                    let per_engine_bytes = &per_engine_bytes;
                    let lat_hist = &lat_hist;
                    let bulk_hist = &bulk_hist;
                    let total_batches = &total_batches;
                    let failed_batches = &failed_batches;
                    scope.spawn(move || {
                        let mut rng = Pcg64::new(cfg.seed ^ (((j as u64) << 8) | t as u64), 0xF1EE7);
                        // Private fetch scratch, one slot per window entry:
                        // in-flight fetches never overlap.
                        let scratch = match engine.register_segment(
                            Location::host(j as u16, 0),
                            cfg.latency_block * window as u64,
                        ) {
                            Ok(s) => s,
                            Err(_) => return, // cluster shutting down
                        };
                        let mut inflight: VecDeque<Pending> = VecDeque::with_capacity(window);
                        let mut ops: u64 = 0;
                        let mut reap = |engine: &TentEngine, q: &mut VecDeque<Pending>| {
                            if let Some(p) = q.pop_front() {
                                let ok = engine
                                    .wait_any(p.batch, Duration::from_secs(120))
                                    .map(|st| st.ok())
                                    .unwrap_or(false);
                                let _ = engine.release_batch(p.batch);
                                total_batches.fetch_add(1, Ordering::Relaxed);
                                if ok {
                                    let dt = clock::now_ns().saturating_sub(p.t0);
                                    match p.class {
                                        TransferClass::Latency => lat_hist.record(dt),
                                        TransferClass::Bulk => bulk_hist.record(dt),
                                    }
                                    per_engine_bytes[j].fetch_add(p.bytes, Ordering::Relaxed);
                                } else {
                                    failed_batches.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        };
                        while clock::now_ns() < deadline {
                            let slot = ops % window as u64;
                            let bulk = cfg.bulk_every > 0
                                && ops % cfg.bulk_every as u64 == cfg.bulk_every as u64 - 1;
                            let (req, class, bytes) = if bulk {
                                // Checkpoint push to the ring neighbour,
                                // into this submitter's own slot window.
                                let bulk_slot = (t * window) as u64 + slot;
                                let req = TransferReq::write(
                                    stores[j],
                                    0,
                                    ckpt_dsts[j],
                                    bulk_slot * cfg.bulk_block,
                                    cfg.bulk_block,
                                );
                                (req, TransferClass::Bulk, cfg.bulk_block)
                            } else {
                                // KV fetch: pull a block from a random
                                // peer's store. The pull rides the *peer's*
                                // rails — the cross-engine sharing under
                                // test.
                                let peer = if n == 1 {
                                    0
                                } else {
                                    let r = rng.gen_range((n - 1) as u64) as usize;
                                    if r >= j {
                                        r + 1
                                    } else {
                                        r
                                    }
                                };
                                let src_slots = store_len / cfg.latency_block;
                                let off = rng.gen_range(src_slots) * cfg.latency_block;
                                let req = TransferReq::read(
                                    stores[peer],
                                    off,
                                    scratch,
                                    slot * cfg.latency_block,
                                    cfg.latency_block,
                                )
                                .class(TransferClass::Latency);
                                (req, TransferClass::Latency, cfg.latency_block)
                            };
                            let batch = engine.allocate_batch();
                            let t0 = clock::now_ns();
                            if engine.submit(batch, &[req]).is_err() {
                                let _ = engine.release_batch(batch);
                                break; // engine/cluster shutting down
                            }
                            inflight.push_back(Pending {
                                batch,
                                t0,
                                class,
                                bytes,
                            });
                            if inflight.len() >= window {
                                reap(&engine, &mut inflight);
                            }
                            ops += 1;
                        }
                        while !inflight.is_empty() {
                            reap(&engine, &mut inflight);
                        }
                    });
                }
            }
        });
        let wall_ns = clock::now_ns().saturating_sub(start);

        Ok(FleetReport {
            nodes: n,
            seed: cfg.seed,
            config_digest: self.config.digest(),
            wall_ns,
            per_engine_bytes: per_engine_bytes.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            latency_hist: lat_hist,
            bulk_hist,
            total_batches: total_batches.load(Ordering::Relaxed),
            failed_batches: failed_batches.load(Ordering::Relaxed),
            healing_hist: Histogram::new(),
            recovery_hist: Histogram::new(),
        })
    }

    /// Prefill nodes of a heterogeneous fleet: every node carrying an
    /// NVLink-connected GPU pool (the GPU prefill silo).
    pub fn prefill_nodes(&self) -> Vec<u16> {
        self.silo_nodes(crate::topology::FabricKind::NvLink)
    }

    /// Decode nodes: every node carrying a UB-connected NPU pool (the
    /// accelerator decode silo).
    pub fn decode_nodes(&self) -> Vec<u16> {
        self.silo_nodes(crate::topology::FabricKind::AscendUb)
    }

    fn silo_nodes(&self, fabric: crate::topology::FabricKind) -> Vec<u16> {
        (0..self.nodes() as u16)
            .filter(|&i| {
                self.cluster
                    .topo
                    .node_in_fabric(crate::topology::NodeId(i), fabric)
            })
            .collect()
    }

    /// Drive the disaggregated prefill→decode KV handoff across a mixed
    /// hardware fleet: each prefill (GPU) node streams KV blocks from
    /// device memory to its round-robin-paired decode (NPU) node's device
    /// memory, with a pipelining window per pair. On fleets whose silos
    /// share no direct fabric (e.g. the `silo_fleet` profile) every handoff
    /// rides a planned k-hop relay route through a gateway — the spraying,
    /// QoS, and chaos machinery apply to each hop unchanged.
    pub fn run_cross_silo(&self, cfg: &CrossSiloConfig) -> Result<FleetReport> {
        let prefill = self.prefill_nodes();
        let decode = self.decode_nodes();
        if prefill.is_empty() || decode.is_empty() {
            return Err(crate::Error::Config(format!(
                "cross-silo workload needs both silos: {} prefill (NVLink) and {} decode (UB) nodes",
                prefill.len(),
                decode.len()
            )));
        }
        let window = cfg.window.max(1);
        let n = self.nodes();
        // Pair prefill→decode round-robin; each pair gets private device
        // segments sized one window of KV blocks (in-flight writes stay
        // disjoint).
        let pairs: Vec<(u16, u16)> = prefill
            .iter()
            .enumerate()
            .map(|(k, &p)| (p, decode[k % decode.len()]))
            .collect();
        let span = cfg.block * window as u64;
        let segs: Vec<(SegmentId, SegmentId)> = pairs
            .iter()
            .map(|&(p, d)| -> Result<(SegmentId, SegmentId)> {
                let src = self.engines[p as usize]
                    .register_segment(Location::device(p, 0), span)?;
                let dst = self.engines[p as usize]
                    .register_segment(Location::device(d, 0), span)?;
                Ok((src, dst))
            })
            .collect::<Result<_>>()?;

        let per_engine_bytes: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let hist = Histogram::new();
        let total_batches = AtomicU64::new(0);
        let failed_batches = AtomicU64::new(0);
        let deadline = clock::now_ns() + cfg.duration.as_nanos() as u64;

        let start = clock::now_ns();
        std::thread::scope(|scope| {
            for (k, &(p, _d)) in pairs.iter().enumerate() {
                let engine = Arc::clone(&self.engines[p as usize]);
                let (src, dst) = segs[k];
                let per_engine_bytes = &per_engine_bytes;
                let hist = &hist;
                let total_batches = &total_batches;
                let failed_batches = &failed_batches;
                scope.spawn(move || {
                    let mut inflight: VecDeque<Pending> = VecDeque::with_capacity(window);
                    let mut ops: u64 = 0;
                    let mut reap = |engine: &TentEngine, q: &mut VecDeque<Pending>| {
                        if let Some(pe) = q.pop_front() {
                            let ok = engine
                                .wait_any(pe.batch, Duration::from_secs(120))
                                .map(|st| st.ok())
                                .unwrap_or(false);
                            let _ = engine.release_batch(pe.batch);
                            total_batches.fetch_add(1, Ordering::Relaxed);
                            if ok {
                                hist.record(clock::now_ns().saturating_sub(pe.t0));
                                per_engine_bytes[p as usize]
                                    .fetch_add(pe.bytes, Ordering::Relaxed);
                            } else {
                                failed_batches.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    };
                    while clock::now_ns() < deadline {
                        let slot = ops % window as u64;
                        let req = TransferReq::write(
                            src,
                            slot * cfg.block,
                            dst,
                            slot * cfg.block,
                            cfg.block,
                        )
                        .class(cfg.class);
                        let batch = engine.allocate_batch();
                        let t0 = clock::now_ns();
                        if engine.submit(batch, &[req]).is_err() {
                            let _ = engine.release_batch(batch);
                            break;
                        }
                        inflight.push_back(Pending {
                            batch,
                            t0,
                            class: cfg.class,
                            bytes: cfg.block,
                        });
                        if inflight.len() >= window {
                            reap(&engine, &mut inflight);
                        }
                        ops += 1;
                    }
                    while !inflight.is_empty() {
                        reap(&engine, &mut inflight);
                    }
                });
            }
        });
        let wall_ns = clock::now_ns().saturating_sub(start);

        let (latency_hist, bulk_hist) = match cfg.class {
            TransferClass::Latency => (hist, Histogram::new()),
            TransferClass::Bulk => (Histogram::new(), hist),
        };
        Ok(FleetReport {
            nodes: n,
            seed: cfg.seed,
            config_digest: self.config.digest(),
            wall_ns,
            per_engine_bytes: per_engine_bytes.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            latency_hist,
            bulk_hist,
            total_batches: total_batches.load(Ordering::Relaxed),
            failed_batches: failed_batches.load(Ordering::Relaxed),
            healing_hist: Histogram::new(),
            recovery_hist: Histogram::new(),
        })
    }
}

/// One outstanding batch in a submitter's pipeline window.
struct Pending {
    batch: crate::engine::BatchId,
    t0: u64,
    class: TransferClass,
    bytes: u64,
}

/// Workload generator knobs (see [`Fleet::run_workload`]).
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Measured wall-clock duration (submission stops, then drains).
    pub duration: Duration,
    /// KV-fetch block size (Latency class).
    pub latency_block: u64,
    /// Checkpoint block size (Bulk class).
    pub bulk_block: u64,
    /// Every `bulk_every`-th op is a checkpoint push (0 disables bulk).
    pub bulk_every: usize,
    /// Submission threads per engine.
    pub submitters_per_engine: usize,
    /// Outstanding batches per submitter (pipelining depth).
    pub window: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            duration: Duration::from_millis(1500),
            latency_block: 256 << 10,
            bulk_block: 2 << 20,
            bulk_every: 4,
            submitters_per_engine: 2,
            window: 4,
            seed: 0xF1EE7,
        }
    }
}

/// Cross-silo prefill→decode handoff knobs (see [`Fleet::run_cross_silo`]).
#[derive(Clone, Debug)]
pub struct CrossSiloConfig {
    /// Measured wall-clock duration (submission stops, then drains).
    pub duration: Duration,
    /// KV block size per handoff.
    pub block: u64,
    /// Outstanding batches per prefill→decode pair (pipelining depth).
    pub window: usize,
    /// QoS class the handoff rides (KV delivery is latency-sensitive by
    /// default — decode stalls until the blocks land).
    pub class: TransferClass,
    pub seed: u64,
}

impl Default for CrossSiloConfig {
    fn default() -> Self {
        CrossSiloConfig {
            duration: Duration::from_millis(800),
            block: 256 << 10,
            window: 4,
            class: TransferClass::Latency,
            seed: 0x51_10,
        }
    }
}

/// Aggregated result of one fleet workload run.
pub struct FleetReport {
    pub nodes: usize,
    /// Workload seed the run was driven with (reproducibility handle).
    pub seed: u64,
    /// [`FleetConfig::digest`] of the fleet that produced this report.
    pub config_digest: u64,
    pub wall_ns: u64,
    /// Completed payload bytes credited to each engine.
    pub per_engine_bytes: Vec<u64>,
    /// Transfer-completion latency, Latency class (KV fetches).
    pub latency_hist: Histogram,
    /// Transfer-completion latency, Bulk class (checkpoint pushes).
    pub bulk_hist: Histogram,
    pub total_batches: u64,
    pub failed_batches: u64,
    /// Per-fault-event healing latency (injection → first rerouted-slice
    /// completion on a surviving rail). Empty for plain workload runs;
    /// populated by `chaos::run`, which merges the healing probe's
    /// measurements into the report it returns.
    pub healing_hist: Histogram,
    /// Per-fault-event goodput-recovery latency (injection → aggregate
    /// carried-bytes rate back above 90% of the pre-fault rate). Empty for
    /// plain workload runs; populated by `chaos::run`.
    pub recovery_hist: Histogram,
}

impl FleetReport {
    /// One-line run identity printed above every pretty-printed report:
    /// the seed and config digest that make the numbers reproducible.
    pub fn header(&self) -> String {
        format!(
            "nodes={} seed={:#x} config={}",
            self.nodes,
            self.seed,
            crate::util::canon::digest_hex(self.config_digest)
        )
    }

    /// Aggregate goodput over the whole fleet (bytes/sec, sim units).
    pub fn aggregate_goodput(&self) -> f64 {
        let total: u64 = self.per_engine_bytes.iter().sum();
        total as f64 / (self.wall_ns.max(1) as f64 / 1e9)
    }

    /// Per-engine fairness: min/max completed-bytes ratio in [0, 1];
    /// 1 = perfectly even, 0 = someone starved.
    pub fn fairness(&self) -> f64 {
        let min = self.per_engine_bytes.iter().copied().min().unwrap_or(0);
        let max = self.per_engine_bytes.iter().copied().max().unwrap_or(0);
        if max == 0 {
            return 0.0;
        }
        min as f64 / max as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_builds_one_engine_per_node() {
        let f = Fleet::new(FleetConfig::new("h800_hgx", 4)).unwrap();
        assert_eq!(f.nodes(), 4);
        assert_eq!(f.cluster.topo.nodes.len(), 4);
        // Engines registered consecutive fabric shards.
        let shards = f.cluster.fabric.config.counter_shards;
        assert_eq!(shards, 4);
    }

    #[test]
    fn small_fleet_workload_moves_bytes_fairly() {
        let f = Fleet::new(FleetConfig::new("h800_hgx", 4)).unwrap();
        let w = WorkloadConfig {
            duration: Duration::from_millis(300),
            submitters_per_engine: 1,
            ..Default::default()
        };
        let r = f.run_workload(&w).unwrap();
        assert_eq!(r.failed_batches, 0, "no failures without injection");
        assert!(r.total_batches >= 4, "every engine submitted");
        // The report names its reproducibility handle.
        assert_eq!(r.seed, w.seed);
        assert_eq!(r.config_digest, f.config.digest());
        assert!(r.header().contains("seed=0x") && r.header().contains("config="));
        assert!(r.per_engine_bytes.iter().all(|&b| b > 0), "{:?}", r.per_engine_bytes);
        assert!(r.aggregate_goodput() > 0.0);
        assert!(r.fairness() > 0.0);
        // Conservation: without injection nothing fails, so every engine's
        // dispatch/complete ledgers agree exactly.
        for e in f.engines() {
            let s = e.stats();
            assert_eq!(s.slices_completed, s.slices_dispatched, "{s:?}");
            assert_eq!(s.permanent_failures, 0, "{s:?}");
        }
    }

    #[test]
    fn silo_fleet_splits_into_prefill_and_decode_nodes() {
        let f = Fleet::new(FleetConfig::new("silo_fleet", 6)).unwrap();
        assert_eq!(f.prefill_nodes(), vec![0, 3]);
        assert_eq!(f.decode_nodes(), vec![1, 4]);
    }

    #[test]
    fn cross_silo_handoff_relays_through_gateways() {
        let f = Fleet::new(FleetConfig::new("silo_fleet", 6)).unwrap();
        let cfg = CrossSiloConfig {
            duration: Duration::from_millis(400),
            block: 64 << 10,
            window: 2,
            ..Default::default()
        };
        let r = f.run_cross_silo(&cfg).unwrap();
        assert_eq!(r.failed_batches, 0, "no failures without injection");
        assert!(r.total_batches >= 2, "both pairs submitted");
        // Prefill engines carried the handoffs; decode engines idle.
        assert!(r.per_engine_bytes[0] > 0 && r.per_engine_bytes[3] > 0);
        assert_eq!(r.per_engine_bytes[1] + r.per_engine_bytes[4], 0);
        // The silos share no direct fabric, so every byte bounced through a
        // gateway: the relay ledger must show traffic and balance (every
        // staged byte forwarded, none stranded) at each gateway node.
        let moved: u64 = r.per_engine_bytes.iter().sum();
        let mut relayed = 0u64;
        for gw in [2u16, 5] {
            let (inb, outb) = f.cluster.fabric.relay_bytes(crate::topology::NodeId(gw));
            assert_eq!(inb, outb, "gateway {gw} relay ledger imbalanced");
            relayed += inb;
        }
        assert!(
            relayed >= moved,
            "relayed {relayed} < completed {moved}: some handoff skipped the gateways"
        );
        // Queues fully drained after the run.
        for rail in &f.cluster.fabric.rails {
            assert_eq!(rail.queued_bytes(), 0, "{} leaked queue", rail.id);
        }
    }

    #[test]
    fn cross_silo_on_homogeneous_fleet_is_a_config_error() {
        let f = Fleet::new(FleetConfig::new("h800_hgx", 2)).unwrap();
        let err = f.run_cross_silo(&CrossSiloConfig::default()).unwrap_err();
        assert!(matches!(err, crate::Error::Config(_)), "{err:?}");
    }

    #[test]
    fn numa_domain_fleet_runs_clean() {
        let mut cfg = FleetConfig::new("h800_hgx", 4);
        cfg.numa_domains = 2;
        let f = Fleet::new(cfg).unwrap();
        assert_eq!(f.cluster.fabric.config.numa_domains, 2);
        let w = WorkloadConfig {
            duration: Duration::from_millis(200),
            submitters_per_engine: 1,
            ..Default::default()
        };
        let r = f.run_workload(&w).unwrap();
        assert_eq!(r.failed_batches, 0);
        // Domain-blocked shard placement must not break queue conservation.
        for rail in &f.cluster.fabric.rails {
            assert_eq!(rail.queued_bytes(), 0, "{} leaked queue", rail.id);
        }
    }

    #[test]
    fn single_counter_baseline_still_correct() {
        let mut cfg = FleetConfig::new("legacy_tcp", 3);
        cfg.sharded_counters = false;
        let f = Fleet::new(cfg).unwrap();
        assert_eq!(f.cluster.fabric.config.counter_shards, 1);
        let w = WorkloadConfig {
            duration: Duration::from_millis(200),
            latency_block: 64 << 10,
            bulk_block: 256 << 10,
            submitters_per_engine: 1,
            ..Default::default()
        };
        let r = f.run_workload(&w).unwrap();
        assert_eq!(r.failed_batches, 0);
        // Queues fully drained after the run.
        for rail in &f.cluster.fabric.rails {
            assert_eq!(rail.queued_bytes(), 0, "{} leaked queue", rail.id);
        }
    }
}
