//! TEBench — the §5.1.3 microbenchmark harness (NIXLBench-inspired).
//!
//! Issues repeated synchronous batched transfer requests from multiple
//! submission threads with configurable block size, batch size, and thread
//! count; reports goodput and completion-latency percentiles plus per-rail
//! byte counters. Every figure bench (`rust/benches/fig*.rs`) is a thin
//! driver over this module.

use crate::engine::{TentEngine, TransferOp, TransferReq};
use crate::segment::SegmentId;
use crate::util::clock;
use crate::util::hist::Histogram;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// One submission thread's endpoints.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPair {
    pub src: SegmentId,
    pub dst: SegmentId,
    /// Segment capacity (offsets cycle within it).
    pub seg_len: u64,
}

/// Bench knobs.
#[derive(Clone, Debug)]
pub struct TeBenchConfig {
    pub block_size: u64,
    /// Transfers per submitted batch.
    pub batch_size: usize,
    /// Iterations (batches) per thread, measured.
    pub iters: usize,
    /// Warmup batches per thread (not measured).
    pub warmup: usize,
    pub op: TransferOp,
    /// Overall wall-clock cap; threads stop early when exceeded.
    pub time_limit: Duration,
}

impl Default for TeBenchConfig {
    fn default() -> Self {
        TeBenchConfig {
            block_size: 1 << 20,
            batch_size: 1,
            iters: 32,
            warmup: 2,
            op: TransferOp::Write,
            time_limit: Duration::from_secs(30),
        }
    }
}

/// Aggregated result.
pub struct TeBenchResult {
    pub bytes_moved: u64,
    pub wall_ns: u64,
    /// Per-batch completion latency (ns).
    pub latency: Histogram,
    pub batches: u64,
    pub failed_batches: u64,
}

impl TeBenchResult {
    /// Goodput in bytes/sec (sim units).
    pub fn throughput(&self) -> f64 {
        self.bytes_moved as f64 / (self.wall_ns as f64 / 1e9)
    }
    /// Paper-style Gbps (sim units × 8).
    pub fn gbps(&self) -> f64 {
        self.throughput() * 8.0 / 1e9
    }
}

/// Run the bench: each `pairs[i]` gets one submission thread.
pub fn run(engine: &Arc<TentEngine>, pairs: &[ThreadPair], cfg: &TeBenchConfig) -> Result<TeBenchResult> {
    let latency = Arc::new(Histogram::new());
    let bytes = Arc::new(AtomicU64::new(0));
    let batches = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let deadline = clock::now_ns() + cfg.time_limit.as_nanos() as u64;

    let start = clock::now_ns();
    std::thread::scope(|scope| {
        for pair in pairs {
            let engine = Arc::clone(engine);
            let latency = Arc::clone(&latency);
            let bytes = Arc::clone(&bytes);
            let batches = Arc::clone(&batches);
            let failed = Arc::clone(&failed);
            let cfg = cfg.clone();
            let pair = *pair;
            scope.spawn(move || {
                let slots = (pair.seg_len / cfg.block_size).max(1);
                let mut slot = 0u64;
                let mut make_batch = |measure: bool| {
                    let reqs: Vec<TransferReq> = (0..cfg.batch_size)
                        .map(|_| {
                            let off = (slot % slots) * cfg.block_size;
                            slot += 1;
                            match cfg.op {
                                TransferOp::Write => {
                                    TransferReq::write(pair.src, off, pair.dst, off, cfg.block_size)
                                }
                                TransferOp::Read => {
                                    TransferReq::read(pair.src, off, pair.dst, off, cfg.block_size)
                                }
                            }
                        })
                        .collect();
                    let t0 = clock::now_ns();
                    let b = engine.allocate_batch();
                    let ok = engine.submit(b, &reqs).is_ok()
                        && engine.wait(b, Duration::from_secs(120)).is_ok();
                    let _ = engine.release_batch(b);
                    if measure {
                        let dt = clock::now_ns() - t0;
                        latency.record(dt);
                        batches.fetch_add(1, Ordering::Relaxed);
                        if ok {
                            bytes.fetch_add(
                                cfg.block_size * cfg.batch_size as u64,
                                Ordering::Relaxed,
                            );
                        } else {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                };
                for _ in 0..cfg.warmup {
                    make_batch(false);
                }
                for _ in 0..cfg.iters {
                    if clock::now_ns() > deadline {
                        break;
                    }
                    make_batch(true);
                }
            });
        }
    });
    let wall_ns = clock::now_ns() - start;

    Ok(TeBenchResult {
        bytes_moved: bytes.load(Ordering::Relaxed),
        wall_ns,
        latency: Arc::try_unwrap(latency).unwrap_or_else(|a| {
            let h = Histogram::new();
            h.merge(&a);
            h
        }),
        batches: batches.load(Ordering::Relaxed),
        failed_batches: failed.load(Ordering::Relaxed),
    })
}

/// Pretty row formatting used by the figure benches.
pub fn fmt_row(label: &str, r: &TeBenchResult) -> String {
    format!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>8}",
        label,
        crate::util::fmt_bw(r.throughput()),
        crate::util::fmt_ns(r.latency.p50()),
        crate::util::fmt_ns(r.latency.p90()),
        crate::util::fmt_ns(r.latency.p99()),
        r.batches,
    )
}

pub fn header() -> String {
    format!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>8}",
        "config", "goodput", "p50", "p90", "p99", "batches"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::EngineConfig;
    use crate::segment::Location;

    #[test]
    fn tebench_moves_expected_bytes() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let e = Arc::new(TentEngine::new(&c, EngineConfig::default()).unwrap());
        let len = 4u64 << 20;
        let pairs: Vec<ThreadPair> = (0..2)
            .map(|i| {
                let src = e.register_segment(Location::host(0, i as u8 % 2), len).unwrap();
                let dst = e.register_segment(Location::host(1, i as u8 % 2), len).unwrap();
                ThreadPair { src, dst, seg_len: len }
            })
            .collect();
        let cfg = TeBenchConfig {
            block_size: 256 << 10,
            batch_size: 2,
            iters: 4,
            warmup: 1,
            ..Default::default()
        };
        let r = run(&e, &pairs, &cfg).unwrap();
        assert_eq!(r.failed_batches, 0);
        assert_eq!(r.batches, 2 * 4);
        assert_eq!(r.bytes_moved, 2 * 4 * 2 * (256 << 10));
        assert!(r.throughput() > 0.0);
        assert!(r.latency.p99() >= r.latency.p50());
    }
}
