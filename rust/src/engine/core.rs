//! Shared engine state referenced by submission threads, rail workers, and
//! the maintenance thread.

use super::datapath::SharedDatapath;
use super::sched::{SchedCtx, SchedulerState};
use super::telemetry::EngineStats;
use super::TransferClass;
use crate::fabric::Fabric;
use crate::policy::SlicePolicy;
use crate::segment::SegmentManager;
use crate::topology::Topology;
use crate::transport::TransportRegistry;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

/// Engine tunables. Defaults follow the paper (§4.2): 64 KB minimum slice,
/// γ = 0.05, P = {1, 3, ∞}, periodic reset, sub-50 ms probing.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Scheduling policy (TENT or a baseline).
    pub policy: crate::policy::PolicyKind,
    /// Minimum slice size (bytes). Paper default: 64 KB.
    pub min_slice: u64,
    /// Cap on slices per transfer (bounds control-plane overhead).
    pub max_slices: usize,
    /// Algorithm-1 parameters (γ, penalties, EWMA α, ω).
    pub sched: super::sched::SchedParams,
    /// Periodic scheduler state reset (paper: ~30 s; benches use shorter).
    pub reset_interval: Duration,
    /// Heartbeat probing cadence for excluded rails.
    pub probe_interval: Duration,
    /// Per-slice retry budget before the transfer is failed.
    pub max_retries: u32,
    /// Capacity of each rail's MPSC ring (each QoS lane gets its own ring
    /// of this capacity). The datapath is shared per cluster: the first
    /// engine brought up on a cluster fixes this (and `bulk_quantum` /
    /// `idle_backoff_max`) for everyone; `cluster::Fleet` scales it with
    /// the engine count.
    pub ring_capacity: usize,
    /// Dual-lane QoS datapath: per rail, a latency lane drained ahead of
    /// the bulk lane. `false` falls back to the single shared ring (the
    /// ablation baseline for `benches/qos_multiplex.rs`) and also disables
    /// per-class queue isolation in the scheduler.
    pub qos_lanes: bool,
    /// Max bulk-lane slices a worker executes per wakeup while
    /// latency-class work is pending (anti-starvation weight; clamped ≥ 1).
    pub bulk_quantum: usize,
    /// Max latency-lane slices a worker serves per scheduling round,
    /// counting mid-bulk preemption pops. Together with `bulk_quantum`
    /// this turns strict lane priority into a weighted-fair split
    /// (default 64:4): latency keeps its head start, but a latency
    /// firehose can no longer starve bulk indefinitely. Clamped ≥ 1;
    /// shared-datapath knob, fixed by the first engine on the cluster.
    pub lat_quantum: usize,
    /// Coalesce completion feedback per (engine, class) within one drain
    /// pass: one queue subtraction, one histogram merge, one EWMA step
    /// per batch instead of each per slice. `false` restores the
    /// per-slice completion path (the ablation baseline measured by
    /// `benches/ablation_slice_gamma.rs --feedback`).
    pub batched_feedback: bool,
    /// Cap on the worker's *bounded* idle-backoff sleeps — the escalation
    /// stage before a worker deep-parks indefinitely behind its published
    /// parked flag (wakeups are flag-gated and reliable, so deep park
    /// costs nothing and loses nothing). Shared-datapath knob: fixed by
    /// the first engine on the cluster.
    pub idle_backoff_max: Duration,
    /// Telemetry exclusion threshold: exclude a rail whose β1 exceeds this
    /// multiple of the fleet median (∞ disables).
    pub degrade_exclude_factor: f64,
    /// Spawn the maintenance (prober/reset) thread.
    pub maintenance: bool,
    /// PRNG seed for jitter streams (deterministic runs).
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: crate::policy::PolicyKind::Tent,
            min_slice: 64 << 10,
            max_slices: 512,
            sched: super::sched::SchedParams::default(),
            reset_interval: Duration::from_secs(30),
            probe_interval: Duration::from_millis(20),
            max_retries: 4,
            ring_capacity: 4096,
            qos_lanes: true,
            bulk_quantum: 4,
            lat_quantum: 64,
            batched_feedback: true,
            idle_backoff_max: Duration::from_micros(50),
            degrade_exclude_factor: f64::INFINITY,
            maintenance: true,
            seed: 0x7E27,
        }
    }
}

impl EngineConfig {
    /// Convenience: same engine, different policy (for baseline benches).
    pub fn with_policy(kind: crate::policy::PolicyKind) -> Self {
        EngineConfig {
            policy: kind,
            ..Default::default()
        }
    }
}

/// State shared by every engine thread.
pub struct EngineCore {
    pub topo: Arc<Topology>,
    pub fabric: Arc<Fabric>,
    pub segments: Arc<SegmentManager>,
    pub transports: Arc<TransportRegistry>,
    pub config: EngineConfig,
    pub policy: Box<dyn SlicePolicy>,
    pub sched: SchedulerState,
    pub batches: super::batch::BatchTable,
    pub stats: EngineStats,
    pub shutdown: AtomicBool,
    /// The cluster-shared datapath this engine enqueues into.
    pub(crate) datapath: Arc<SharedDatapath>,
}

impl EngineCore {
    pub fn new(
        topo: Arc<Topology>,
        fabric: Arc<Fabric>,
        segments: Arc<SegmentManager>,
        transports: Arc<TransportRegistry>,
        datapath: Arc<SharedDatapath>,
        config: EngineConfig,
    ) -> Self {
        let policy = crate::policy::make_policy(config.policy);
        // The scheduler's per-class queue isolation only holds when this
        // engine routes onto dual lanes; keep the two in lockstep.
        let mut sched_params = config.sched.clone();
        sched_params.class_isolation = config.qos_lanes;
        // Register with the shared fabric: this engine's queue accounting
        // writes its own counter shard (see `Fabric::register_engine`).
        let sched = SchedulerState::new_registered(topo.rails.len(), sched_params, &fabric);
        EngineCore {
            topo,
            fabric,
            segments,
            transports,
            config,
            policy,
            sched,
            batches: super::batch::BatchTable::new(),
            stats: EngineStats::default(),
            shutdown: AtomicBool::new(false),
            datapath,
        }
    }

    /// Policy context view for a slice of the given QoS class.
    #[inline]
    pub(crate) fn ctx(&self, class: TransferClass) -> SchedCtx<'_> {
        SchedCtx {
            sched: &self.sched,
            fabric: &self.fabric,
            topo: &self.topo,
            class,
        }
    }
}
