//! The low-overhead datapath (§4.4): per-rail lock-free MPSC rings drained
//! by dedicated worker threads, split into **two QoS lanes per rail** — and,
//! since the fleet-scaling work, owned by the *cluster*, not the engine.
//!
//! A rail is a physical resource: exactly one pinned worker services it no
//! matter how many engine instances share the fabric, so queueing
//! discipline stays physical at fleet scale (engine-private workers would
//! both multiply threads by the engine count and let two engines' workers
//! race each other's pacing on the same wire). Engines are control planes:
//! they plan, schedule, and account; their slices all funnel into the
//! shared per-rail rings, and completions are routed back through the
//! `Arc<EngineCore>` each slice carries.
//!
//! Fleet-scale mechanics:
//!
//! * **Lazy workers** — rings and the worker thread for a rail materialize
//!   on first enqueue. A 64-node fleet has thousands of rails; only the
//!   ones actually carrying traffic cost memory and a thread.
//! * **Flag-gated wakeups** — producers unpark the worker only when its
//!   published `parked` flag is set, instead of unconditionally on every
//!   enqueue. Under load the flag is false and the enqueue hot path does a
//!   single relaxed-ish load (counted in `EngineStats::wakeups_coalesced`);
//!   sparse traffic still gets immediate wakeup (`wakeups_sent`).
//! * **Deep park** — an idle worker escalates yield → bounded
//!   `park_timeout` → indefinite `park`. The flag/recheck handshake (store
//!   parked, re-check both rings, then sleep; producers push, then load the
//!   flag — both `SeqCst`) makes the indefinite park lose no wakeups, so an
//!   idle fleet burns no CPU, where the old per-engine workers re-woke
//!   every `idle_backoff_max` forever.
//!
//! QoS lane scheduling is a **weighted-fair split**: the latency lane
//! drains ahead of the bulk lane, but a worker serves at most
//! `DatapathConfig::lat_quantum` latency slices per scheduling round
//! (counting mid-bulk preemption pops), while bulk advances by at least
//! `DatapathConfig::bulk_quantum` slices per wakeup whenever latency work
//! is pending. Latency keeps its head start; a latency firehose can no
//! longer starve bulk indefinitely. `EngineConfig::qos_lanes = false` is
//! purely a routing choice of that engine: its latency slices ride the
//! bulk lane (the single-FIFO baseline), without affecting other engines
//! on the rail.
//!
//! Completion delivery is **batched** (the hot-path half of the adaptive
//! slicing work): within one drain pass the worker coalesces finished
//! slices per (engine, class) and applies queue subtraction, histogram
//! merge, byte/stat counters, and the policy's EWMA feedback once per
//! batch instead of once per slice. `EngineConfig::batched_feedback =
//! false` restores the per-slice path (the ablation baseline in
//! `benches/ablation_slice_gamma.rs`). Semantics that are latency-critical
//! or slice-identity-bound stay per-slice in either mode: transfer
//! completion wake-ups, reroute healing stamps, receiver-ingress release,
//! and the whole failure path.

use super::core::{EngineConfig, EngineCore};
use super::slice::SliceDesc;
use super::telemetry::EngineStats;
use super::TransferClass;
use crate::fabric::RailHealth;
use crate::log;
use crate::topology::{RailId, Topology};
use crate::transport::SliceIo;
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::util::ring::{ring, CachePadded, Consumer, Producer};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Datapath tunables. The datapath is shared by every engine on a cluster,
/// so these are fixed when the first engine brings it up (that engine's
/// `EngineConfig` supplies them; later engines' copies are ignored).
#[derive(Clone, Debug)]
pub struct DatapathConfig {
    /// Capacity of each rail's MPSC ring (each QoS lane gets its own ring
    /// of this capacity). Shared rings: size for the number of engines
    /// expected to push concurrently (`cluster::Fleet` scales this).
    pub ring_capacity: usize,
    /// Max bulk-lane slices a worker executes per wakeup while
    /// latency-class work is pending (anti-starvation weight; clamped ≥ 1).
    pub bulk_quantum: usize,
    /// Max latency-lane slices a worker serves per scheduling round,
    /// counting mid-bulk preemption pops (weighted-fair split with
    /// `bulk_quantum`; clamped ≥ 1).
    pub lat_quantum: usize,
    /// Cap on the worker's *bounded* idle-backoff sleeps (the escalation
    /// stage before deep park). Wakeups are flag-gated and reliable, so
    /// this only shapes how quickly an idle worker descends to the
    /// zero-cost indefinite park.
    pub idle_backoff_max: Duration,
    /// PRNG seed for worker jitter streams.
    pub seed: u64,
}

impl Default for DatapathConfig {
    fn default() -> Self {
        DatapathConfig {
            ring_capacity: 4096,
            bulk_quantum: 4,
            lat_quantum: 64,
            idle_backoff_max: Duration::from_micros(50),
            seed: 0x7E27,
        }
    }
}

impl DatapathConfig {
    /// Derive from an engine's config (the engine bringing the datapath up).
    pub fn from_engine(cfg: &EngineConfig) -> DatapathConfig {
        DatapathConfig {
            ring_capacity: cfg.ring_capacity,
            bulk_quantum: cfg.bulk_quantum,
            lat_quantum: cfg.lat_quantum,
            idle_backoff_max: cfg.idle_backoff_max,
            seed: cfg.seed,
        }
    }
}

/// State shared between the datapath handle and every rail worker.
struct DpShared {
    config: DatapathConfig,
    shutdown: AtomicBool,
}

/// Per-rail lane state, materialized on first use.
struct RailLanes {
    /// `lanes[TransferClass::index()]` — one ring per QoS lane.
    lanes: [Producer<SliceDesc>; TransferClass::COUNT],
    /// The worker's thread handle for unparking.
    waker: std::thread::Thread,
    /// Published by the worker right before it parks indefinitely;
    /// producers only unpark when this is set (flag-gated wakeup).
    parked: Arc<CachePadded<AtomicBool>>,
}

/// The cluster-shared datapath: one (lazily spawned) worker + dual-lane
/// ring pair per rail, shared by every engine on the cluster.
pub struct SharedDatapath {
    topo: Arc<Topology>,
    shared: Arc<DpShared>,
    rails: Box<[OnceLock<RailLanes>]>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl SharedDatapath {
    pub fn new(topo: &Arc<Topology>, config: DatapathConfig) -> Arc<SharedDatapath> {
        let n = topo.rails.len();
        Arc::new(SharedDatapath {
            topo: Arc::clone(topo),
            shared: Arc::new(DpShared {
                config,
                shutdown: AtomicBool::new(false),
            }),
            rails: (0..n).map(|_| OnceLock::new()).collect(),
            handles: Mutex::new(Vec::new()),
        })
    }

    /// Lane state for `rail`, spawning its worker on first use.
    fn lanes(&self, rail: RailId) -> &RailLanes {
        self.rails[rail.0 as usize].get_or_init(|| {
            let def = self.topo.rail(rail);
            let cap = self.shared.config.ring_capacity;
            let (lat_tx, lat_rx) = ring::<SliceDesc>(cap);
            let (bulk_tx, bulk_rx) = ring::<SliceDesc>(cap);
            let parked = Arc::new(CachePadded::new(AtomicBool::new(false)));
            let shared = Arc::clone(&self.shared);
            let flag = Arc::clone(&parked);
            let handle = std::thread::Builder::new()
                .name(format!("tent-{}", def.name))
                .spawn(move || worker_loop(shared, rail, lat_rx, bulk_rx, flag))
                .expect("spawn rail worker");
            let waker = handle.thread().clone();
            self.handles.lock().unwrap().push(handle);
            RailLanes {
                lanes: [lat_tx, bulk_tx],
                waker,
                parked,
            }
        })
    }

    /// Push a dispatched slice onto its rail's lane, yielding while full
    /// (each stall episode is counted in `EngineStats::ring_full_stalls`;
    /// stalls with other engines' bytes on the rail also count as
    /// `cross_engine_stalls`). On shutdown — of the slice's engine or of
    /// the cluster — the slice is handed back so the caller can unwind its
    /// accounting.
    pub(crate) fn enqueue(&self, slice: SliceDesc) -> Result<(), SliceDesc> {
        // No teardown race by construction: every caller reaches this
        // method through an owning `Arc<SharedDatapath>` (its engine
        // core), and workers are only stopped by the last owner's Drop —
        // so the datapath cannot be mid-teardown here. The check below
        // only trips for the slice's own engine shutting down (see the
        // ring-full branch) or defensive reuse after teardown.
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return Err(slice);
        }
        let core = Arc::clone(&slice.core);
        let rail = slice.plan.candidates[slice.cand_idx].rail;
        let lane = if core.config.qos_lanes {
            slice.class.index()
        } else {
            TransferClass::Bulk.index()
        };
        let rl = self.lanes(rail);
        let producer = &rl.lanes[lane];
        let mut item = slice;
        let mut stalled = false;
        loop {
            match producer.push(item) {
                Ok(()) => {
                    // Flag-gated wakeup: only unpark a worker that said it
                    // went to sleep. The SC fence pairs with the worker's
                    // publish-fence-recheck (the ring's backlog counters
                    // are relaxed), so the indefinite park cannot miss
                    // this enqueue: either we see the flag, or the worker's
                    // recheck sees our push.
                    std::sync::atomic::fence(Ordering::SeqCst);
                    if rl.parked.load(Ordering::SeqCst) {
                        rl.waker.unpark();
                        EngineStats::bump(&core.stats.wakeups_sent);
                    } else {
                        EngineStats::bump(&core.stats.wakeups_coalesced);
                    }
                    return Ok(());
                }
                Err(back) => {
                    if core.shutdown.load(Ordering::Acquire)
                        || self.shared.shutdown.load(Ordering::Acquire)
                    {
                        return Err(back);
                    }
                    if !stalled {
                        stalled = true;
                        EngineStats::bump(&core.stats.ring_full_stalls);
                        // Attribute the stall: fabric-global queued beyond
                        // this engine's own in-flight bytes means other
                        // engines are loading the rail too.
                        let lq = &core.sched.local_queued[rail.0 as usize];
                        let local: u64 = lq.iter().map(|a| a.load(Ordering::Relaxed)).sum();
                        if core.fabric.rail(rail).queued_bytes() > local {
                            EngineStats::bump(&core.stats.cross_engine_stalls);
                        }
                    }
                    // The worker is behind; kick it in case it parked
                    // behind the other lane.
                    rl.waker.unpark();
                    item = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Ring backlog for a rail, summed over both lanes (tests / telemetry).
    pub fn backlog(&self, rail: RailId) -> u64 {
        self.rails[rail.0 as usize]
            .get()
            .map(|rl| rl.lanes.iter().map(|p| p.backlog()).sum())
            .unwrap_or(0)
    }

    /// Number of rail workers actually spawned (lazy-spawn telemetry).
    pub fn spawned_workers(&self) -> usize {
        self.rails.iter().filter(|slot| slot.get().is_some()).count()
    }

    /// Unpark every spawned rail worker (engine shutdown drains faster;
    /// also the cluster-teardown kick).
    pub(crate) fn wake_all(&self) {
        for slot in self.rails.iter() {
            if let Some(rl) = slot.get() {
                rl.waker.unpark();
            }
        }
    }

    /// Stop and join every rail worker. Workers drain their rings before
    /// exiting, so every slice ever enqueued resolves.
    fn shutdown_and_join(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.wake_all();
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.handles.lock().unwrap());
        let me = std::thread::current().id();
        for h in handles {
            // The final owner drop can land on a worker thread (the last
            // engine core riding a completing slice); never join self —
            // that thread exits naturally right after this Drop.
            if h.thread().id() != me {
                let _ = h.join();
            }
        }
    }
}

/// Teardown runs when the *last* owner lets go — the `Cluster` and every
/// `EngineCore` (and thus every in-flight slice) hold an owning `Arc`, so
/// workers can never be stopped while anyone could still enqueue, and an
/// engine outliving its `Cluster` struct keeps a fully live datapath.
impl Drop for SharedDatapath {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

fn worker_loop(
    shared: Arc<DpShared>,
    rail: RailId,
    mut lat_rx: Consumer<SliceDesc>,
    mut bulk_rx: Consumer<SliceDesc>,
    parked: Arc<CachePadded<AtomicBool>>,
) {
    let mut rng = Pcg64::new(shared.config.seed ^ 0xDA7A_0000, rail.0 as u64);
    let bulk_quantum = shared.config.bulk_quantum.max(1);
    let lat_quantum = shared.config.lat_quantum.max(1);
    let max_sleep = shared.config.idle_backoff_max.max(Duration::from_micros(1));
    let mut lat_batch: Vec<SliceDesc> = Vec::with_capacity(lat_quantum.min(1024));
    let mut bulk_batch: Vec<SliceDesc> = Vec::with_capacity(64);
    let mut batcher = CompletionBatcher::new(rail);
    let mut idle_spins: u32 = 0;
    loop {
        // Batched dequeue (§4.4), latency lane first. Weighted-fair split:
        // latency is served first but capped at `lat_quantum` slices per
        // round (initial batch plus mid-bulk preemption pops); while
        // latency work is pending, bulk advances by at most `bulk_quantum`
        // slices per wakeup — priority with an anti-starvation floor on
        // both sides.
        let n_lat = lat_rx.pop_batch(&mut lat_batch, lat_quantum);
        let mut lat_budget = lat_quantum - n_lat;
        let bulk_budget = if n_lat > 0 || lat_rx.backlog() > 0 {
            bulk_quantum
        } else {
            64
        };
        let n_bulk = bulk_rx.pop_batch(&mut bulk_batch, bulk_budget);
        if n_lat + n_bulk == 0 {
            if shared.shutdown.load(Ordering::SeqCst) {
                // Shutdown is only set by the last owner's Drop, when no
                // producer can exist anymore — both rings just read
                // empty, so this drain is complete.
                return;
            }
            // Idle escalation: yield (single-core friendly), then bounded
            // parks, then the zero-cost indefinite park. The parked flag
            // is published for both park stages so a sparse enqueue wakes
            // the worker immediately instead of waiting out the backoff.
            idle_spins = (idle_spins + 1).min(24);
            if idle_spins < 4 {
                std::thread::yield_now();
            } else if idle_spins < 16 {
                // Same publish-fence-recheck handshake as the deep park:
                // an enqueue racing the flag publish must not sleep out
                // the bounded timeout with its slice already queued.
                let backoff = Duration::from_micros(20 * (idle_spins as u64 - 3));
                parked.store(true, Ordering::SeqCst);
                std::sync::atomic::fence(Ordering::SeqCst);
                if lat_rx.backlog() == 0 && bulk_rx.backlog() == 0 {
                    std::thread::park_timeout(backoff.min(max_sleep));
                }
                parked.store(false, Ordering::SeqCst);
            } else {
                // Deep park. Publish the flag, fence, then re-check both
                // rings and the shutdown flag: an enqueue that raced the
                // publish either sees the flag (and unparks us — the token
                // makes the park return immediately) or pushed before our
                // re-check (and we see its backlog). The paired SC fences
                // make the Dekker handshake sound even though the backlog
                // counters themselves are relaxed.
                parked.store(true, Ordering::SeqCst);
                std::sync::atomic::fence(Ordering::SeqCst);
                if lat_rx.backlog() == 0
                    && bulk_rx.backlog() == 0
                    && !shared.shutdown.load(Ordering::SeqCst)
                {
                    std::thread::park();
                }
                parked.store(false, Ordering::SeqCst);
            }
            continue;
        }
        idle_spins = 0;
        for slice in lat_batch.drain(..) {
            execute_slice(slice, &mut rng, Some(&mut batcher));
        }
        for slice in bulk_batch.drain(..) {
            // Latency arrivals during bulk service preempt the rest of the
            // bulk batch at slice granularity — but only within this
            // round's remaining `lat_quantum` budget, so even a sustained
            // stream of latency submissions cannot indefinitely defer the
            // bulk work already popped (the quantum guarantee holds both
            // ways).
            while lat_budget > 0 {
                match lat_rx.pop() {
                    Some(l) => {
                        lat_budget -= 1;
                        execute_slice(l, &mut rng, Some(&mut batcher));
                    }
                    None => break,
                }
            }
            execute_slice(slice, &mut rng, Some(&mut batcher));
        }
        // One coalesced feedback/accounting application per drain pass.
        batcher.flush();
    }
}

/// Completion state accumulated for one (engine, class) pair within a
/// single drain pass of one rail worker.
struct CompletionBatch {
    core: Arc<EngineCore>,
    class: TransferClass,
    bytes: u64,
    sum_predicted_ns: f64,
    sum_serial_ns: f64,
    sum_observed_ns: f64,
    /// Observed latencies, kept individually so histogram quantiles stay
    /// identical to the per-slice path (`Histogram::record_batch` merges
    /// them under one atomic round per touched bucket).
    latencies: Vec<u64>,
}

/// Coalesces completion feedback within one worker drain pass (§4.4).
///
/// A rail worker executing a burst of slices used to pay the full
/// feedback fan-out — queue subtraction, two histogram records, six stat
/// counters, an EWMA update — once per slice. The batcher accumulates
/// completions per (engine, class) and applies each of those once per
/// batch at [`CompletionBatcher::flush`], with the EWMA folded through
/// the weight-equivalent [`SlicePolicy::on_complete_batch`] hook. Batches
/// never outlive a drain pass (flush drops the engine `Arc`s), so an
/// idle worker pins no engine and the deferred accounting — queued-bytes
/// release and the inflight decrement — is stale for at most one pass.
///
/// [`SlicePolicy::on_complete_batch`]: crate::policy::SlicePolicy::on_complete_batch
pub(crate) struct CompletionBatcher {
    rail: RailId,
    batches: Vec<CompletionBatch>,
}

impl CompletionBatcher {
    fn new(rail: RailId) -> CompletionBatcher {
        CompletionBatcher {
            rail,
            batches: Vec::new(),
        }
    }

    /// Record one successful slice completion for later coalesced delivery.
    fn push(
        &mut self,
        core: &Arc<EngineCore>,
        class: TransferClass,
        len: u64,
        predicted_ns: f64,
        serial_ns: f64,
        observed_ns: u64,
    ) {
        let batch = match self
            .batches
            .iter_mut()
            .position(|b| b.class == class && Arc::ptr_eq(&b.core, core))
        {
            Some(i) => &mut self.batches[i],
            None => {
                self.batches.push(CompletionBatch {
                    core: Arc::clone(core),
                    class,
                    bytes: 0,
                    sum_predicted_ns: 0.0,
                    sum_serial_ns: 0.0,
                    sum_observed_ns: 0.0,
                    latencies: Vec::with_capacity(16),
                });
                self.batches.last_mut().expect("just pushed")
            }
        };
        batch.bytes += len;
        batch.sum_predicted_ns += predicted_ns;
        batch.sum_serial_ns += serial_ns;
        batch.sum_observed_ns += observed_ns as f64;
        batch.latencies.push(observed_ns);
    }

    /// Apply every accumulated batch: one queue subtraction, one histogram
    /// merge, one stats round, and one policy feedback call per
    /// (engine, class).
    fn flush(&mut self) {
        for b in self.batches.drain(..) {
            let n = b.latencies.len() as u64;
            if n == 0 {
                continue;
            }
            let core = &b.core;
            let rail_state = core.fabric.rail(self.rail);
            core.sched.sub_queued(&core.fabric, self.rail, b.bytes, b.class);
            rail_state.bytes_carried.fetch_add(b.bytes, Ordering::Relaxed);
            rail_state.slices_ok.fetch_add(n, Ordering::Relaxed);
            rail_state.latency.record_batch(&b.latencies);
            rail_state.class_latency[b.class.index()].record_batch(&b.latencies);
            core.stats.slices_completed.fetch_add(n, Ordering::Relaxed);
            core.stats.slices_completed_class[b.class.index()]
                .fetch_add(n, Ordering::Relaxed);
            let inv = 1.0 / n as f64;
            core.policy.on_complete_batch(
                self.rail,
                n,
                b.sum_predicted_ns * inv,
                b.sum_serial_ns * inv,
                b.sum_observed_ns * inv,
                &core.ctx(b.class),
            );
            core.stats.inflight.fetch_sub(n, Ordering::AcqRel);
        }
    }
}

/// Run one slice to completion (or hand it to the resilience layer). The
/// slice carries its engine (`SliceDesc::core`): all accounting, feedback,
/// and retry routing happen against the engine that dispatched it, even
/// though the executing worker is shared by the whole cluster.
///
/// With a `batcher` (the worker hot path) and `batched_feedback` enabled
/// on the slice's engine, a successful completion only records into the
/// batch and wakes the transfer; the feedback fan-out lands at the next
/// [`CompletionBatcher::flush`]. Without one (or with the ablation knob
/// off) the full per-slice path runs inline. Failures always resolve
/// per-slice — the resilience layer needs them immediately.
pub(crate) fn execute_slice(
    slice: SliceDesc,
    rng: &mut Pcg64,
    batcher: Option<&mut CompletionBatcher>,
) {
    let core = Arc::clone(&slice.core);
    let cand = &slice.plan.candidates[slice.cand_idx];
    let rail = cand.rail;
    let rail_state = core.fabric.rail(rail);

    // A rail that hard-failed while this slice sat in the ring errors
    // immediately — the sim analogue of a posted WR flushing with error.
    let result = if rail_state.health() == RailHealth::Failed {
        Err(crate::Error::TransferFailed(format!("{rail} is down")))
    } else {
        let io = SliceIo {
            src: &slice.src,
            src_off: slice.src_off,
            dst: &slice.dst,
            dst_off: slice.dst_off,
            len: slice.len,
            rail,
            affinity: slice.affinity(),
        };
        cand.backend.execute(&io, &core.topo, &core.fabric, rng)
    };

    match result {
        Ok(_out) => {
            let done_ns = clock::now_ns();
            let observed = done_ns.saturating_sub(slice.enqueue_ns);
            // Receiver-side pricing: release this slice's ingestion claims
            // on the destination node and any relay nodes of the candidate
            // that carried it. Terminal-event symmetric with the
            // dispatch-side `add_ingress_route` (retries keep the claims;
            // a retry that switched candidates swapped the relay set).
            if core.sched.params.rx_omega > 0.0 {
                core.sched.sub_ingress_route(
                    &core.fabric,
                    slice.plan.dst_node,
                    cand.relays(),
                    slice.len,
                    slice.class,
                );
            }
            if slice.attempt > 0 {
                // A resilience reroute landed: stamp the completion instant
                // for the chaos healing probe (§4.3's sub-50 ms claim).
                // Stays per-slice even under batching — the healing gate
                // measures this instant.
                EngineStats::bump(&core.stats.reroutes_completed);
                core.stats
                    .last_reroute_complete_ns
                    .fetch_max(done_ns, Ordering::Relaxed);
            }
            match batcher {
                Some(b) if core.config.batched_feedback => {
                    b.push(
                        &core,
                        slice.class,
                        slice.len,
                        slice.predicted_ns,
                        slice.serial_ns,
                        observed,
                    );
                    // Wake the transfer immediately; only the feedback
                    // fan-out is deferred to the flush.
                    slice.transfer.complete_slice();
                }
                _ => {
                    core.sched.sub_queued(&core.fabric, rail, slice.len, slice.class);
                    rail_state.bytes_carried.fetch_add(slice.len, Ordering::Relaxed);
                    rail_state.slices_ok.fetch_add(1, Ordering::Relaxed);
                    rail_state.latency.record(observed);
                    rail_state.class_latency[slice.class.index()].record(observed);
                    EngineStats::bump(&core.stats.slices_completed);
                    EngineStats::bump(&core.stats.slices_completed_class[slice.class.index()]);
                    // Feedback (§4.2): observed completion vs prediction.
                    core.policy.on_complete(
                        rail,
                        slice.predicted_ns,
                        slice.serial_ns,
                        observed as f64,
                        &core.ctx(slice.class),
                    );
                    slice.transfer.complete_slice();
                    core.stats.inflight.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
        Err(err) => {
            core.sched.sub_queued(&core.fabric, rail, slice.len, slice.class);
            rail_state.slices_failed.fetch_add(1, Ordering::Relaxed);
            EngineStats::bump(&core.stats.slice_failures);
            log::debug!("slice failed on {rail}: {err}");
            super::resilience::on_slice_failure(&core, slice);
        }
    }
}
