//! The low-overhead datapath (§4.4): per-rail lock-free MPSC rings drained
//! by dedicated worker threads, split into **two QoS lanes per rail**.
//!
//! Submission threads push slice descriptors and return immediately; each
//! worker owns one rail (its "queue pair"), dequeues in batches, executes
//! slices through the transport backend, and drives the completion /
//! feedback / failure paths. All completion accounting is hierarchical
//! atomic counters — the hot path takes no locks.
//!
//! The lanes implement the production multiplexing scenario: the latency
//! lane (KV-cache fetches) drains ahead of the bulk lane (checkpoint /
//! parameter traffic), so a queued bulk burst can no longer head-of-line
//! block a latency fetch. Bulk is never starved: while latency work is
//! pending the worker still executes up to `EngineConfig::bulk_quantum`
//! bulk slices per wakeup, and latency arrivals preempt a bulk batch only
//! at slice granularity. `EngineConfig::qos_lanes = false` collapses
//! everything onto the bulk lane (the single-ring baseline).
//!
//! Idle workers park with a bounded escalating timeout
//! (`EngineConfig::idle_backoff_max` cap) and are **unparked on every
//! enqueue**, so a sparse latency slice never waits out the backoff.

use super::core::EngineCore;
use super::slice::SliceDesc;
use super::telemetry::EngineStats;
use super::TransferClass;
use crate::fabric::RailHealth;
use crate::log;
use crate::topology::RailId;
use crate::transport::SliceIo;
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::util::ring::{ring, Consumer, Producer};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Per-rail, per-lane producer handles plus worker wakeup handles.
pub struct Datapath {
    /// `lanes[rail][TransferClass::index()]` — one ring per QoS lane.
    lanes: Vec<[Producer<SliceDesc>; TransferClass::COUNT]>,
    /// Rail-worker thread handles, for prompt wakeup from idle backoff.
    wakers: Vec<std::thread::Thread>,
    /// Cached `EngineConfig::qos_lanes`; `false` routes every class onto
    /// the bulk lane (single-ring fallback).
    qos: bool,
}

/// Spawn one worker per rail; returns the producer set and join handles.
pub fn spawn_workers(
    core: &Arc<EngineCore>,
    ring_capacity: usize,
    seed: u64,
) -> (Datapath, Vec<JoinHandle<()>>) {
    let n = core.topo.rails.len();
    let qos = core.config.qos_lanes;
    let mut lanes = Vec::with_capacity(n);
    let mut wakers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (i, def) in core.topo.rails.iter().enumerate() {
        let (lat_tx, lat_rx) = ring::<SliceDesc>(ring_capacity);
        let (bulk_tx, bulk_rx) = ring::<SliceDesc>(ring_capacity);
        lanes.push([lat_tx, bulk_tx]);
        let core = Arc::clone(core);
        let name = format!("tent-{}", def.name);
        let handle = std::thread::Builder::new()
            .name(name)
            .spawn(move || worker_loop(core, RailId(i as u32), lat_rx, bulk_rx, seed))
            .expect("spawn rail worker");
        wakers.push(handle.thread().clone());
        handles.push(handle);
    }
    (Datapath { lanes, wakers, qos }, handles)
}

fn worker_loop(
    core: Arc<EngineCore>,
    rail: RailId,
    mut lat_rx: Consumer<SliceDesc>,
    mut bulk_rx: Consumer<SliceDesc>,
    seed: u64,
) {
    let mut rng = Pcg64::new(seed ^ 0xDA7A_0000, rail.0 as u64);
    let qos = core.config.qos_lanes;
    let bulk_quantum = core.config.bulk_quantum.max(1);
    let max_sleep = core.config.idle_backoff_max.max(Duration::from_micros(1));
    let mut lat_batch: Vec<SliceDesc> = Vec::with_capacity(64);
    let mut bulk_batch: Vec<SliceDesc> = Vec::with_capacity(64);
    let mut idle_spins: u32 = 0;
    loop {
        // Batched dequeue (§4.4), latency lane first. While latency work is
        // pending, bulk advances by at most `bulk_quantum` slices per
        // wakeup — strict priority with an anti-starvation floor.
        let n_lat = if qos {
            lat_rx.pop_batch(&mut lat_batch, 64)
        } else {
            0
        };
        let bulk_budget = if qos && (n_lat > 0 || lat_rx.backlog() > 0) {
            bulk_quantum
        } else {
            64
        };
        let n_bulk = bulk_rx.pop_batch(&mut bulk_batch, bulk_budget);
        if n_lat + n_bulk == 0 {
            if core.shutdown.load(Ordering::Acquire) {
                return;
            }
            // Adaptive backoff: yield first (single-core friendly), then
            // park with escalating-but-capped timeouts while idle.
            // `Datapath::enqueue` unparks this worker, so the cap only
            // bounds the damage of a lost wakeup.
            idle_spins = (idle_spins + 1).min(20);
            if idle_spins < 4 {
                std::thread::yield_now();
            } else {
                let backoff = Duration::from_micros(20 * (idle_spins as u64 - 3));
                std::thread::park_timeout(backoff.min(max_sleep));
            }
            continue;
        }
        idle_spins = 0;
        for slice in lat_batch.drain(..) {
            execute_slice(&core, slice, &mut rng);
        }
        for slice in bulk_batch.drain(..) {
            if qos {
                // Latency arrivals during bulk service preempt the rest of
                // the bulk batch at slice granularity — bounded to one
                // batch per bulk slice, so even a sustained stream of
                // latency submissions cannot indefinitely defer the bulk
                // work already popped (the quantum guarantee holds).
                for _ in 0..64 {
                    match lat_rx.pop() {
                        Some(l) => execute_slice(&core, l, &mut rng),
                        None => break,
                    }
                }
            }
            execute_slice(&core, slice, &mut rng);
        }
    }
}

/// Run one slice to completion (or hand it to the resilience layer).
pub(crate) fn execute_slice(core: &Arc<EngineCore>, slice: SliceDesc, rng: &mut Pcg64) {
    let cand = &slice.plan.candidates[slice.cand_idx];
    let rail = cand.rail;
    let rail_state = core.fabric.rail(rail);

    // A rail that hard-failed while this slice sat in the ring errors
    // immediately — the sim analogue of a posted WR flushing with error.
    let result = if rail_state.health() == RailHealth::Failed {
        Err(crate::Error::TransferFailed(format!("{rail} is down")))
    } else {
        let io = SliceIo {
            src: &slice.src,
            src_off: slice.src_off,
            dst: &slice.dst,
            dst_off: slice.dst_off,
            len: slice.len,
            rail,
            affinity: slice.affinity(),
        };
        cand.backend.execute(&io, &core.topo, &core.fabric, rng)
    };

    core.sched.sub_queued(&core.fabric, rail, slice.len, slice.class);

    match result {
        Ok(_out) => {
            let observed = clock::now_ns().saturating_sub(slice.enqueue_ns);
            rail_state.bytes_carried.fetch_add(slice.len, Ordering::Relaxed);
            rail_state.slices_ok.fetch_add(1, Ordering::Relaxed);
            rail_state.latency.record(observed);
            rail_state.class_latency[slice.class.index()].record(observed);
            EngineStats::bump(&core.stats.slices_completed);
            EngineStats::bump(&core.stats.slices_completed_class[slice.class.index()]);
            // Feedback (§4.2): observed completion vs prediction.
            core.policy.on_complete(
                rail,
                slice.predicted_ns,
                slice.serial_ns,
                observed as f64,
                &core.ctx(slice.class),
            );
            slice.transfer.complete_slice();
        }
        Err(err) => {
            rail_state.slices_failed.fetch_add(1, Ordering::Relaxed);
            EngineStats::bump(&core.stats.slice_failures);
            log::debug!("slice failed on {rail}: {err}");
            super::resilience::on_slice_failure(core, slice);
        }
    }
}

impl Datapath {
    /// Lane a slice of `class` rides; everything shares the bulk lane when
    /// QoS lanes are disabled.
    #[inline]
    fn lane_idx(&self, class: TransferClass) -> usize {
        if self.qos {
            class.index()
        } else {
            TransferClass::Bulk.index()
        }
    }

    /// Push a dispatched slice onto its rail's lane, yielding while full
    /// (each stall episode is counted in `EngineStats::ring_full_stalls`).
    /// Errors only on engine shutdown.
    pub fn enqueue(&self, core: &EngineCore, slice: SliceDesc) -> crate::Result<()> {
        let rail = slice.plan.candidates[slice.cand_idx].rail.0 as usize;
        let lane = self.lane_idx(slice.class);
        let producer = &self.lanes[rail][lane];
        let mut item = slice;
        let mut stalled = false;
        loop {
            match producer.push(item) {
                Ok(()) => {
                    // Prompt wakeup: the worker may be in idle backoff.
                    self.wakers[rail].unpark();
                    return Ok(());
                }
                Err(back) => {
                    if core.shutdown.load(Ordering::Acquire) {
                        return Err(crate::Error::Shutdown);
                    }
                    if !stalled {
                        stalled = true;
                        EngineStats::bump(&core.stats.ring_full_stalls);
                    }
                    // A full lane means the worker is busy, but kick it
                    // anyway in case it parked behind the other lane.
                    self.wakers[rail].unpark();
                    item = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Ring backlog for a rail, summed over both lanes (tests / telemetry).
    pub fn backlog(&self, rail: RailId) -> u64 {
        self.lanes[rail.0 as usize].iter().map(|p| p.backlog()).sum()
    }

    /// Unpark every rail worker (shutdown: don't wait out a parked
    /// worker's idle-backoff timeout).
    pub(crate) fn wake_all(&self) {
        for w in &self.wakers {
            w.unpark();
        }
    }
}
