//! The low-overhead datapath (§4.4): per-rail lock-free MPSC rings drained
//! by dedicated worker threads.
//!
//! Submission threads push slice descriptors and return immediately; each
//! worker owns one rail (its "queue pair"), dequeues in batches, executes
//! slices through the transport backend, and drives the completion /
//! feedback / failure paths. All completion accounting is hierarchical
//! atomic counters — the hot path takes no locks.

use super::core::EngineCore;
use super::slice::SliceDesc;
use super::telemetry::EngineStats;
use crate::fabric::RailHealth;
use crate::log;
use crate::topology::RailId;
use crate::transport::SliceIo;
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::util::ring::{ring, Consumer, Producer};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Per-rail producer handles (indexed by RailId).
pub struct Datapath {
    pub producers: Vec<Producer<SliceDesc>>,
}

/// Spawn one worker per rail; returns the producer set and join handles.
pub fn spawn_workers(
    core: &Arc<EngineCore>,
    ring_capacity: usize,
    seed: u64,
) -> (Datapath, Vec<JoinHandle<()>>) {
    let n = core.topo.rails.len();
    let mut producers = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx) = ring::<SliceDesc>(ring_capacity);
        producers.push(tx);
        let core = Arc::clone(core);
        let name = format!("tent-{}", core.topo.rails[i].name);
        handles.push(
            std::thread::Builder::new()
                .name(name)
                .spawn(move || worker_loop(core, RailId(i as u32), rx, seed))
                .expect("spawn rail worker"),
        );
    }
    (Datapath { producers }, handles)
}

fn worker_loop(core: Arc<EngineCore>, rail: RailId, mut rx: Consumer<SliceDesc>, seed: u64) {
    let mut rng = Pcg64::new(seed ^ 0xDA7A_0000, rail.0 as u64);
    let mut batch: Vec<SliceDesc> = Vec::with_capacity(64);
    let mut idle_spins: u32 = 0;
    loop {
        // Batched dequeue (§4.4): drain up to 64 descriptors per wakeup.
        let n = rx.pop_batch(&mut batch, 64);
        if n == 0 {
            if core.shutdown.load(Ordering::Acquire) {
                return;
            }
            // Adaptive backoff: yield first (single-core friendly), then
            // sleep with escalating intervals while idle.
            idle_spins = (idle_spins + 1).min(20);
            if idle_spins < 4 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(
                    20 * (idle_spins as u64 - 3),
                ));
            }
            continue;
        }
        idle_spins = 0;
        for slice in batch.drain(..) {
            execute_slice(&core, slice, &mut rng);
        }
    }
}

/// Run one slice to completion (or hand it to the resilience layer).
pub(crate) fn execute_slice(core: &Arc<EngineCore>, slice: SliceDesc, rng: &mut Pcg64) {
    let cand = &slice.plan.candidates[slice.cand_idx];
    let rail = cand.rail;
    let rail_state = core.fabric.rail(rail);

    // A rail that hard-failed while this slice sat in the ring errors
    // immediately — the sim analogue of a posted WR flushing with error.
    let result = if rail_state.health() == RailHealth::Failed {
        Err(crate::Error::TransferFailed(format!("{rail} is down")))
    } else {
        let io = SliceIo {
            src: &slice.src,
            src_off: slice.src_off,
            dst: &slice.dst,
            dst_off: slice.dst_off,
            len: slice.len,
            rail,
            affinity: slice.affinity(),
        };
        cand.backend.execute(&io, &core.topo, &core.fabric, rng)
    };

    core.sched.sub_queued(&core.fabric, rail, slice.len);

    match result {
        Ok(_out) => {
            let observed = clock::now_ns().saturating_sub(slice.enqueue_ns);
            rail_state.bytes_carried.fetch_add(slice.len, Ordering::Relaxed);
            rail_state.slices_ok.fetch_add(1, Ordering::Relaxed);
            rail_state.latency.record(observed);
            EngineStats::bump(&core.stats.slices_completed);
            // Feedback (§4.2): observed completion vs prediction.
            core.policy.on_complete(
                rail,
                slice.predicted_ns,
                slice.serial_ns,
                observed as f64,
                &core.ctx(),
            );
            slice.transfer.complete_slice();
        }
        Err(err) => {
            rail_state.slices_failed.fetch_add(1, Ordering::Relaxed);
            EngineStats::bump(&core.stats.slice_failures);
            log::debug!("slice failed on {rail}: {err}");
            super::resilience::on_slice_failure(core, slice);
        }
    }
}

impl Datapath {
    /// Push a dispatched slice onto its rail's ring, yielding while full.
    /// Errors only on engine shutdown.
    pub fn enqueue(&self, core: &EngineCore, slice: SliceDesc) -> crate::Result<()> {
        let rail = slice.plan.candidates[slice.cand_idx].rail;
        let producer = &self.producers[rail.0 as usize];
        let mut item = slice;
        loop {
            match producer.push(item) {
                Ok(()) => return Ok(()),
                Err(back) => {
                    if core.shutdown.load(Ordering::Acquire) {
                        return Err(crate::Error::Shutdown);
                    }
                    item = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Ring backlog for a rail (used in tests / telemetry).
    pub fn backlog(&self, rail: RailId) -> u64 {
        self.producers[rail.0 as usize].backlog()
    }
}
