//! Phase 1 — dynamic orchestration (§4.1).
//!
//! Given source and destination segment metadata, enumerate every reachable
//! (backend, rail) pair across all loaded transports, classify each by
//! affinity tier, and retain the full ranked set so that binding can be
//! deferred: Phase 2 chooses per-slice, Phase 3 steers around failures, and
//! backend substitution falls out of the plan containing multiple fabrics.
//!
//! When no direct path spans the endpoints (e.g. consumer GPUs without
//! GPUDirect), the planner synthesizes a staged D2H→H2H→H2D route; when
//! even the single bounce cannot reach (partitioned host fabrics), it
//! searches the fabric-reachability graph for a k-hop relay route
//! (`Topology::relay_routes`, k ≤ `MAX_RELAY_LEGS`).

use super::TransferClass;
use crate::segment::Segment;
use crate::topology::{NodeId, RailId, RelayRoute, Tier, Topology, MAX_RELAY_LEGS};
use crate::transport::staged::StagedBackend;
use crate::transport::{TransportBackend, TransportRegistry};
use crate::{Error, Result};
use std::sync::Arc;

/// One feasible way to carry a slice.
pub struct Candidate {
    pub backend: Arc<dyn TransportBackend>,
    pub rail: RailId,
    /// Affinity tier of the rail relative to the *source* buffer (§3.1).
    pub tier: Tier,
    /// Nominal path bandwidth B_d (bytes/sec) — what a state-blind scheduler
    /// knows; real asymmetries only surface through telemetry. For staged
    /// candidates this is the *bottleneck* across every hop of the route
    /// (D2H, network legs, H2D), not the primary rail's nominal rate.
    pub bw: f64,
    /// Physical path asymmetry (invisible to the scheduler, applied by the
    /// fabric).
    pub cross_numa: bool,
    /// Tier-2 asymmetry: device buffer behind a different PCIe root.
    pub cross_root: bool,
    /// Multi-hop relay route this candidate executes, if any. Pricing
    /// charges its relay nodes (`predict_ns_to`), dispatch claims ingress
    /// at each, and the staged backend bounces through them.
    pub route: Option<Arc<RelayRoute>>,
}

impl Candidate {
    /// Relay nodes this candidate bounces through (empty for direct and
    /// single-bounce paths).
    #[inline]
    pub fn relays(&self) -> &[NodeId] {
        self.route.as_ref().map(|r| r.relays()).unwrap_or(&[])
    }
}

impl std::fmt::Debug for Candidate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Candidate({} {} tier{:?} {:.0}MB/s{})",
            self.backend.name(),
            self.rail,
            self.tier as u8,
            self.bw / 1e6,
            if let Some(r) = &self.route {
                format!(" via{:?}", r.relays())
            } else {
                String::new()
            }
        )
    }
}

/// Bottleneck bandwidth of a staged path: the network leg(s) capped by the
/// D2H/H2D PCIe hops its device endpoints must cross. This is the satellite
/// bugfix — staged candidates used to advertise the primary rail's nominal
/// rate alone, over-ranking bounce routes against direct tier-3 rails.
fn staged_bottleneck(topo: &Topology, src: &Segment, dst: &Segment, net_bw: f64) -> f64 {
    let hop = |seg: &Segment| {
        StagedBackend::pcie_hop(seg, topo).map(|r| topo.rail(r).bw_bytes_per_sec)
    };
    let mut bw = net_bw;
    if let Some(b) = hop(src) {
        bw = bw.min(b);
    }
    if let Some(b) = hop(dst) {
        bw = bw.min(b);
    }
    bw
}

/// The transport plan for one logical transfer: the full candidate set plus
/// bookkeeping the policies need.
pub struct TransferPlan {
    pub candidates: Vec<Candidate>,
    /// True if this plan required staged route synthesis.
    pub staged: bool,
    /// Total logical transfer length (policies with size thresholds use it).
    pub transfer_len: u64,
    /// QoS class declared on the transfer. Set by the engine after
    /// planning (before `shape_plan`); slices inherit it from here.
    pub class: TransferClass,
    /// Destination node — receiver-side pricing keys the fabric's
    /// per-node ingestion counters on it (`SchedParams::rx_omega`). Every
    /// candidate of one plan shares the same destination.
    pub dst_node: NodeId,
}

/// Build the plan for `src → dst`.
pub fn build_plan(
    registry: &TransportRegistry,
    topo: &Topology,
    src: &Arc<Segment>,
    dst: &Arc<Segment>,
    transfer_len: u64,
) -> Result<TransferPlan> {
    let mut candidates = Vec::new();
    let src_numa = src.loc.numa();
    let src_root = src.loc.pcie_root();
    let mk = |backend: &Arc<dyn TransportBackend>, rail: RailId| {
        let def = topo.rail(rail);
        let cross_numa = def.numa != src_numa;
        Candidate {
            backend: Arc::clone(backend),
            rail,
            tier: topo.classify_tier(rail, src_numa, src_root),
            bw: def.bw_bytes_per_sec,
            cross_numa,
            cross_root: !cross_numa
                && src_root.map(|r| def.pcie_root != r).unwrap_or(false),
            route: None,
        }
    };
    for backend in registry.all() {
        for rail in backend.plan_rails(src, dst, topo) {
            candidates.push(mk(backend, rail));
        }
    }
    let mut staged = false;
    if candidates.is_empty() {
        // §4.1: synthesize a staged single-bounce route through host memory,
        // priced by its bottleneck hop rather than the primary rail alone.
        let backend = registry.staged();
        for rail in backend.plan_rails(src, dst, topo) {
            let mut c = mk(&backend, rail);
            c.bw = staged_bottleneck(topo, src, dst, c.bw);
            candidates.push(c);
        }
        staged = !candidates.is_empty();
    }
    if candidates.is_empty() && !src.loc.is_storage() && !dst.loc.is_storage() {
        // Last resort: k-hop relay routes over the fabric-reachability
        // graph (partitioned host fabrics — e.g. an RDMA-only prefill silo
        // reaching a TCP-only decode silo through a dual-fabric gateway).
        // One candidate per (route × first-leg rail); the candidate's bw is
        // the bottleneck over every hop, so Algorithm 1 ranks a 20x-slower
        // relay leg honestly against anything faster.
        for route in topo.relay_routes(src.loc.node(), dst.loc.node(), MAX_RELAY_LEGS) {
            let route = Arc::new(route);
            let backend: Arc<dyn TransportBackend> =
                Arc::new(StagedBackend::over(Arc::clone(&route)));
            for rail in backend.plan_rails(src, dst, topo) {
                let mut c = mk(&backend, rail);
                let net_bw = route.bottleneck_bw.min(topo.rail(rail).bw_bytes_per_sec);
                c.bw = staged_bottleneck(topo, src, dst, net_bw);
                c.route = Some(Arc::clone(&route));
                candidates.push(c);
            }
        }
        staged = !candidates.is_empty();
    }
    if candidates.is_empty() {
        return Err(Error::NoEligibleDevice(format!(
            "no transport can reach {:?} -> {:?}",
            src.loc, dst.loc
        )));
    }
    Ok(TransferPlan {
        candidates,
        staged,
        transfer_len,
        class: TransferClass::default(),
        dst_node: dst.loc.node(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::segment::Location;

    #[test]
    fn h2h_inter_node_plan_spans_rdma_and_tcp() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        assert!(!plan.staged);
        let names: Vec<&str> = plan.candidates.iter().map(|x| x.backend.name()).collect();
        assert!(names.contains(&"rdma_sim"));
        assert!(names.contains(&"tcp"));
        // 8 NICs + 1 TCP rail.
        assert_eq!(plan.candidates.len(), 9);
        // NUMA-local NICs are tier-1, the rest tier-3 for host memory.
        let t1 = plan
            .candidates
            .iter()
            .filter(|x| x.tier == Tier::T1 && x.backend.name() == "rdma_sim")
            .count();
        assert_eq!(t1, 4);
    }

    #[test]
    fn d2d_intra_node_prefers_gpu_fabrics_in_plan() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::device(0, 1), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        let names: Vec<&str> = plan.candidates.iter().map(|x| x.backend.name()).collect();
        assert!(names.contains(&"nvlink_sim"));
        assert!(names.contains(&"rdma_sim")); // GPUDirect rails also feasible
        // NVLink candidate has the highest nominal bandwidth.
        let best = plan
            .candidates
            .iter()
            .max_by(|x, y| x.bw.partial_cmp(&y.bw).unwrap())
            .unwrap();
        assert_eq!(best.backend.name(), "nvlink_sim");
    }

    #[test]
    fn no_gpudirect_pair_gets_staged_plan() {
        let c = Cluster::from_profile("no_gpudirect").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        assert!(plan.staged);
        assert!(plan.candidates.iter().all(|x| x.backend.name() == "staged"));
    }

    #[test]
    fn unreachable_pair_is_an_error() {
        // Storage on one node, memory on another: no direct backend, staged
        // refuses storage endpoints.
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1024).unwrap();
        let p = std::env::temp_dir().join(format!("tent_plan_{}", std::process::id()));
        let s = c
            .segments
            .register_file(Location::storage(1, p.clone()), 1024)
            .unwrap();
        let e = build_plan(&c.transports, &c.topo, &a, &s, 1024);
        assert!(matches!(e, Err(Error::NoEligibleDevice(_))));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn mixed_fleet_cross_silo_gpu_pair_stages() {
        let c = Cluster::from_profile_nodes(
            "mixed_fleet",
            0,
            crate::fabric::FabricConfig::default(),
        )
        .unwrap();
        let nv = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let asc = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &nv, &asc, 1024).unwrap();
        assert!(plan.staged, "cross-vendor GPU pair must stage via hosts");
    }

    #[test]
    fn staged_candidates_price_the_bottleneck_hop() {
        // Satellite bugfix: a staged candidate used to advertise its H2H
        // rail's nominal bw; it must be min(D2H PCIe, H2H rail, H2D PCIe).
        let c = Cluster::from_profile("no_gpudirect").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        assert!(plan.staged);
        let hop_bw = |seg: &Arc<crate::segment::Segment>| {
            crate::transport::staged::StagedBackend::pcie_hop(seg, &c.topo)
                .map(|r| c.topo.rail(r).bw_bytes_per_sec)
                .unwrap()
        };
        let (d2h, h2d) = (hop_bw(&a), hop_bw(&b));
        for cand in &plan.candidates {
            let rail_bw = c.topo.rail(cand.rail).bw_bytes_per_sec;
            assert_eq!(
                cand.bw,
                rail_bw.min(d2h).min(h2d),
                "candidate {cand:?} must price its slowest hop"
            );
        }
    }

    #[test]
    fn silo_fleet_cross_silo_pair_plans_a_relay_route() {
        // Acceptance: a pair with no direct backend AND no single-bounce
        // path (partitioned host fabrics) plans a k<=3-hop relay route.
        let c = Cluster::from_profile_nodes(
            "silo_fleet",
            3,
            crate::fabric::FabricConfig::default(),
        )
        .unwrap();
        let gpu = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let npu = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &gpu, &npu, 1024).unwrap();
        assert!(plan.staged);
        assert!(!plan.candidates.is_empty());
        for cand in &plan.candidates {
            let route = cand.route.as_ref().expect("relay candidates carry routes");
            assert!(route.legs() >= 2 && route.legs() <= 3);
            assert_eq!(cand.relays(), &[crate::topology::NodeId(2)]);
            // Bottleneck pricing: the slow TCP decode leg caps the whole
            // route even though the first leg rides a 20x-faster RDMA rail.
            let tcp_bw = c
                .topo
                .rails_of(crate::topology::NodeId(2), crate::topology::FabricKind::Tcp)
                .iter()
                .map(|&r| c.topo.rail(r).bw_bytes_per_sec)
                .fold(0.0f64, f64::max);
            assert_eq!(cand.bw, cand.bw.min(tcp_bw));
            assert!(
                cand.bw < c.topo.rail(cand.rail).bw_bytes_per_sec,
                "first-leg rail bw must not be advertised: {cand:?}"
            );
        }
    }

    #[test]
    fn relay_candidate_ranks_below_equally_slow_direct_rail() {
        // Ranking regression: under the old pricing a relay candidate
        // advertised its first-leg RDMA rail (~20x the route's true TCP
        // bottleneck) and out-ranked honest direct paths. With bottleneck
        // pricing plus the relay_cost term, a direct rail of the same
        // nominal bw must always win.
        use crate::engine::sched::{SchedParams, SchedulerState};
        use crate::policy::SlicePolicy;
        let c = Cluster::from_profile_nodes(
            "silo_fleet",
            3,
            crate::fabric::FabricConfig::default(),
        )
        .unwrap();
        let gpu = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let npu = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let mut plan = build_plan(&c.transports, &c.topo, &gpu, &npu, 1 << 20).unwrap();
        let relay = &plan.candidates[0];
        // Synthetic "direct" candidate with the same nominal bw and tier on
        // an idle gateway TCP rail — a state-blind scheduler sees two
        // equally-fast paths, but only one buffers at a relay.
        let tcp_rail =
            c.topo.rails_of(crate::topology::NodeId(2), crate::topology::FabricKind::Tcp)[0];
        let direct = Candidate {
            backend: Arc::clone(&relay.backend),
            rail: tcp_rail,
            tier: relay.tier,
            bw: relay.bw,
            cross_numa: false,
            cross_root: false,
            route: None,
        };
        plan.candidates.push(direct);
        let sched = SchedulerState::new(c.topo.rails.len(), SchedParams::default());
        let ctx = crate::engine::sched::SchedCtx {
            sched: &sched,
            fabric: &c.fabric,
            topo: &c.topo,
            class: crate::engine::TransferClass::Bulk,
        };
        let direct_idx = plan.candidates.len() - 1;
        let viable: Vec<usize> = (0..plan.candidates.len()).collect();
        for _ in 0..32 {
            let i = crate::policy::TentPolicy
                .pick(&plan, &viable, 1 << 20, &ctx)
                .unwrap();
            assert_eq!(i, direct_idx, "relay route must not out-rank a direct rail");
        }
    }

    #[test]
    fn relay_fallback_never_serves_storage_endpoints() {
        let c = Cluster::from_profile_nodes(
            "silo_fleet",
            3,
            crate::fabric::FabricConfig::default(),
        )
        .unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1024).unwrap();
        let p = std::env::temp_dir().join(format!("tent_relay_{}", std::process::id()));
        let s = c
            .segments
            .register_file(Location::storage(1, p.clone()), 1024)
            .unwrap();
        let e = build_plan(&c.transports, &c.topo, &a, &s, 1024);
        assert!(matches!(e, Err(Error::NoEligibleDevice(_))));
        std::fs::remove_file(p).ok();
    }
}
