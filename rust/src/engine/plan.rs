//! Phase 1 — dynamic orchestration (§4.1).
//!
//! Given source and destination segment metadata, enumerate every reachable
//! (backend, rail) pair across all loaded transports, classify each by
//! affinity tier, and retain the full ranked set so that binding can be
//! deferred: Phase 2 chooses per-slice, Phase 3 steers around failures, and
//! backend substitution falls out of the plan containing multiple fabrics.
//!
//! When no direct path spans the endpoints (e.g. consumer GPUs without
//! GPUDirect), the planner synthesizes a staged D2H→H2H→H2D route.

use super::TransferClass;
use crate::segment::Segment;
use crate::topology::{NodeId, RailId, Tier, Topology};
use crate::transport::{TransportBackend, TransportRegistry};
use crate::{Error, Result};
use std::sync::Arc;

/// One feasible way to carry a slice.
pub struct Candidate {
    pub backend: Arc<dyn TransportBackend>,
    pub rail: RailId,
    /// Affinity tier of the rail relative to the *source* buffer (§3.1).
    pub tier: Tier,
    /// Nominal link bandwidth B_d (bytes/sec) — what a state-blind scheduler
    /// knows; real asymmetries only surface through telemetry.
    pub bw: f64,
    /// Physical path asymmetry (invisible to the scheduler, applied by the
    /// fabric).
    pub cross_numa: bool,
    /// Tier-2 asymmetry: device buffer behind a different PCIe root.
    pub cross_root: bool,
}

impl std::fmt::Debug for Candidate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Candidate({} {} tier{:?} {:.0}MB/s)",
            self.backend.name(),
            self.rail,
            self.tier as u8,
            self.bw / 1e6
        )
    }
}

/// The transport plan for one logical transfer: the full candidate set plus
/// bookkeeping the policies need.
pub struct TransferPlan {
    pub candidates: Vec<Candidate>,
    /// True if this plan required staged route synthesis.
    pub staged: bool,
    /// Total logical transfer length (policies with size thresholds use it).
    pub transfer_len: u64,
    /// QoS class declared on the transfer. Set by the engine after
    /// planning (before `shape_plan`); slices inherit it from here.
    pub class: TransferClass,
    /// Destination node — receiver-side pricing keys the fabric's
    /// per-node ingestion counters on it (`SchedParams::rx_omega`). Every
    /// candidate of one plan shares the same destination.
    pub dst_node: NodeId,
}

/// Build the plan for `src → dst`.
pub fn build_plan(
    registry: &TransportRegistry,
    topo: &Topology,
    src: &Arc<Segment>,
    dst: &Arc<Segment>,
    transfer_len: u64,
) -> Result<TransferPlan> {
    let mut candidates = Vec::new();
    let src_numa = src.loc.numa();
    let src_root = src.loc.pcie_root();
    let mk = |backend: &Arc<dyn TransportBackend>, rail: RailId| {
        let def = topo.rail(rail);
        let cross_numa = def.numa != src_numa;
        Candidate {
            backend: Arc::clone(backend),
            rail,
            tier: topo.classify_tier(rail, src_numa, src_root),
            bw: def.bw_bytes_per_sec,
            cross_numa,
            cross_root: !cross_numa
                && src_root.map(|r| def.pcie_root != r).unwrap_or(false),
        }
    };
    for backend in registry.all() {
        for rail in backend.plan_rails(src, dst, topo) {
            candidates.push(mk(backend, rail));
        }
    }
    let mut staged = false;
    if candidates.is_empty() {
        // §4.1: synthesize a staged multi-hop route through host memory.
        let backend = registry.staged();
        for rail in backend.plan_rails(src, dst, topo) {
            candidates.push(mk(&backend, rail));
        }
        staged = !candidates.is_empty();
    }
    if candidates.is_empty() {
        return Err(Error::NoEligibleDevice(format!(
            "no transport can reach {:?} -> {:?}",
            src.loc, dst.loc
        )));
    }
    Ok(TransferPlan {
        candidates,
        staged,
        transfer_len,
        class: TransferClass::default(),
        dst_node: dst.loc.node(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::segment::Location;

    #[test]
    fn h2h_inter_node_plan_spans_rdma_and_tcp() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::host(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        assert!(!plan.staged);
        let names: Vec<&str> = plan.candidates.iter().map(|x| x.backend.name()).collect();
        assert!(names.contains(&"rdma_sim"));
        assert!(names.contains(&"tcp"));
        // 8 NICs + 1 TCP rail.
        assert_eq!(plan.candidates.len(), 9);
        // NUMA-local NICs are tier-1, the rest tier-3 for host memory.
        let t1 = plan
            .candidates
            .iter()
            .filter(|x| x.tier == Tier::T1 && x.backend.name() == "rdma_sim")
            .count();
        assert_eq!(t1, 4);
    }

    #[test]
    fn d2d_intra_node_prefers_gpu_fabrics_in_plan() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::device(0, 1), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        let names: Vec<&str> = plan.candidates.iter().map(|x| x.backend.name()).collect();
        assert!(names.contains(&"nvlink_sim"));
        assert!(names.contains(&"rdma_sim")); // GPUDirect rails also feasible
        // NVLink candidate has the highest nominal bandwidth.
        let best = plan
            .candidates
            .iter()
            .max_by(|x, y| x.bw.partial_cmp(&y.bw).unwrap())
            .unwrap();
        assert_eq!(best.backend.name(), "nvlink_sim");
    }

    #[test]
    fn no_gpudirect_pair_gets_staged_plan() {
        let c = Cluster::from_profile("no_gpudirect").unwrap();
        let a = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &a, &b, 1024).unwrap();
        assert!(plan.staged);
        assert!(plan.candidates.iter().all(|x| x.backend.name() == "staged"));
    }

    #[test]
    fn unreachable_pair_is_an_error() {
        // Storage on one node, memory on another: no direct backend, staged
        // refuses storage endpoints.
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let a = c.segments.register_memory(Location::host(0, 0), 1024).unwrap();
        let p = std::env::temp_dir().join(format!("tent_plan_{}", std::process::id()));
        let s = c
            .segments
            .register_file(Location::storage(1, p.clone()), 1024)
            .unwrap();
        let e = build_plan(&c.transports, &c.topo, &a, &s, 1024);
        assert!(matches!(e, Err(Error::NoEligibleDevice(_))));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn mixed_fleet_cross_silo_gpu_pair_stages() {
        let c = Cluster::from_profile_nodes(
            "mixed_fleet",
            0,
            crate::fabric::FabricConfig::default(),
        )
        .unwrap();
        let nv = c.segments.register_memory(Location::device(0, 0), 1024).unwrap();
        let asc = c.segments.register_memory(Location::device(1, 0), 1024).unwrap();
        let plan = build_plan(&c.transports, &c.topo, &nv, &asc, 1024).unwrap();
        assert!(plan.staged, "cross-vendor GPU pair must stage via hosts");
    }
}
