//! The TENT engine (§3–§4): declarative batch-transfer API over the
//! three-phase execution pipeline.
//!
//! * **Phase 1** (`plan`) — dynamic orchestration: per-request route
//!   enumeration across every loaded transport, tier classification, staged
//!   route synthesis.
//! * **Phase 2** (`sched` + `policy::TentPolicy`) — telemetry-driven slice
//!   spraying: Algorithm 1 with EWMA feedback.
//! * **Phase 3** (`resilience`) — dual-layer self-healing: per-slice
//!   rerouting and backend substitution inside the data plane.
//! * `datapath` — the §4.4 lock-free MPSC rings and rail workers, split
//!   into two QoS lanes per rail: the latency lane (KV-cache fetches)
//!   drains ahead of the bulk lane (checkpoint/parameter traffic) with an
//!   anti-starvation quantum.
//!
//! ```no_run
//! use tent::cluster::Cluster;
//! use tent::engine::{TentEngine, EngineConfig, TransferReq};
//! use tent::segment::Location;
//! # fn main() -> tent::Result<()> {
//! let cluster = Cluster::from_profile("h800_hgx")?;
//! let engine = TentEngine::new(&cluster, EngineConfig::default())?;
//! let src = engine.register_segment(Location::host(0, 0), 1 << 20)?;
//! let dst = engine.register_segment(Location::host(1, 0), 1 << 20)?;
//! let batch = engine.allocate_batch();
//! engine.submit(batch, &[TransferReq::write(src, 0, dst, 0, 1 << 20)])?;
//! engine.wait(batch, std::time::Duration::from_secs(10))?;
//! # Ok(()) }
//! ```

pub mod batch;
pub mod core;
pub mod datapath;
pub mod plan;
pub mod resilience;
pub mod sched;
pub mod slice;
pub mod telemetry;

pub use batch::{BatchId, BatchStatus};
// `self::` disambiguates the submodule from the built-in `core` crate in
// the extern prelude (bare `use core::…` is ambiguous here).
pub use self::core::{EngineConfig, EngineCore};

use crate::cluster::Cluster;
use crate::log;
use crate::segment::{Location, Segment, SegmentId};
use crate::topology::Topology;
use crate::util::clock;
use crate::{Error, Result};
use batch::TransferState;
use slice::SliceDesc;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;
use telemetry::EngineStats;

/// Direction of a declared transfer (recorded for symmetry with the paper's
/// API; both directions execute as src→dst byte movement).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TransferOp {
    /// Pull bytes from `src` (typically remote) into `dst`.
    Read,
    /// Push bytes from `src` into `dst` (typically remote).
    Write,
}

/// QoS class of a transfer. Production deployments multiplex
/// latency-critical KV-cache fetches with bulk checkpoint/parameter traffic
/// on the same rails; the class decides which datapath lane a slice rides
/// and which queue statistics its cost prediction sees.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub enum TransferClass {
    /// Latency-critical foreground traffic (e.g. KV-cache fetches): every
    /// rail worker drains this lane first.
    Latency,
    /// Bulk background traffic (checkpoints, parameter broadcast). The
    /// default; never starved — workers still execute a bounded quantum of
    /// bulk slices per wakeup under latency load.
    #[default]
    Bulk,
}

impl TransferClass {
    /// Number of classes (= datapath lanes per rail).
    pub const COUNT: usize = 2;

    /// Lane index in the dual-lane datapath and per-class accounting.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            TransferClass::Latency => 0,
            TransferClass::Bulk => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransferClass::Latency => "latency",
            TransferClass::Bulk => "bulk",
        }
    }
}

// The fabric's per-class telemetry arrays are sized independently (fabric
// cannot depend on engine types); fail the build if the two ever diverge.
const _: () = assert!(TransferClass::COUNT == crate::fabric::QOS_CLASSES);

/// A declared transfer: pure intent — segments, offsets, length, QoS class.
/// No transport binding (§3.1).
#[derive(Clone, Copy, Debug)]
pub struct TransferReq {
    pub op: TransferOp,
    pub src: SegmentId,
    pub src_off: u64,
    pub dst: SegmentId,
    pub dst_off: u64,
    pub len: u64,
    pub class: TransferClass,
}

impl TransferReq {
    pub fn write(src: SegmentId, src_off: u64, dst: SegmentId, dst_off: u64, len: u64) -> Self {
        TransferReq {
            op: TransferOp::Write,
            src,
            src_off,
            dst,
            dst_off,
            len,
            class: TransferClass::Bulk,
        }
    }
    pub fn read(src: SegmentId, src_off: u64, dst: SegmentId, dst_off: u64, len: u64) -> Self {
        TransferReq {
            op: TransferOp::Read,
            src,
            src_off,
            dst,
            dst_off,
            len,
            class: TransferClass::Bulk,
        }
    }

    /// Builder-style QoS class override (constructors default to `Bulk`).
    pub fn class(mut self, class: TransferClass) -> Self {
        self.class = class;
        self
    }
}

/// The engine: a control plane over the cluster-shared datapath; cheap to
/// share behind `Arc`. Any number of engines (one per node, in fleet
/// deployments) coexist on one `Cluster`, sharing its per-rail workers.
pub struct TentEngine {
    core: Arc<EngineCore>,
    maint: Option<JoinHandle<()>>,
}

impl TentEngine {
    /// Bring up an engine over a cluster: load backends, build the
    /// scheduler, attach to the cluster's shared datapath (creating it —
    /// and fixing its ring/wakeup knobs — if this is the first engine),
    /// and spawn the maintenance thread.
    pub fn new(cluster: &Cluster, config: EngineConfig) -> Result<TentEngine> {
        let maintenance = config.maintenance;
        let dp = cluster.shared_datapath(datapath::DatapathConfig::from_engine(&config));
        let core = Arc::new(EngineCore::new(
            Arc::clone(&cluster.topo),
            Arc::clone(&cluster.fabric),
            Arc::clone(&cluster.segments),
            Arc::clone(&cluster.transports),
            dp,
            config,
        ));
        let maint = maintenance.then(|| resilience::spawn_maintenance(&core));
        Ok(TentEngine { core, maint })
    }

    // ---- segment management (§3.1) ----

    /// Register a memory segment (host DRAM or sim device HBM).
    pub fn register_segment(&self, loc: Location, len: u64) -> Result<SegmentId> {
        Ok(self.core.segments.register_memory(loc, len)?.id)
    }

    /// Register a file-backed (storage) segment.
    pub fn register_file_segment(&self, loc: Location, len: u64) -> Result<SegmentId> {
        Ok(self.core.segments.register_file(loc, len)?.id)
    }

    /// Resolve a segment for direct data access (examples/tests).
    pub fn segment(&self, id: SegmentId) -> Result<Arc<Segment>> {
        self.core.segments.get(id)
    }

    pub fn unregister_segment(&self, id: SegmentId) -> Result<()> {
        self.core.segments.unregister(id)
    }

    // ---- batch API (§3.3) ----

    /// Allocate a batch control block.
    pub fn allocate_batch(&self) -> BatchId {
        EngineStats::bump(&self.core.stats.batches_allocated);
        self.core.batches.allocate()
    }

    /// Submit transfers into a batch. Returns once every slice is planned
    /// and enqueued (the application thread never blocks on hardware).
    pub fn submit(&self, batch: BatchId, reqs: &[TransferReq]) -> Result<()> {
        if self.core.shutdown.load(Ordering::Acquire) {
            return Err(Error::Shutdown);
        }
        let core = &self.core;
        let b = core.batches.get(batch)?;
        b.add_transfers(reqs.len() as u64);
        let mut first_err: Option<Error> = None;
        for req in reqs {
            EngineStats::bump(&core.stats.transfers_submitted);
            core.stats
                .bytes_submitted
                .fetch_add(req.len, Ordering::Relaxed);
            match self.submit_one(&b, req) {
                Ok(()) => {}
                Err(e) => {
                    // Keep counters consistent: the transfer completes failed.
                    b.complete_transfer(false);
                    log::warn!("transfer submit failed: {e}");
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn submit_one(&self, b: &Arc<batch::BatchState>, req: &TransferReq) -> Result<()> {
        let core = &self.core;
        let src = core.segments.get(req.src)?;
        let dst = core.segments.get(req.dst)?;
        src.check(req.src_off, req.len)?;
        dst.check(req.dst_off, req.len)?;
        if req.len == 0 {
            b.complete_transfer(true);
            return Ok(());
        }

        // Phase 1: plan (full candidate pool), then let the policy shape it
        // (baselines emulate their static binding here).
        let mut plan = plan::build_plan(&core.transports, &core.topo, &src, &dst, req.len)?;
        plan.class = req.class;
        core.policy.shape_plan(&mut plan, &src, &dst, &core.topo);
        if plan.candidates.is_empty() {
            return Err(Error::NoEligibleDevice("plan shaped to empty".into()));
        }
        if plan.staged {
            EngineStats::bump(&core.stats.staged_plans);
        }
        let plan = Arc::new(plan);

        // Slice decomposition (§4.2). Fixed γ carves at the static minimum
        // slice; adaptive γ derives the slice size from the learned cost
        // model of the plan's strongest live rail (amortization floor vs
        // HoL cap, see `SchedulerState::adaptive_slice_bytes`), so slices
        // grow on clean fast rails and shrink under congestion/jitter.
        let spans = if core.sched.params.adaptive_gamma {
            let target = adaptive_target(core, &plan);
            slice::decompose(
                req.len,
                target.max(core.config.min_slice),
                core.config.max_slices,
            )
        } else {
            slice::decompose(req.len, core.config.min_slice, core.config.max_slices)
        };
        let transfer = TransferState::new(Arc::clone(b), spans.len() as u64);

        for (off, len) in spans {
            let s = SliceDesc {
                core: Arc::clone(&self.core),
                src: Arc::clone(&src),
                src_off: req.src_off + off,
                dst: Arc::clone(&dst),
                dst_off: req.dst_off + off,
                len,
                class: plan.class,
                cand_idx: 0,
                predicted_ns: 0.0,
                serial_ns: 0.0,
                enqueue_ns: 0,
                attempt: 0,
                plan: Arc::clone(&plan),
                transfer: Arc::clone(&transfer),
            };
            if let Err(e) = self.dispatch(s) {
                // Could not place this slice at all: fail the transfer but
                // keep the slice ledger balanced.
                transfer.mark_failed();
                transfer.complete_slice();
                EngineStats::bump(&core.stats.permanent_failures);
                log::warn!("dispatch failed: {e}");
            }
        }
        Ok(())
    }

    /// Phase 2 for one slice: policy pick + queue accounting + enqueue.
    fn dispatch(&self, mut s: SliceDesc) -> Result<()> {
        let core = &self.core;
        let ctx = core.ctx(s.class);
        let failover = core.policy.failover();
        // Candidate viability: TENT-style policies skip excluded/dead rails;
        // state-blind baselines see the raw (shaped) set, faithfully hitting
        // dead paths.
        let viable: Vec<usize> = (0..s.plan.candidates.len())
            .filter(|&i| {
                if !failover {
                    return true;
                }
                let rail = s.plan.candidates[i].rail;
                !core.sched.is_excluded(rail)
                    && core.fabric.rail(rail).health() != crate::fabric::RailHealth::Failed
            })
            .collect();
        let picked = core
            .policy
            .pick(&s.plan, &viable, s.len, &ctx)
            .or_else(|| {
                // Everything excluded: Algorithm-1 line 2 would error; the
                // resilience layer instead tries any live rail.
                if failover {
                    (0..s.plan.candidates.len()).find(|&i| {
                        core.fabric.rail(s.plan.candidates[i].rail).health()
                            != crate::fabric::RailHealth::Failed
                    })
                } else {
                    None
                }
            })
            .ok_or_else(|| Error::NoEligibleDevice("all candidates unavailable".into()))?;

        s.cand_idx = picked;
        let cand = &s.plan.candidates[picked];
        let (pred, serial) = core.sched.predict_ns_to(
            &core.fabric,
            cand.rail,
            s.len,
            cand.bw,
            s.class,
            Some(s.plan.dst_node),
            cand.relays(),
        );
        s.predicted_ns = pred;
        s.serial_ns = serial;
        s.enqueue_ns = clock::now_ns();
        core.sched.add_queued(&core.fabric, cand.rail, s.len, s.class); // Alg. 1 line 11
        if core.sched.params.rx_omega > 0.0 {
            // Receiver-side pricing: claim ingestion capacity on the
            // destination node — and every relay node of a multi-hop
            // candidate — until the slice terminally resolves.
            core.sched.add_ingress_route(
                &core.fabric,
                s.plan.dst_node,
                cand.relays(),
                s.len,
                s.class,
            );
        }
        EngineStats::bump(&core.stats.slices_dispatched);
        core.stats.inflight.fetch_add(1, Ordering::AcqRel);
        match core.datapath.enqueue(s) {
            Ok(()) => Ok(()),
            Err(back) => {
                // Shutdown while enqueueing: unwind the accounting (caller
                // completes the transfer ledger as failed).
                core.stats.inflight.fetch_sub(1, Ordering::AcqRel);
                let cand = &back.plan.candidates[back.cand_idx];
                core.sched
                    .sub_queued(&core.fabric, cand.rail, back.len, back.class);
                if core.sched.params.rx_omega > 0.0 {
                    core.sched.sub_ingress_route(
                        &core.fabric,
                        back.plan.dst_node,
                        cand.relays(),
                        back.len,
                        back.class,
                    );
                }
                Err(Error::Shutdown)
            }
        }
    }

    /// Non-blocking batch status query.
    pub fn status(&self, batch: BatchId) -> Result<BatchStatus> {
        Ok(self.core.batches.get(batch)?.status())
    }

    /// Block until the batch completes; single completion event (§3.3).
    pub fn wait(&self, batch: BatchId, timeout: Duration) -> Result<BatchStatus> {
        let st = self.core.batches.get(batch)?.wait(timeout)?;
        if !st.ok() {
            return Err(Error::TransferFailed(format!(
                "{batch}: {}/{} transfers failed",
                st.failed_transfers, st.total_transfers
            )));
        }
        Ok(st)
    }

    /// Wait without treating failed transfers as `Err` (benches observing
    /// baseline failure behaviour use this).
    pub fn wait_any(&self, batch: BatchId, timeout: Duration) -> Result<BatchStatus> {
        self.core.batches.get(batch)?.wait(timeout)
    }

    /// Release a batch control block.
    pub fn release_batch(&self, batch: BatchId) -> Result<()> {
        self.core.batches.release(batch)
    }

    /// Convenience: submit one transfer and wait for it.
    pub fn transfer_sync(&self, req: TransferReq, timeout: Duration) -> Result<()> {
        let b = self.allocate_batch();
        self.submit(b, &[req])?;
        let r = self.wait(b, timeout);
        let _ = self.release_batch(b);
        r.map(|_| ())
    }

    // ---- introspection ----

    pub fn stats(&self) -> telemetry::StatCounters {
        self.core.stats.snapshot()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.core.config
    }

    pub fn rail_snapshots(&self) -> Vec<telemetry::RailSnapshot> {
        telemetry::rail_snapshots(
            &self.core.topo,
            &self.core.fabric,
            &self.core.sched,
            self.core.config.min_slice,
        )
    }

    pub fn topo(&self) -> &Topology {
        &self.core.topo
    }

    pub fn fabric(&self) -> &crate::fabric::Fabric {
        &self.core.fabric
    }

    pub fn policy_kind(&self) -> crate::policy::PolicyKind {
        self.core.policy.kind()
    }

    /// Stop this engine: refuse new work, join maintenance, and drain
    /// every in-flight slice. The rail workers belong to the cluster and
    /// keep running for other engines; draining (rather than joining)
    /// preserves the old guarantee that no slice of this engine is still
    /// executing after shutdown returns. Idempotent.
    pub fn shutdown(&mut self) {
        self.core.shutdown.store(true, Ordering::Release);
        if let Some(m) = self.maint.take() {
            let _ = m.join();
        }
        // Bounded drain: in-flight work at shutdown is normally tiny
        // (callers wait their batches first), but a crashed rail worker
        // must degrade to a loud leak, not a permanent hang in Drop.
        let deadline = clock::now_ns() + Duration::from_secs(30).as_nanos() as u64;
        let mut spins = 0u32;
        while self.core.stats.inflight.load(Ordering::Acquire) > 0 {
            if clock::now_ns() > deadline {
                log::error!(
                    "engine shutdown: {} slices still in flight after 30s; leaking them",
                    self.core.stats.inflight.load(Ordering::Acquire)
                );
                return;
            }
            spins = spins.wrapping_add(1);
            if spins % 64 == 0 {
                // Defensive kick: wake any deep-parked worker (the wakeup
                // protocol shouldn't lose tokens, but shutdown must not
                // hinge on that).
                self.core.datapath.wake_all();
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

impl Drop for TentEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Adaptive-γ slice-size target for one transfer: the telemetry-derived
/// size of the plan's highest-bandwidth live candidate (the rail
/// Algorithm 1 sprays hardest when healthy). One query per transfer — the
/// per-rail models move on EWMA timescales, so per-slice re-querying would
/// cost hot-path reads without changing the answer within a transfer.
fn adaptive_target(core: &EngineCore, plan: &plan::TransferPlan) -> u64 {
    let live = |c: &&plan::Candidate| {
        core.fabric.rail(c.rail).health() != crate::fabric::RailHealth::Failed
            && !core.sched.is_excluded(c.rail)
    };
    let best = plan
        .candidates
        .iter()
        .filter(live)
        .max_by(|a, b| a.bw.partial_cmp(&b.bw).unwrap_or(std::cmp::Ordering::Equal))
        .or_else(|| plan.candidates.first());
    match best {
        Some(c) => core
            .sched
            .adaptive_slice_bytes(&core.fabric, c.rail, c.bw, core.config.min_slice),
        None => core.config.min_slice,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(profile: &str) -> (Cluster, TentEngine) {
        let c = Cluster::from_profile(profile).unwrap();
        let e = TentEngine::new(&c, EngineConfig::default()).unwrap();
        (c, e)
    }

    fn fill_pattern(e: &TentEngine, id: SegmentId, len: usize, seed: u8) {
        let seg = e.segment(id).unwrap();
        let data: Vec<u8> = (0..len).map(|i| (i as u8).wrapping_mul(31).wrapping_add(seed)).collect();
        seg.write_at(0, &data).unwrap();
    }

    fn verify_pattern(e: &TentEngine, id: SegmentId, len: usize, seed: u8) {
        let seg = e.segment(id).unwrap();
        let mut buf = vec![0u8; len];
        seg.read_at(0, &mut buf).unwrap();
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, (i as u8).wrapping_mul(31).wrapping_add(seed), "byte {i}");
        }
    }

    #[test]
    fn h2h_transfer_delivers_bytes() {
        let (_c, e) = engine("h800_hgx");
        let len = 3 << 20; // 48 slices
        let a = e.register_segment(Location::host(0, 0), len as u64).unwrap();
        let b = e.register_segment(Location::host(1, 0), len as u64).unwrap();
        fill_pattern(&e, a, len, 7);
        let batch = e.allocate_batch();
        e.submit(batch, &[TransferReq::write(a, 0, b, 0, len as u64)]).unwrap();
        let st = e.wait(batch, Duration::from_secs(30)).unwrap();
        assert!(st.ok());
        verify_pattern(&e, b, len, 7);
        let stats = e.stats();
        assert_eq!(stats.transfers_submitted, 1);
        assert!(stats.slices_dispatched >= 48);
        assert_eq!(stats.slices_completed, stats.slices_dispatched);
    }

    #[test]
    fn d2d_uses_nvlink_first() {
        let (_c, e) = engine("h800_hgx");
        let len = 2u64 << 20;
        let a = e.register_segment(Location::device(0, 0), len).unwrap();
        let b = e.register_segment(Location::device(0, 1), len).unwrap();
        fill_pattern(&e, a, len as usize, 3);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, b, len as usize, 3);
        // NVLink rail must have carried (nearly) all of it.
        let nvl_bytes: u64 = e
            .rail_snapshots()
            .iter()
            .filter(|r| r.fabric == "nvlink")
            .map(|r| r.bytes_carried)
            .sum();
        assert!(nvl_bytes >= len / 2, "nvlink carried {nvl_bytes}");
    }

    #[test]
    fn mooncake_policy_avoids_nvlink() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let e = TentEngine::new(
            &c,
            EngineConfig::with_policy(crate::policy::PolicyKind::MooncakeTe),
        )
        .unwrap();
        let len = 1u64 << 20;
        let a = e.register_segment(Location::device(0, 0), len).unwrap();
        let b = e.register_segment(Location::device(0, 1), len).unwrap();
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        let nvl_bytes: u64 = e
            .rail_snapshots()
            .iter()
            .filter(|r| r.fabric == "nvlink")
            .map(|r| r.bytes_carried)
            .sum();
        assert_eq!(nvl_bytes, 0, "TE must not use NVLink");
    }

    #[test]
    fn multiple_transfers_one_batch() {
        let (_c, e) = engine("h800_hgx");
        let len = 256u64 << 10;
        let mut reqs = Vec::new();
        let mut dsts = Vec::new();
        for i in 0..6u8 {
            let a = e.register_segment(Location::host(0, 0), len).unwrap();
            let b = e.register_segment(Location::host(1, 1), len).unwrap();
            fill_pattern(&e, a, len as usize, i);
            reqs.push(TransferReq::write(a, 0, b, 0, len));
            dsts.push((b, i));
        }
        let batch = e.allocate_batch();
        e.submit(batch, &reqs).unwrap();
        let st = e.wait(batch, Duration::from_secs(30)).unwrap();
        assert_eq!(st.total_transfers, 6);
        for (b, i) in dsts {
            verify_pattern(&e, b, len as usize, i);
        }
    }

    #[test]
    fn class_defaults_to_bulk_and_builder_overrides() {
        let (_c, e) = engine("h800_hgx");
        let len = 256u64 << 10;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        let req = TransferReq::write(a, 0, b, 0, len);
        assert_eq!(req.class, TransferClass::Bulk);
        let req = req.class(TransferClass::Latency);
        assert_eq!(req.class, TransferClass::Latency);
        fill_pattern(&e, a, len as usize, 21);
        e.transfer_sync(req, Duration::from_secs(30)).unwrap();
        verify_pattern(&e, b, len as usize, 21);
        // Every completed slice must be accounted under the latency class.
        let s = e.stats();
        assert!(s.slices_completed > 0);
        assert_eq!(s.slices_completed_latency, s.slices_completed);
        assert_eq!(s.slices_completed_bulk, 0);
    }

    #[test]
    fn adaptive_gamma_carves_fewer_bigger_slices() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let mut cfg = EngineConfig::default();
        cfg.sched.adaptive_gamma = true;
        let e = TentEngine::new(&c, cfg).unwrap();
        let len = 16u64 << 20; // fixed γ would carve 256 × 64 KiB
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        fill_pattern(&e, a, len as usize, 17);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, b, len as usize, 17);
        // Fresh models on a clean RDMA rail (2.5e8 B/s in sim units) put
        // the amortization floor at ~320 KB — 64·β0·bw/β1 with β0 = 20 µs
        // — so the 16 MiB transfer carves ~53 slices instead of 256.
        let s = e.stats();
        assert!(
            s.slices_dispatched < 64,
            "adaptive mode dispatched {} slices for a 16 MiB transfer",
            s.slices_dispatched
        );
        assert_eq!(s.slices_completed, s.slices_dispatched);
    }

    #[test]
    fn per_slice_feedback_ablation_still_delivers() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let mut cfg = EngineConfig::default();
        cfg.batched_feedback = false;
        let e = TentEngine::new(&c, cfg).unwrap();
        let len = 2u64 << 20;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        fill_pattern(&e, a, len as usize, 19);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, b, len as usize, 19);
        let s = e.stats();
        assert_eq!(s.slices_completed, s.slices_dispatched);
        assert!(s.slices_completed >= 32);
    }

    #[test]
    fn rx_pricing_round_trips_ingress_accounting() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let mut cfg = EngineConfig::default();
        cfg.sched.rx_omega = 0.5;
        let e = TentEngine::new(&c, cfg).unwrap();
        let len = 4u64 << 20;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        fill_pattern(&e, a, len as usize, 23);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, b, len as usize, 23);
        // Every dispatch-side ingress claim must have been released on
        // completion: the destination node's counters drain back to zero.
        assert_eq!(c.fabric.ingress_bytes(crate::topology::NodeId(1)), 0);
    }

    #[test]
    fn zero_length_transfer_completes() {
        let (_c, e) = engine("h800_hgx");
        let a = e.register_segment(Location::host(0, 0), 64).unwrap();
        let b = e.register_segment(Location::host(1, 0), 64).unwrap();
        e.transfer_sync(TransferReq::write(a, 0, b, 0, 0), Duration::from_secs(5))
            .unwrap();
    }

    #[test]
    fn out_of_bounds_submit_fails_cleanly() {
        let (_c, e) = engine("h800_hgx");
        let a = e.register_segment(Location::host(0, 0), 64).unwrap();
        let b = e.register_segment(Location::host(1, 0), 64).unwrap();
        let batch = e.allocate_batch();
        let err = e.submit(batch, &[TransferReq::write(a, 0, b, 0, 128)]);
        assert!(err.is_err());
        // Batch still completes (as failed) — no hang.
        let st = e.wait_any(batch, Duration::from_secs(5)).unwrap();
        assert!(st.done() && !st.ok());
    }

    #[test]
    fn staged_route_end_to_end() {
        let (_c, e) = engine("no_gpudirect");
        let len = 1u64 << 20;
        let a = e.register_segment(Location::device(0, 0), len).unwrap();
        let b = e.register_segment(Location::device(1, 2), len).unwrap();
        fill_pattern(&e, a, len as usize, 9);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(60))
            .unwrap();
        verify_pattern(&e, b, len as usize, 9);
        assert!(e.stats().staged_plans >= 1);
    }

    #[test]
    fn failover_masks_injected_failure() {
        let (c, e) = engine("h800_hgx");
        let len = 4u64 << 20;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        fill_pattern(&e, a, len as usize, 5);
        // Kill two NUMA-0 NICs before submitting.
        let rails = c.topo.rails_of(crate::topology::NodeId(0), crate::topology::FabricKind::Rdma);
        c.fabric.inject_failure(rails[0]);
        c.fabric.inject_failure(rails[1]);
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, b, len as usize, 5);
        c.fabric.recover(rails[0]);
        c.fabric.recover(rails[1]);
    }

    #[test]
    fn baseline_surfaces_failure_to_caller() {
        let c = Cluster::from_profile("h800_hgx").unwrap();
        let e = TentEngine::new(
            &c,
            EngineConfig::with_policy(crate::policy::PolicyKind::UcclP2p),
        )
        .unwrap();
        let len = 1u64 << 20;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        // UCCL pins this region to one NIC; kill *all* NICs so it must fail.
        for r in c.topo.rails_of(crate::topology::NodeId(0), crate::topology::FabricKind::Rdma) {
            c.fabric.inject_failure(r);
        }
        let batch = e.allocate_batch();
        e.submit(batch, &[TransferReq::write(a, 0, b, 0, len)]).unwrap();
        let st = e.wait_any(batch, Duration::from_secs(30)).unwrap();
        assert!(st.done() && !st.ok(), "baseline must surface the failure");
        for r in c.topo.rails_of(crate::topology::NodeId(0), crate::topology::FabricKind::Rdma) {
            c.fabric.recover(r);
        }
    }

    #[test]
    fn backend_substitution_rdma_to_tcp() {
        // Kill every RDMA NIC on the source node: TENT must fall back to the
        // TCP rail and still deliver.
        let (c, e) = engine("h800_hgx");
        let len = 256u64 << 10;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        fill_pattern(&e, a, len as usize, 11);
        for r in c.topo.rails_of(crate::topology::NodeId(0), crate::topology::FabricKind::Rdma) {
            c.fabric.inject_failure(r);
        }
        e.transfer_sync(TransferReq::write(a, 0, b, 0, len), Duration::from_secs(60))
            .unwrap();
        verify_pattern(&e, b, len as usize, 11);
        let tcp_bytes: u64 = e
            .rail_snapshots()
            .iter()
            .filter(|r| r.fabric == "tcp")
            .map(|r| r.bytes_carried)
            .sum();
        assert!(tcp_bytes >= len, "tcp carried {tcp_bytes}");
        for r in c.topo.rails_of(crate::topology::NodeId(0), crate::topology::FabricKind::Rdma) {
            c.fabric.recover(r);
        }
    }

    #[test]
    fn host_to_file_tiering() {
        let (_c, e) = engine("h800_hgx");
        let len = 512u64 << 10;
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let p = std::env::temp_dir().join(format!("tent_engine_file_{}", std::process::id()));
        let f = e
            .register_file_segment(Location::storage(0, p.clone()), len)
            .unwrap();
        fill_pattern(&e, a, len as usize, 13);
        e.transfer_sync(TransferReq::write(a, 0, f, 0, len), Duration::from_secs(30))
            .unwrap();
        verify_pattern(&e, f, len as usize, 13);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn wait_times_out_on_unfinished_batch() {
        let (_c, e) = engine("h800_hgx");
        let len = 32u64 << 20; // long enough to still be in flight
        let a = e.register_segment(Location::host(0, 0), len).unwrap();
        let b = e.register_segment(Location::host(1, 0), len).unwrap();
        let batch = e.allocate_batch();
        e.submit(batch, &[TransferReq::write(a, 0, b, 0, len)]).unwrap();
        let r = e.wait(batch, Duration::from_millis(1));
        assert!(matches!(r, Err(Error::Timeout(_))));
        // Then it still finishes.
        e.wait(batch, Duration::from_secs(60)).unwrap();
    }
}
