//! Phase 2 scheduler state (§4.2): per-rail cost models, queue accounting,
//! soft-exclusion flags, and the context handed to pluggable policies.
//!
//! The actual *choice* (Algorithm 1 for TENT, static striping for the
//! baselines) lives in [`crate::policy`]; this module owns the shared
//! telemetry every policy reads and the feedback every completion writes.

use super::TransferClass;
use crate::fabric::Fabric;
use crate::topology::{NodeId, RailId, Tier, Topology};
use crate::util::ewma::LinearCostModel;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Tunables shared by scheduler + policies (a copy of the relevant
/// EngineConfig fields, kept flat for cheap access).
#[derive(Clone, Debug)]
pub struct SchedParams {
    /// Tolerance window γ (Algorithm 1, line 9).
    pub gamma: f64,
    /// Topology penalties P_tier for tiers 1..3 (Algorithm 1, line 7).
    pub tier_penalties: [f64; 3],
    /// EWMA α for the (β0, β1) feedback filter.
    pub ewma_alpha: f64,
    /// Global-load-diffusion weight ω ∈ [0,1]; 0 = local queue only
    /// (the paper's default: diffusion disabled).
    pub omega: f64,
    /// Initial fixed cost β0 (ns).
    pub init_beta0_ns: f64,
    /// Per-class queue isolation: latency-class predictions see only
    /// latency-class queued bytes, because the dual-lane datapath
    /// guarantees bulk backlog cannot delay them. The engine forces this to
    /// `EngineConfig::qos_lanes`; standalone `SchedulerState` users may
    /// toggle it directly.
    pub class_isolation: bool,
    /// Adaptive per-rail slice sizing: derive each rail's slice size from
    /// its learned cost model (β0/β1) and recent latency jitter instead of
    /// the static `min_slice` decomposition — fast, uncongested rails get
    /// larger slices (lower per-slice overhead), slow or jittery rails get
    /// finer slices (better rebalancing granularity). `false` (default)
    /// keeps the bit-identical static decomposition for ablation.
    pub adaptive_gamma: bool,
    /// Lower clamp for the adaptive slice size, as a multiple of the
    /// engine's `min_slice` (1.0 = never slice finer than the static mode).
    pub gamma_min: f64,
    /// Upper clamp for the adaptive slice size, as a multiple of
    /// `min_slice` (e.g. 64.0 with 64 KiB min ⇒ at most 4 MiB slices).
    pub gamma_max: f64,
    /// Receiver-side load-diffusion weight ∈ [0,1]: how strongly the
    /// destination node's ingestion backlog (see `Fabric::add_ingress_at`)
    /// inflates the effective queue term of a prediction. 0 (default) =
    /// sender-side pricing only, the historical behavior.
    pub rx_omega: f64,
    /// Relay-buffering penalty for multi-hop staged routes, in extra
    /// serializations per relay node: every bounce through an intermediate
    /// host buffers the payload once (write into staging memory) and drains
    /// it once (read back out), so a k-relay route pays roughly
    /// `k × relay_cost × len/bottleneck_bw` on top of the wire estimate.
    /// 1.0 (default) models a store-and-forward hop; 0 ablates the term
    /// (routes priced purely by bottleneck bandwidth).
    pub relay_cost: f64,
}

impl Default for SchedParams {
    fn default() -> Self {
        SchedParams {
            gamma: 0.05,
            tier_penalties: [1.0, 3.0, f64::INFINITY],
            ewma_alpha: 0.1,
            omega: 0.0,
            init_beta0_ns: 20_000.0,
            class_isolation: true,
            adaptive_gamma: false,
            gamma_min: 1.0,
            gamma_max: 64.0,
            rx_omega: 0.0,
            relay_cost: 1.0,
        }
    }
}

/// Per-engine scheduler state, shared across submission threads and workers.
pub struct SchedulerState {
    /// Per-rail completion-time models (Eq. 1).
    pub models: Vec<LinearCostModel>,
    /// Bytes this engine instance has in flight per rail and QoS class
    /// (A_d^local split by lane: `[latency, bulk]`, indexed by
    /// [`TransferClass::index`]).
    pub local_queued: Vec<[AtomicU64; TransferClass::COUNT]>,
    /// Soft exclusion flags set by the resilience layer (§4.3): an excluded
    /// rail's cost is effectively ∞ without heavyweight reconfiguration.
    pub excluded: Vec<AtomicBool>,
    /// Round-robin tie-break cursor (Algorithm 1, line 10).
    pub rr: AtomicUsize,
    pub params: SchedParams,
    /// Counter shard this engine writes in the shared fabric's per-rail
    /// queued-bytes stripes (`Fabric::register_engine`). 0 for standalone
    /// scheduler states and single-counter fabrics.
    pub fabric_shard: usize,
}

impl SchedulerState {
    pub fn new(n_rails: usize, params: SchedParams) -> Self {
        SchedulerState {
            models: (0..n_rails)
                .map(|_| LinearCostModel::new(params.init_beta0_ns, 1.0, params.ewma_alpha))
                .collect(),
            local_queued: (0..n_rails).map(|_| Default::default()).collect(),
            excluded: (0..n_rails).map(|_| AtomicBool::new(false)).collect(),
            rr: AtomicUsize::new(0),
            params,
            fabric_shard: 0,
        }
    }

    /// Same, but registered against a shared fabric: the state's queue
    /// accounting writes the engine's private counter shard.
    pub fn new_registered(n_rails: usize, params: SchedParams, fabric: &Fabric) -> Self {
        let mut s = SchedulerState::new(n_rails, params);
        s.fabric_shard = fabric.register_engine();
        s
    }

    #[inline]
    pub fn is_excluded(&self, rail: RailId) -> bool {
        self.excluded[rail.0 as usize].load(Ordering::Acquire)
    }

    pub fn exclude(&self, rail: RailId) -> bool {
        !self.excluded[rail.0 as usize].swap(true, Ordering::AcqRel)
    }

    pub fn readmit(&self, rail: RailId) -> bool {
        let was = self.excluded[rail.0 as usize].swap(false, Ordering::AcqRel);
        if was {
            // Fresh start for a re-admitted rail (§4.2 periodic reset).
            self.models[rail.0 as usize].reset();
        }
        was
    }

    /// Effective queued bytes A_d for a slice of `class`: local in-flight
    /// blended with the global (fabric-wide) count when load diffusion is
    /// enabled.
    ///
    /// With class isolation a latency slice only waits behind the latency
    /// lane, so its A_d excludes bulk backlog (which would otherwise poison
    /// latency-cost predictions); a bulk slice waits behind both lanes.
    /// Without isolation (single-lane fallback) every class shares one FIFO
    /// and both see the total.
    #[inline]
    pub fn queued(&self, fabric: &Fabric, rail: RailId, class: TransferClass) -> u64 {
        let lq = &self.local_queued[rail.0 as usize];
        let lat = lq[TransferClass::Latency.index()].load(Ordering::Relaxed);
        let bulk = lq[TransferClass::Bulk.index()].load(Ordering::Relaxed);
        let local = if self.params.class_isolation && class == TransferClass::Latency {
            lat
        } else {
            lat + bulk
        };
        let w = self.params.omega;
        if w <= 0.0 {
            return local;
        }
        // Class-scoped diffusion: under isolation a latency slice's global
        // term reads only the fabric's latency lane — the rail-level pool
        // used to be class-blind, so one engine's bulk flood inflated every
        // other engine's latency predictions.
        let global = if self.params.class_isolation && class == TransferClass::Latency {
            fabric.queued_bytes_class_from(self.fabric_shard, rail, class.index())
        } else {
            fabric.queued_bytes_from(self.fabric_shard, rail)
        };
        ((1.0 - w) * local as f64 + w * global as f64) as u64
    }

    /// Receiver-side pressure term: the destination node's ingestion
    /// backlog, class-scoped like [`SchedulerState::queued`] (a latency
    /// slice is not delayed by bulk ingest thanks to the dual lanes).
    #[inline]
    pub fn rx_queued(&self, fabric: &Fabric, node: NodeId, class: TransferClass) -> u64 {
        if self.params.class_isolation && class == TransferClass::Latency {
            fabric.ingress_bytes_class_from(self.fabric_shard, node, class.index())
        } else {
            fabric
                .ingress_bytes_class_from(self.fabric_shard, node, TransferClass::Latency.index())
                + fabric
                    .ingress_bytes_class_from(self.fabric_shard, node, TransferClass::Bulk.index())
        }
    }

    #[inline]
    pub fn penalty(&self, tier: Tier) -> f64 {
        self.params.tier_penalties[(tier as usize) - 1]
    }

    /// Predict completion time t̂_d (ns) for a slice of `len` and `class`
    /// on `rail`.
    #[inline]
    pub fn predict_ns(
        &self,
        fabric: &Fabric,
        rail: RailId,
        len: u64,
        bw: f64,
        class: TransferClass,
    ) -> (f64, f64) {
        let a = self.queued(fabric, rail, class);
        let serial = (a + len) as f64 / bw.max(1.0) * 1e9;
        let pred = self.models[rail.0 as usize].predict_ns(len, a, bw);
        (pred, serial)
    }

    /// Like [`SchedulerState::predict_ns`] but pricing **every node** on
    /// the path: when `rx_omega > 0` and the destination node is known, the
    /// receiver's ingestion backlog inflates the effective queue term, so
    /// sprays back off a node many peers are incasting into even when the
    /// local rail looks idle — and the same charge applies at every relay
    /// node of a multi-hop staged route, not just the final destination (a
    /// congested gateway must repel new routes exactly like a congested
    /// receiver). Each relay additionally pays the store-and-forward term
    /// `relay_cost × len/bw` on both the prediction and the serial floor.
    /// With `rx_omega == 0` and no relays this is exactly `predict_ns`.
    #[inline]
    pub fn predict_ns_to(
        &self,
        fabric: &Fabric,
        rail: RailId,
        len: u64,
        bw: f64,
        class: TransferClass,
        dst: Option<NodeId>,
        relays: &[NodeId],
    ) -> (f64, f64) {
        let mut a = self.queued(fabric, rail, class);
        let w = self.params.rx_omega;
        if w > 0.0 {
            if let Some(node) = dst {
                a += (w * self.rx_queued(fabric, node, class) as f64) as u64;
            }
            for &relay in relays {
                a += (w * self.rx_queued(fabric, relay, class) as f64) as u64;
            }
        }
        let bounce = relays.len() as f64 * self.params.relay_cost * len as f64
            / bw.max(1.0)
            * 1e9;
        let serial = (a + len) as f64 / bw.max(1.0) * 1e9 + bounce;
        let pred = self.models[rail.0 as usize].predict_ns(len, a, bw) + bounce;
        (pred, serial)
    }

    /// Adaptive per-rail slice size (bytes): how much of a transfer the
    /// dispatcher should carve for `rail` right now. Derived from the
    /// rail's learned cost model —
    ///
    /// * amortization floor: the wire (serial) term should dwarf the fixed
    ///   per-slice cost β0, so size grows with the congestion-corrected
    ///   bandwidth `bw/β1`;
    /// * head-of-line cap: one slice should not occupy the rail longer
    ///   than a target wire time, so size shrinks as β1 (learned
    ///   congestion) grows;
    /// * jitter guard: a noisy rail (P99 ≫ P50 service latency) halves the
    ///   size — finer slices re-balance faster when quality is unstable.
    ///
    /// The result is clamped to `[gamma_min, gamma_max] × min_slice`.
    pub fn adaptive_slice_bytes(
        &self,
        fabric: &Fabric,
        rail: RailId,
        bw: f64,
        min_slice: u64,
    ) -> u64 {
        /// The serial term should be ≥ this multiple of β0. Calibrated for
        /// the simulation's scaled bandwidths (see `topology::profile`'s
        /// `SCALE`): the sim RDMA rail moves 2.5e8 B/s, so 64×β0 with a
        /// fresh model (β0 = 20 µs) lands at ~320 KB — ~5 slices/MiB
        /// instead of the 16 that a 64 KiB min_slice would carve.
        const AMORT_FACTOR: f64 = 64.0;
        /// Max wire time one slice may occupy a healthy rail (ns).
        const TARGET_SLICE_NS: f64 = 2_000_000.0;
        /// P99/P50 service-latency ratio above which a rail counts jittery.
        const JITTER_RATIO: f64 = 4.0;
        /// Histogram samples needed before the jitter guard engages.
        const JITTER_MIN_SAMPLES: u64 = 64;

        let m = &self.models[rail.0 as usize];
        let beta1 = m.beta1().max(0.05);
        let eff_bw = bw.max(1.0) / beta1;
        let amort = AMORT_FACTOR * m.beta0_ns() * eff_bw / 1e9;
        let cap = TARGET_SLICE_NS * eff_bw / 1e9;
        let mut size = amort.min(cap);
        let hist = &fabric.rail(rail).latency;
        if hist.count() >= JITTER_MIN_SAMPLES {
            let p50 = hist.p50().max(1);
            if hist.p99() as f64 > JITTER_RATIO * p50 as f64 {
                size *= 0.5;
            }
        }
        let lo = (self.params.gamma_min * min_slice as f64).max(1.0);
        let hi = (self.params.gamma_max * min_slice as f64).max(lo);
        size.clamp(lo, hi) as u64
    }

    /// Account a dispatched slice (Algorithm 1, line 11).
    pub fn add_queued(&self, fabric: &Fabric, rail: RailId, len: u64, class: TransferClass) {
        self.local_queued[rail.0 as usize][class.index()].fetch_add(len, Ordering::Relaxed);
        fabric.add_queued_at(self.fabric_shard, rail, len, class.index());
    }

    /// Account receiver-side bytes for a slice headed to `node` (paired
    /// with [`SchedulerState::sub_ingress`] on completion/give-up). Only
    /// called when `rx_omega > 0` — the counters are pure prediction
    /// input, so the default sender-side mode skips the extra RMWs.
    #[inline]
    pub fn add_ingress(&self, fabric: &Fabric, node: NodeId, len: u64, class: TransferClass) {
        fabric.add_ingress_at(self.fabric_shard, node, len, class.index());
    }

    #[inline]
    pub fn sub_ingress(&self, fabric: &Fabric, node: NodeId, len: u64, class: TransferClass) {
        fabric.sub_ingress_at(self.fabric_shard, node, len, class.index());
    }

    /// Claim ingress for a whole route: the destination plus every relay
    /// node the chosen candidate bounces through. A multi-hop slice
    /// pressures each staging host it crosses, so `predict_ns_to` can
    /// charge congested gateways (which the dst-only claim missed).
    #[inline]
    pub fn add_ingress_route(
        &self,
        fabric: &Fabric,
        dst: NodeId,
        relays: &[NodeId],
        len: u64,
        class: TransferClass,
    ) {
        self.add_ingress(fabric, dst, len, class);
        for &relay in relays {
            self.add_ingress(fabric, relay, len, class);
        }
    }

    /// Release the claims of [`SchedulerState::add_ingress_route`]. Must be
    /// called with the *same* relay set that was claimed — on a retry that
    /// switches candidates the caller swaps relay claims explicitly.
    #[inline]
    pub fn sub_ingress_route(
        &self,
        fabric: &Fabric,
        dst: NodeId,
        relays: &[NodeId],
        len: u64,
        class: TransferClass,
    ) {
        self.sub_ingress(fabric, dst, len, class);
        for &relay in relays {
            self.sub_ingress(fabric, relay, len, class);
        }
    }

    /// Account a completed / failed slice. Saturating on both ledgers: the
    /// engine-local one asserts in debug builds (dispatch/completion are
    /// strictly paired within one engine, so a clamp is a local bug), the
    /// fabric one clamps + counts (see `Fabric::sub_queued_at`).
    pub fn sub_queued(&self, fabric: &Fabric, rail: RailId, len: u64, class: TransferClass) {
        let lq = &self.local_queued[rail.0 as usize][class.index()];
        let mut clamped = false;
        let _ = lq.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            clamped = v < len;
            Some(v.saturating_sub(len))
        });
        debug_assert!(!clamped, "local queued-bytes underflow on {rail}");
        fabric.sub_queued_at(self.fabric_shard, rail, len, class.index());
    }

    /// Feedback (§4.2): fold the observed completion time into the rail's
    /// model.
    pub fn observe(&self, rail: RailId, predicted_ns: f64, serial_ns: f64, observed_ns: f64) {
        self.models[rail.0 as usize].observe_ns(predicted_ns, observed_ns, serial_ns);
    }

    /// Batched feedback: fold `n` completions (their mean serial/observed
    /// times) into the rail's model in one EWMA step with the equivalent
    /// total weight (see `LinearCostModel::observe_batch_ns`).
    pub fn observe_batch(&self, rail: RailId, n: u64, mean_observed_ns: f64, mean_serial_ns: f64) {
        self.models[rail.0 as usize].observe_batch_ns(n, mean_observed_ns, mean_serial_ns);
    }

    /// Periodic state reset (§4.2): forget learned penalties everywhere so
    /// recovered paths re-enter the pool.
    pub fn reset_models(&self) {
        for m in &self.models {
            m.reset();
        }
    }
}

/// Everything a policy may consult when picking a rail.
pub struct SchedCtx<'a> {
    pub sched: &'a SchedulerState,
    pub fabric: &'a Fabric,
    pub topo: &'a Topology,
    /// QoS class of the slice being placed (selects which per-class queue
    /// statistics cost predictions read).
    pub class: TransferClass,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::topology::profile::build_profile;
    use crate::topology::NodeId;
    use crate::topology::FabricKind;

    fn setup() -> (Topology, Fabric, SchedulerState) {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let s = SchedulerState::new(t.rails.len(), SchedParams::default());
        (t, f, s)
    }

    #[test]
    fn queue_accounting_local_and_global() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        s.add_queued(&f, rail, 1000, TransferClass::Bulk);
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 1000);
        assert_eq!(f.rail(rail).queued_bytes(), 1000);
        s.sub_queued(&f, rail, 400, TransferClass::Bulk);
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 600);
        s.sub_queued(&f, rail, 600, TransferClass::Bulk);
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 0);
        assert_eq!(f.rail(rail).queued_bytes(), 0);
    }

    #[test]
    fn oversubtraction_saturates_and_asserts_in_debug() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        s.add_queued(&f, rail, 600, TransferClass::Bulk);
        if cfg!(debug_assertions) {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                s.sub_queued(&f, rail, 10_000, TransferClass::Bulk)
            }));
            assert!(r.is_err(), "debug builds must flag the accounting bug");
        } else {
            s.sub_queued(&f, rail, 10_000, TransferClass::Bulk);
        }
        // Saturating semantics in every build: no wrap to ~2^64.
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 0);
    }

    #[test]
    fn class_isolation_splits_accounting() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        s.add_queued(&f, rail, 10_000, TransferClass::Bulk);
        s.add_queued(&f, rail, 1_000, TransferClass::Latency);
        // A latency slice only sees latency bytes ahead of it; a bulk slice
        // waits behind both lanes. The fabric-global count stays total.
        assert_eq!(s.queued(&f, rail, TransferClass::Latency), 1_000);
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 11_000);
        assert_eq!(f.rail(rail).queued_bytes(), 11_000);
        s.sub_queued(&f, rail, 1_000, TransferClass::Latency);
        assert_eq!(s.queued(&f, rail, TransferClass::Latency), 0);
        assert_eq!(s.queued(&f, rail, TransferClass::Bulk), 10_000);
    }

    #[test]
    fn without_isolation_latency_sees_total() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let p = SchedParams {
            class_isolation: false,
            ..Default::default()
        };
        let s = SchedulerState::new(t.rails.len(), p);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        s.add_queued(&f, rail, 10_000, TransferClass::Bulk);
        assert_eq!(s.queued(&f, rail, TransferClass::Latency), 10_000);
    }

    #[test]
    fn diffusion_blends_global_queue() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let p = SchedParams {
            omega: 0.5,
            ..Default::default()
        };
        let s1 = SchedulerState::new(t.rails.len(), p.clone());
        let s2 = SchedulerState::new(t.rails.len(), p);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        // Engine 2 loads the rail; engine 1 must see half of it via ω.
        s2.add_queued(&f, rail, 10_000, TransferClass::Bulk);
        assert_eq!(s1.queued(&f, rail, TransferClass::Bulk), 5_000);
    }

    #[test]
    fn diffusion_is_class_scoped() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let p = SchedParams {
            omega: 0.5,
            ..Default::default()
        };
        let s1 = SchedulerState::new(t.rails.len(), p.clone());
        let s2 = SchedulerState::new(t.rails.len(), p);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        // Engine 2 floods the rail with Bulk. Engine 1's latency-class view
        // must stay clean — the fabric lane it diffuses from is per-class.
        s2.add_queued(&f, rail, 100 << 20, TransferClass::Bulk);
        assert_eq!(s1.queued(&f, rail, TransferClass::Latency), 0);
        // Bulk (and non-isolated) views still see the shared backlog.
        assert!(s1.queued(&f, rail, TransferClass::Bulk) > 0);
    }

    #[test]
    fn rx_pricing_inflates_prediction_toward_busy_node() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let p = SchedParams {
            rx_omega: 1.0,
            ..Default::default()
        };
        let s = SchedulerState::new(t.rails.len(), p);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let bw = t.rail(rail).bw_bytes_per_sec;
        let quiet = t.nodes[0];
        let busy = t.nodes[1];
        s.add_ingress(&f, busy, 64 << 20, TransferClass::Bulk);
        let (p_quiet, _) =
            s.predict_ns_to(&f, rail, 1 << 20, bw, TransferClass::Bulk, Some(quiet), &[]);
        let (p_busy, _) =
            s.predict_ns_to(&f, rail, 1 << 20, bw, TransferClass::Bulk, Some(busy), &[]);
        assert!(p_busy > 2.0 * p_quiet, "quiet={p_quiet} busy={p_busy}");
        // Latency-class slices are not priced against bulk ingest.
        let (l_busy, _) =
            s.predict_ns_to(&f, rail, 1 << 20, bw, TransferClass::Latency, Some(busy), &[]);
        assert!((l_busy - p_quiet).abs() / p_quiet < 0.01);
        // rx_omega = 0 + no relays restores plain predict_ns exactly.
        let s0 = SchedulerState::new(t.rails.len(), SchedParams::default());
        let (a, sa) = s0.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
        let (b, sb) =
            s0.predict_ns_to(&f, rail, 1 << 20, bw, TransferClass::Bulk, Some(busy), &[]);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        s.sub_ingress(&f, busy, 64 << 20, TransferClass::Bulk);
        assert_eq!(f.ingress_bytes(busy), 0);
    }

    #[test]
    fn relay_pricing_charges_every_hop() {
        let t = build_profile("h800_hgx", 3).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let p = SchedParams {
            rx_omega: 1.0,
            ..Default::default()
        };
        let s = SchedulerState::new(t.rails.len(), p);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let bw = t.rail(rail).bw_bytes_per_sec;
        let len: u64 = 1 << 20;
        let dst = t.nodes[1];
        let relay = t.nodes[2];

        // Store-and-forward term: one relay costs ~one extra serialization
        // of `len` at the bottleneck bandwidth (relay_cost = 1.0 default).
        let (p0, s0) = s.predict_ns_to(&f, rail, len, bw, TransferClass::Bulk, Some(dst), &[]);
        let (p1, s1) =
            s.predict_ns_to(&f, rail, len, bw, TransferClass::Bulk, Some(dst), &[relay]);
        let per_hop = len as f64 / bw * 1e9;
        assert!((p1 - p0 - per_hop).abs() < 1.0, "p0={p0} p1={p1}");
        assert!((s1 - s0 - per_hop).abs() < 1.0, "s0={s0} s1={s1}");

        // Congestion at the relay inflates the route's price even when the
        // final destination is idle — the bug this PR fixes priced only dst.
        s.add_ingress(&f, relay, 64 << 20, TransferClass::Bulk);
        let (p_busy, _) =
            s.predict_ns_to(&f, rail, len, bw, TransferClass::Bulk, Some(dst), &[relay]);
        assert!(p_busy > 2.0 * p1, "idle={p1} busy-relay={p_busy}");
        s.sub_ingress(&f, relay, 64 << 20, TransferClass::Bulk);

        // relay_cost = 0 ablates the store-and-forward term entirely.
        let pz = SchedParams {
            rx_omega: 1.0,
            relay_cost: 0.0,
            ..Default::default()
        };
        let sz = SchedulerState::new(t.rails.len(), pz);
        let (z0, _) = sz.predict_ns_to(&f, rail, len, bw, TransferClass::Bulk, Some(dst), &[]);
        let (z1, _) =
            sz.predict_ns_to(&f, rail, len, bw, TransferClass::Bulk, Some(dst), &[relay]);
        assert_eq!(z0, z1);

        // Route-claim helpers: claim at dst + every relay, release drains all.
        s.add_ingress_route(&f, dst, &[relay], 4_096, TransferClass::Bulk);
        assert_eq!(f.ingress_bytes(dst), 4_096);
        assert_eq!(f.ingress_bytes(relay), 4_096);
        s.sub_ingress_route(&f, dst, &[relay], 4_096, TransferClass::Bulk);
        assert_eq!(f.ingress_bytes(dst), 0);
        assert_eq!(f.ingress_bytes(relay), 0);
    }

    #[test]
    fn adaptive_slice_shrinks_under_congestion_and_respects_clamps() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        // Synthetic fast rail (sim units) so the healthy size sits well
        // inside the clamp window and congestion has room to shrink it.
        let bw = 1e9;
        let min_slice = 64 << 10;
        let healthy = s.adaptive_slice_bytes(&f, rail, bw, min_slice);
        assert!(healthy >= min_slice);
        assert!(healthy <= 64 * min_slice, "hi clamp: {healthy}");
        assert!(
            healthy >= 8 * min_slice,
            "a healthy fast rail should take coarse slices, got {healthy}"
        );
        // Teach the model this rail runs ~8x slower than nominal.
        for _ in 0..60 {
            let (pred, serial) = s.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
            s.observe(rail, pred, serial, 8.0 * serial);
        }
        let congested = s.adaptive_slice_bytes(&f, rail, bw, min_slice);
        assert!(
            congested * 4 <= healthy,
            "healthy={healthy} congested={congested}"
        );
        assert!(congested >= min_slice, "lo clamp: {congested}");
    }

    #[test]
    fn adaptive_slice_halves_on_jittery_rail() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        // Synthetic fast rail (sim units), as in the congestion test.
        let bw = 1e9;
        let min_slice = 64 << 10;
        // Put the model mid-range first so neither clamp masks the halving.
        for _ in 0..60 {
            let (pred, serial) = s.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
            s.observe(rail, pred, serial, 8.0 * serial);
        }
        let calm = s.adaptive_slice_bytes(&f, rail, bw, min_slice);
        // Now make the observed service latency bimodal: P99 ≫ P50.
        let hist = &f.rail(rail).latency;
        for _ in 0..97 {
            hist.record(50_000);
        }
        for _ in 0..3 {
            hist.record(5_000_000);
        }
        let jittery = s.adaptive_slice_bytes(&f, rail, bw, min_slice);
        assert!(
            jittery <= calm / 2 + 1,
            "calm={calm} jittery={jittery}"
        );
        assert!(jittery >= min_slice);
    }

    #[test]
    fn exclusion_roundtrip_resets_model() {
        let (_t, _f, s) = setup();
        let rail = RailId(0);
        // Poison the model.
        s.observe(rail, 1000.0, 1000.0, 1_000_000.0);
        assert!(s.models[0].beta1() > 1.0);
        assert!(s.exclude(rail));
        assert!(!s.exclude(rail)); // already excluded
        assert!(s.is_excluded(rail));
        assert!(s.readmit(rail));
        assert!(!s.is_excluded(rail));
        assert_eq!(s.models[0].beta1(), 1.0); // reset on re-admission
    }

    #[test]
    fn predict_grows_with_queue() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let bw = t.rail(rail).bw_bytes_per_sec;
        let (p0, _) = s.predict_ns(&f, rail, 64 << 10, bw, TransferClass::Bulk);
        s.add_queued(&f, rail, 8 << 20, TransferClass::Bulk);
        let (p1, _) = s.predict_ns(&f, rail, 64 << 10, bw, TransferClass::Bulk);
        assert!(p1 > 5.0 * p0, "p0={p0} p1={p1}");
        // Bulk backlog must not poison a latency-class prediction.
        let (pl, _) = s.predict_ns(&f, rail, 64 << 10, bw, TransferClass::Latency);
        assert!((pl - p0).abs() / p0 < 0.01, "p0={p0} pl={pl}");
    }

    #[test]
    fn reset_models_restores_predictions() {
        let (t, f, s) = setup();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let bw = t.rail(rail).bw_bytes_per_sec;
        let (before, _) = s.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
        for _ in 0..20 {
            s.observe(rail, before, before, before * 10.0);
        }
        let (poisoned, _) = s.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
        assert!(poisoned > 2.0 * before);
        s.reset_models();
        let (after, _) = s.predict_ns(&f, rail, 1 << 20, bw, TransferClass::Bulk);
        assert!((after - before).abs() / before < 0.01);
    }
}
