//! Batch control blocks with hierarchical atomic completion counters (§4.4).
//!
//! Applications observe only coarse counters (batch X has N transfers
//! remaining); workers decrement a per-transfer slice counter, and the last
//! slice of a transfer decrements the batch counter — two levels, all
//! lock-free on the hot path, with a condvar only for the final wakeup.

use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Handle to a batch of transfers.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct BatchId(pub u64);

impl std::fmt::Display for BatchId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "batch{}", self.0)
    }
}

/// Completion status of a batch.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct BatchStatus {
    pub total_transfers: u64,
    pub remaining_transfers: u64,
    pub failed_transfers: u64,
}

impl BatchStatus {
    pub fn done(&self) -> bool {
        self.remaining_transfers == 0
    }
    pub fn ok(&self) -> bool {
        self.done() && self.failed_transfers == 0
    }
}

/// Top level of the counter hierarchy: one per allocated batch.
pub struct BatchState {
    pub id: BatchId,
    total: AtomicU64,
    remaining: AtomicU64,
    failed: AtomicU64,
    mu: Mutex<()>,
    cv: Condvar,
}

impl BatchState {
    fn new(id: BatchId) -> Self {
        BatchState {
            id,
            total: AtomicU64::new(0),
            remaining: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            mu: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Register `n` more transfers in this batch (called at submit).
    pub fn add_transfers(&self, n: u64) {
        self.total.fetch_add(n, Ordering::Relaxed);
        self.remaining.fetch_add(n, Ordering::Release);
    }

    /// Called by the datapath when a transfer's last slice completes.
    pub fn complete_transfer(&self, ok: bool) {
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.mu.lock().unwrap();
            self.cv.notify_all();
        }
    }

    pub fn status(&self) -> BatchStatus {
        BatchStatus {
            total_transfers: self.total.load(Ordering::Relaxed),
            remaining_transfers: self.remaining.load(Ordering::Acquire),
            failed_transfers: self.failed.load(Ordering::Relaxed),
        }
    }

    /// Block until all transfers submitted so far complete or `timeout`.
    pub fn wait(&self, timeout: Duration) -> Result<BatchStatus> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.mu.lock().unwrap();
        loop {
            let st = self.status();
            if st.done() {
                return Ok(st);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Timeout(self.id.0));
            }
            let (g, _timeout_res) = self
                .cv
                .wait_timeout(guard, deadline - now)
                .map_err(|_| Error::Shutdown)?;
            guard = g;
        }
    }
}

/// Second level: one per logical transfer, counting its slices.
pub struct TransferState {
    pub batch: Arc<BatchState>,
    remaining_slices: AtomicU64,
    failed: AtomicBool,
}

impl TransferState {
    pub fn new(batch: Arc<BatchState>, slices: u64) -> Arc<TransferState> {
        Arc::new(TransferState {
            batch,
            remaining_slices: AtomicU64::new(slices),
            failed: AtomicBool::new(false),
        })
    }

    /// Mark the whole transfer failed (retries exhausted on some slice).
    pub fn mark_failed(&self) {
        self.failed.store(true, Ordering::Release);
    }

    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// One slice finished (successfully or after giving up). Returns true if
    /// this was the transfer's last slice.
    pub fn complete_slice(&self) -> bool {
        if self.remaining_slices.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.batch.complete_transfer(!self.is_failed());
            true
        } else {
            false
        }
    }

    pub fn remaining(&self) -> u64 {
        self.remaining_slices.load(Ordering::Acquire)
    }
}

/// Registry of live batches.
pub struct BatchTable {
    next: AtomicU64,
    map: RwLock<HashMap<u64, Arc<BatchState>>>,
}

impl Default for BatchTable {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchTable {
    pub fn new() -> Self {
        BatchTable {
            next: AtomicU64::new(1),
            map: RwLock::new(HashMap::new()),
        }
    }

    pub fn allocate(&self) -> BatchId {
        let id = BatchId(self.next.fetch_add(1, Ordering::Relaxed));
        self.map
            .write()
            .unwrap()
            .insert(id.0, Arc::new(BatchState::new(id)));
        id
    }

    pub fn get(&self, id: BatchId) -> Result<Arc<BatchState>> {
        self.map
            .read()
            .unwrap()
            .get(&id.0)
            .cloned()
            .ok_or(Error::UnknownBatch(id.0))
    }

    /// Free a completed batch's control block.
    pub fn release(&self, id: BatchId) -> Result<()> {
        self.map
            .write()
            .unwrap()
            .remove(&id.0)
            .map(|_| ())
            .ok_or(Error::UnknownBatch(id.0))
    }

    pub fn live(&self) -> usize {
        self.map.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn batch_lifecycle() {
        let t = BatchTable::new();
        let id = t.allocate();
        let b = t.get(id).unwrap();
        b.add_transfers(2);
        assert!(!b.status().done());
        b.complete_transfer(true);
        b.complete_transfer(true);
        let st = b.status();
        assert!(st.ok());
        assert_eq!(st.total_transfers, 2);
        t.release(id).unwrap();
        assert!(t.get(id).is_err());
    }

    #[test]
    fn failed_transfer_counted() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        b.add_transfers(3);
        b.complete_transfer(true);
        b.complete_transfer(false);
        b.complete_transfer(true);
        let st = b.status();
        assert!(st.done());
        assert!(!st.ok());
        assert_eq!(st.failed_transfers, 1);
    }

    #[test]
    fn hierarchical_slice_counting() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        b.add_transfers(1);
        let tr = TransferState::new(Arc::clone(&b), 4);
        assert!(!tr.complete_slice());
        assert!(!tr.complete_slice());
        assert!(!tr.complete_slice());
        assert!(!b.status().done());
        assert!(tr.complete_slice()); // last slice completes the transfer
        assert!(b.status().ok());
    }

    #[test]
    fn transfer_failure_propagates_to_batch() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        b.add_transfers(1);
        let tr = TransferState::new(Arc::clone(&b), 2);
        tr.mark_failed();
        tr.complete_slice();
        tr.complete_slice();
        let st = b.status();
        assert!(st.done() && !st.ok());
    }

    #[test]
    fn wait_blocks_until_done() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        b.add_transfers(1);
        let b2 = Arc::clone(&b);
        let h = thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.complete_transfer(true);
        });
        let st = b.wait(Duration::from_secs(5)).unwrap();
        assert!(st.ok());
        h.join().unwrap();
    }

    #[test]
    fn wait_times_out() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        b.add_transfers(1);
        let e = b.wait(Duration::from_millis(20));
        assert!(matches!(e, Err(Error::Timeout(_))));
    }

    #[test]
    fn empty_batch_is_immediately_done() {
        let t = BatchTable::new();
        let b = t.get(t.allocate()).unwrap();
        assert!(b.wait(Duration::from_millis(1)).unwrap().ok());
    }
}
