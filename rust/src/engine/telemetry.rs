//! Engine-level counters and metric snapshots.
//!
//! Per-rail wire statistics live in [`crate::fabric::RailState`]; this module
//! adds the engine's own event counters (dispatches, retries, exclusions,
//! probes, …) and a combined snapshot used by the CLI, benches, and tests.

use super::TransferClass;
use crate::fabric::{Fabric, RailHealth};
use crate::topology::{RailId, Topology};
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free engine event counters.
#[derive(Default)]
pub struct EngineStats {
    pub batches_allocated: AtomicU64,
    pub transfers_submitted: AtomicU64,
    pub slices_dispatched: AtomicU64,
    pub slices_completed: AtomicU64,
    /// Completed slices split by QoS class (`[latency, bulk]`, indexed by
    /// [`TransferClass::index`]).
    pub slices_completed_class: [AtomicU64; TransferClass::COUNT],
    pub slice_failures: AtomicU64,
    pub retries: AtomicU64,
    pub exclusions: AtomicU64,
    pub readmissions: AtomicU64,
    pub probes: AtomicU64,
    pub model_resets: AtomicU64,
    pub permanent_failures: AtomicU64,
    pub staged_plans: AtomicU64,
    pub bytes_submitted: AtomicU64,
    /// Enqueue attempts that found a full datapath lane and had to spin
    /// (one bump per stall episode, not per retry) — the backpressure
    /// signal for undersized rings.
    pub ring_full_stalls: AtomicU64,
    /// Ring-full stall episodes where other engines also had bytes queued
    /// on the rail (fabric-global queued > this engine's local queued):
    /// backpressure caused by sharing the rail, not by this engine's own
    /// burst. The fleet-contention signal.
    pub cross_engine_stalls: AtomicU64,
    /// Enqueues that actually unparked the rail worker (it was parked).
    pub wakeups_sent: AtomicU64,
    /// Enqueues that skipped the unpark because the worker was already
    /// running — the win from flag-gated (batched) wakeup versus the old
    /// unconditional unpark-per-enqueue.
    pub wakeups_coalesced: AtomicU64,
    /// Completions of rerouted slices (`attempt > 0`) — the moment a
    /// resilience retry actually landed on a surviving rail.
    pub reroutes_completed: AtomicU64,
    /// Timestamp (ns since process epoch, monotone max) of the most recent
    /// rerouted-slice completion. The chaos healing probe measures
    /// injection → first-reroute latency from this stamp, so the metric is
    /// poll-rate-independent: the datapath records the true completion
    /// instant, the probe merely discovers it.
    pub last_reroute_complete_ns: AtomicU64,
    /// Slices handed to the datapath and not yet fully resolved
    /// (completed, or failed past the retry budget). Engine shutdown
    /// drains this to zero so no slice outlives its engine handle.
    pub inflight: AtomicU64,
}

impl EngineStats {
    #[inline]
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
    pub fn snapshot(&self) -> StatCounters {
        let lat = TransferClass::Latency.index();
        let bulk = TransferClass::Bulk.index();
        StatCounters {
            batches_allocated: self.batches_allocated.load(Ordering::Relaxed),
            transfers_submitted: self.transfers_submitted.load(Ordering::Relaxed),
            slices_dispatched: self.slices_dispatched.load(Ordering::Relaxed),
            slices_completed: self.slices_completed.load(Ordering::Relaxed),
            slices_completed_latency: self.slices_completed_class[lat].load(Ordering::Relaxed),
            slices_completed_bulk: self.slices_completed_class[bulk].load(Ordering::Relaxed),
            slice_failures: self.slice_failures.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            exclusions: self.exclusions.load(Ordering::Relaxed),
            readmissions: self.readmissions.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            model_resets: self.model_resets.load(Ordering::Relaxed),
            permanent_failures: self.permanent_failures.load(Ordering::Relaxed),
            staged_plans: self.staged_plans.load(Ordering::Relaxed),
            bytes_submitted: self.bytes_submitted.load(Ordering::Relaxed),
            ring_full_stalls: self.ring_full_stalls.load(Ordering::Relaxed),
            cross_engine_stalls: self.cross_engine_stalls.load(Ordering::Relaxed),
            wakeups_sent: self.wakeups_sent.load(Ordering::Relaxed),
            wakeups_coalesced: self.wakeups_coalesced.load(Ordering::Relaxed),
            reroutes_completed: self.reroutes_completed.load(Ordering::Relaxed),
            last_reroute_complete_ns: self.last_reroute_complete_ns.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatCounters {
    pub batches_allocated: u64,
    pub transfers_submitted: u64,
    pub slices_dispatched: u64,
    pub slices_completed: u64,
    pub slices_completed_latency: u64,
    pub slices_completed_bulk: u64,
    pub slice_failures: u64,
    pub retries: u64,
    pub exclusions: u64,
    pub readmissions: u64,
    pub probes: u64,
    pub model_resets: u64,
    pub permanent_failures: u64,
    pub staged_plans: u64,
    pub bytes_submitted: u64,
    pub ring_full_stalls: u64,
    pub cross_engine_stalls: u64,
    pub wakeups_sent: u64,
    pub wakeups_coalesced: u64,
    pub reroutes_completed: u64,
    pub last_reroute_complete_ns: u64,
}

/// Per-rail view combining topology, fabric counters, and scheduler state.
#[derive(Clone, Debug)]
pub struct RailSnapshot {
    pub rail: RailId,
    pub name: String,
    pub fabric: &'static str,
    pub health: RailHealth,
    pub excluded: bool,
    pub queued_bytes: u64,
    pub bytes_carried: u64,
    pub slices_ok: u64,
    pub slices_failed: u64,
    pub mean_latency_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    /// Latency-class slice count / P99 on this rail.
    pub latency_class_slices: u64,
    pub latency_class_p99_ns: u64,
    /// Bulk-class slice count / P99 on this rail.
    pub bulk_class_slices: u64,
    pub bulk_class_p99_ns: u64,
    pub beta0_ns: f64,
    pub beta1: f64,
    /// Slice size (bytes) adaptive-γ mode would carve for this rail right
    /// now, from the learned (β0, β1) and the latency histogram (jitter
    /// backoff). Meaningful telemetry even when the engine runs fixed γ.
    pub adaptive_slice_bytes: u64,
}

/// Build per-rail snapshots. `min_slice` anchors the adaptive-γ clamp
/// window (the engine passes its `EngineConfig::min_slice`).
pub fn rail_snapshots(
    topo: &Topology,
    fabric: &Fabric,
    sched: &crate::engine::sched::SchedulerState,
    min_slice: u64,
) -> Vec<RailSnapshot> {
    topo.rails
        .iter()
        .map(|def| {
            let st = fabric.rail(def.id);
            let m = &sched.models[def.id.0 as usize];
            RailSnapshot {
                rail: def.id,
                name: def.name.clone(),
                fabric: def.fabric.name(),
                health: st.health(),
                excluded: sched.is_excluded(def.id),
                queued_bytes: st.queued_bytes(),
                bytes_carried: st.bytes_carried.load(Ordering::Relaxed),
                slices_ok: st.slices_ok.load(Ordering::Relaxed),
                slices_failed: st.slices_failed.load(Ordering::Relaxed),
                mean_latency_ns: st.latency.mean(),
                p50_ns: st.latency.p50(),
                p99_ns: st.latency.p99(),
                latency_class_slices: st.class_latency[TransferClass::Latency.index()].count(),
                latency_class_p99_ns: st.class_latency[TransferClass::Latency.index()].p99(),
                bulk_class_slices: st.class_latency[TransferClass::Bulk.index()].count(),
                bulk_class_p99_ns: st.class_latency[TransferClass::Bulk.index()].p99(),
                beta0_ns: m.beta0_ns(),
                beta1: m.beta1(),
                adaptive_slice_bytes: sched.adaptive_slice_bytes(
                    fabric,
                    def.id,
                    def.bw_bytes_per_sec,
                    min_slice,
                ),
            }
        })
        .collect()
}

/// Render rail snapshots as an aligned table (CLI / bench output).
pub fn format_rail_table(snaps: &[RailSnapshot]) -> String {
    let mut s = String::from(
        "rail           fabric    health    excl  queued      bytes        ok      fail  p50         p99         b1\n",
    );
    for r in snaps {
        s.push_str(&format!(
            "{:<14} {:<9} {:<9} {:<5} {:<11} {:<12} {:<7} {:<5} {:<11} {:<11} {:.2}\n",
            r.name,
            r.fabric,
            format!("{:?}", r.health),
            if r.excluded { "yes" } else { "no" },
            crate::util::fmt_bytes(r.queued_bytes),
            crate::util::fmt_bytes(r.bytes_carried),
            r.slices_ok,
            r.slices_failed,
            crate::util::fmt_ns(r.p50_ns),
            crate::util::fmt_ns(r.p99_ns),
            r.beta1,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sched::{SchedParams, SchedulerState};
    use crate::fabric::FabricConfig;
    use crate::topology::profile::build_profile;

    #[test]
    fn counters_snapshot_roundtrip() {
        let s = EngineStats::default();
        EngineStats::bump(&s.retries);
        EngineStats::bump(&s.retries);
        EngineStats::bump(&s.probes);
        EngineStats::bump(&s.ring_full_stalls);
        EngineStats::bump(&s.slices_completed_class[TransferClass::Latency.index()]);
        let snap = s.snapshot();
        assert_eq!(snap.retries, 2);
        assert_eq!(snap.probes, 1);
        assert_eq!(snap.slices_completed, 0);
        assert_eq!(snap.ring_full_stalls, 1);
        assert_eq!(snap.slices_completed_latency, 1);
        assert_eq!(snap.slices_completed_bulk, 0);
    }

    #[test]
    fn rail_snapshot_covers_all_rails() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let sched = SchedulerState::new(t.rails.len(), SchedParams::default());
        let snaps = rail_snapshots(&t, &f, &sched, 64 << 10);
        assert_eq!(snaps.len(), t.rails.len());
        let table = format_rail_table(&snaps);
        assert!(table.contains("n0-mlx0"));
        assert!(table.contains("nvlink"));
        // Fresh models must size every rail inside the clamp window.
        for s in &snaps {
            assert!(s.adaptive_slice_bytes >= 64 << 10);
            assert!(s.adaptive_slice_bytes <= 64 * (64 << 10));
        }
    }
}
