//! Phase 3 — proactive dual-layer resilience (§4.3).
//!
//! **Link level**: a slice failure soft-excludes its rail (cost → ∞, no
//! heavyweight reconfiguration) and the slice is re-executed idempotently on
//! an alternative path chosen for *reliability* (healthiest tier first),
//! bypassing the predictive cost model — but its bytes still count in the
//! global queue statistics, so recovery traffic cannot starve other flows.
//! A background prober heartbeats excluded rails and re-admits them (with a
//! fresh cost model) once responsive.
//!
//! **Transport level**: because Phase 1 plans retain candidates across
//! *multiple* fabrics, exhausting one backend's rails automatically promotes
//! the next-best transport (NVLink → RDMA → TCP) for subsequent attempts —
//! backend substitution with no application involvement.

use super::core::EngineCore;
use super::slice::SliceDesc;
use super::telemetry::EngineStats;
use crate::fabric::RailHealth;
use crate::log;
use crate::util::clock;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Handle a failed slice: exclude the rail, retry on the best alternative,
/// or give up and mark the transfer failed.
pub(crate) fn on_slice_failure(core: &Arc<EngineCore>, mut slice: SliceDesc) {
    let failed_rail = slice.plan.candidates[slice.cand_idx].rail;

    if core.policy.failover() {
        // Soft exclusion (§4.3): drop the rail from the candidate pool.
        if core.sched.exclude(failed_rail) {
            EngineStats::bump(&core.stats.exclusions);
            log::info!("resilience: soft-excluded {failed_rail}");
        }
        if slice.attempt < core.config.max_retries {
            slice.attempt += 1;
            EngineStats::bump(&core.stats.retries);
            // Reliability-first reroute: healthy, non-excluded, best tier.
            // The slice keeps its QoS class — a rerouted latency slice
            // re-enters the latency lane and latency-class accounting.
            if let Some(idx) = pick_reliable(core, &slice, failed_rail) {
                let prev_idx = slice.cand_idx;
                slice.cand_idx = idx;
                let cand = &slice.plan.candidates[idx];
                // The retry keeps its destination-ingress claim (same
                // receiver) — but when the new candidate bounces through a
                // *different* relay set, the relay claims must follow the
                // route the slice will actually take, or the release at
                // completion would drain nodes it never claimed.
                if core.sched.params.rx_omega > 0.0 {
                    let old = slice.plan.candidates[prev_idx].relays();
                    let new = cand.relays();
                    if old != new {
                        for &n in old {
                            core.sched.sub_ingress(&core.fabric, n, slice.len, slice.class);
                        }
                        for &n in new {
                            core.sched.add_ingress(&core.fabric, n, slice.len, slice.class);
                        }
                    }
                }
                let (pred, serial) = core.sched.predict_ns_to(
                    &core.fabric,
                    cand.rail,
                    slice.len,
                    cand.bw,
                    slice.class,
                    Some(slice.plan.dst_node),
                    cand.relays(),
                );
                slice.predicted_ns = pred;
                slice.serial_ns = serial;
                slice.enqueue_ns = clock::now_ns();
                core.sched.add_queued(&core.fabric, cand.rail, slice.len, slice.class);
                match core.datapath.enqueue(slice) {
                    Ok(()) => return,
                    Err(back) => {
                        // Shutdown mid-retry: unwind the queue accounting
                        // and fall through to the give-up path so the
                        // slice ledger (and the engine's in-flight drain)
                        // still balance.
                        let rail = back.plan.candidates[back.cand_idx].rail;
                        core.sched.sub_queued(&core.fabric, rail, back.len, back.class);
                        slice = back;
                    }
                }
            }
        }
    }
    // Give up: release the receiver-ingress claims — destination plus the
    // current candidate's relay nodes (terminal event, like a completion) —
    // and surface the failure through the batch status.
    if core.sched.params.rx_omega > 0.0 {
        core.sched.sub_ingress_route(
            &core.fabric,
            slice.plan.dst_node,
            slice.plan.candidates[slice.cand_idx].relays(),
            slice.len,
            slice.class,
        );
    }
    EngineStats::bump(&core.stats.permanent_failures);
    slice.transfer.mark_failed();
    slice.transfer.complete_slice();
    core.stats.inflight.fetch_sub(1, Ordering::AcqRel);
}

/// Choose the retry path: healthy & non-excluded candidates ordered by tier
/// (reliability over latency); avoid the just-failed rail. A multi-hop
/// failure may sit on a *relay* leg the soft exclusion cannot see (it only
/// tracks the source rail), so candidates that bounce through the same
/// relay set as the failed attempt are deprioritized — an alternative
/// route, when one exists, is tried before another source rail onto the
/// same possibly-dead path. Direct candidates all share the empty relay
/// set, so their ordering is unchanged. Falls back to "any rail that is
/// not hard-failed" so a mass exclusion cannot strand the slice.
fn pick_reliable(core: &EngineCore, slice: &SliceDesc, avoid: crate::topology::RailId) -> Option<usize> {
    let cands = &slice.plan.candidates;
    let failed_relays = cands[slice.cand_idx].relays().to_vec();
    let healthy = |i: &usize| {
        let c = &cands[*i];
        c.rail != avoid && core.fabric.rail(c.rail).health() != RailHealth::Failed
    };
    let mut order: Vec<usize> = (0..cands.len())
        .filter(|i| healthy(i) && !core.sched.is_excluded(cands[*i].rail))
        .collect();
    if order.is_empty() {
        // Backend substitution end-game: everything is excluded — take any
        // rail that is at least alive (§4.3 "prioritizing reliability").
        order = (0..cands.len()).filter(healthy).collect();
    }
    order
        .into_iter()
        .min_by(|&a, &b| {
            let same_route = |i: usize| (cands[i].relays() == failed_relays) as u8;
            same_route(a)
                .cmp(&same_route(b))
                .then((cands[a].tier as u8).cmp(&(cands[b].tier as u8)))
                .then(cands[b].bw.partial_cmp(&cands[a].bw).unwrap())
        })
}

/// Spawn the maintenance thread: heartbeat prober for excluded rails,
/// periodic model reset, and implicit-degradation exclusion.
pub(crate) fn spawn_maintenance(core: &Arc<EngineCore>) -> JoinHandle<()> {
    let core = Arc::clone(core);
    std::thread::Builder::new()
        .name("tent-maint".into())
        .spawn(move || {
            let probe_ns = core.config.probe_interval.as_nanos() as u64;
            let reset_ns = core.config.reset_interval.as_nanos() as u64;
            let mut last_reset = clock::now_ns();
            loop {
                if core.shutdown.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(core.config.probe_interval.min(std::time::Duration::from_millis(5)));
                let now = clock::now_ns();

                // --- Prober: heartbeat excluded rails, re-admit responsive ones.
                for (i, def) in core.topo.rails.iter().enumerate() {
                    let rail = def.id;
                    if !core.sched.is_excluded(rail) {
                        continue;
                    }
                    EngineStats::bump(&core.stats.probes);
                    let responsive = core.fabric.rail(rail).health() != RailHealth::Failed;
                    if responsive && core.sched.readmit(rail) {
                        EngineStats::bump(&core.stats.readmissions);
                        log::info!("resilience: re-admitted {} after probe", def.name);
                    }
                    let _ = i;
                }

                // --- Implicit degradation detection (§4.3): a rail whose
                // learned β1 is far above its peers' median is struggling;
                // soft-exclude it even without explicit errors.
                let factor = core.config.degrade_exclude_factor;
                if factor.is_finite() && factor > 1.0 {
                    let mut b1s: Vec<f64> = Vec::new();
                    for (i, def) in core.topo.rails.iter().enumerate() {
                        let st = core.fabric.rail(def.id);
                        let traffic = st.slices_ok.load(Ordering::Relaxed)
                            + st.slices_failed.load(Ordering::Relaxed);
                        if traffic >= 32 && !core.sched.is_excluded(def.id) {
                            b1s.push(core.sched.models[i].beta1());
                        }
                    }
                    if b1s.len() >= 3 {
                        b1s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                        let median = b1s[b1s.len() / 2];
                        for (i, def) in core.topo.rails.iter().enumerate() {
                            let st = core.fabric.rail(def.id);
                            let traffic = st.slices_ok.load(Ordering::Relaxed)
                                + st.slices_failed.load(Ordering::Relaxed);
                            if traffic >= 32
                                && !core.sched.is_excluded(def.id)
                                && core.sched.models[i].beta1() > factor * median.max(0.05)
                                && core.sched.exclude(def.id)
                            {
                                EngineStats::bump(&core.stats.exclusions);
                                log::info!(
                                    "resilience: telemetry-excluded {} (b1={:.1} median={:.1})",
                                    def.name,
                                    core.sched.models[i].beta1(),
                                    median
                                );
                            }
                        }
                    }
                }

                // --- Periodic state reset (§4.2): re-integrate degraded paths.
                if now.saturating_sub(last_reset) >= reset_ns {
                    core.sched.reset_models();
                    EngineStats::bump(&core.stats.model_resets);
                    last_reset = now;
                }
                let _ = probe_ns;
            }
        })
        .expect("spawn maintenance thread")
}
