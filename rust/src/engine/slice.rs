//! Slice decomposition (§4.2 "Slice Decomposition") and the slice
//! descriptor that flows through the datapath rings.
//!
//! Elephant flows are split into slices of a configurable minimum size
//! (64 KB by default) — small enough that no slice holds a rail for long
//! (bounding HoL blocking), large enough to amortize enqueue/completion
//! costs. Extremely large transfers cap the total slice count to bound
//! control-plane overhead. Every slice writes to an absolute destination
//! offset, so slices complete in any order and retries are idempotent.

use super::batch::TransferState;
use super::core::EngineCore;
use super::plan::TransferPlan;
use super::TransferClass;
use crate::segment::Segment;
use crate::transport::PathAffinity;
use std::sync::Arc;

/// One schedulable slice.
pub struct SliceDesc {
    /// The engine that dispatched this slice. Rail workers are shared by
    /// every engine on the cluster (`datapath::SharedDatapath`), so the
    /// completion path — queue accounting, cost-model feedback, stats,
    /// retries — routes through this backref.
    pub core: Arc<EngineCore>,
    pub src: Arc<Segment>,
    pub src_off: u64,
    pub dst: Arc<Segment>,
    pub dst_off: u64,
    pub len: u64,
    /// QoS class inherited from the parent transfer; decides the datapath
    /// lane and the per-class queue statistics, and is preserved across
    /// resilience reroutes.
    pub class: TransferClass,
    /// Index into `plan.candidates` chosen by the scheduler.
    pub cand_idx: usize,
    /// Prediction recorded at dispatch, for the feedback loop.
    pub predicted_ns: f64,
    /// The (A_d + L)/B_d serial term at dispatch (feedback denominator).
    pub serial_ns: f64,
    /// Dispatch timestamp (ns since process epoch).
    pub enqueue_ns: u64,
    /// Retry attempt (0 = first try).
    pub attempt: u32,
    pub plan: Arc<TransferPlan>,
    pub transfer: Arc<TransferState>,
}

impl SliceDesc {
    pub fn affinity(&self) -> PathAffinity {
        let c = &self.plan.candidates[self.cand_idx];
        PathAffinity {
            cross_numa: c.cross_numa,
            cross_root: c.cross_root,
        }
    }
}

/// Compute `(offset, len)` slice spans for a transfer of `len` bytes.
///
/// * every slice is at least `min_slice` bytes (except a smaller tail or a
///   transfer smaller than `min_slice`),
/// * at most `max_slices` slices are produced.
pub fn decompose(len: u64, min_slice: u64, max_slices: usize) -> Vec<(u64, u64)> {
    assert!(min_slice > 0 && max_slices > 0);
    if len == 0 {
        return Vec::new();
    }
    // Slice size: the minimum unless the count cap forces bigger slices.
    let by_cap = len.div_ceil(max_slices as u64);
    let slice = by_cap.max(min_slice);
    let mut out = Vec::with_capacity(len.div_ceil(slice) as usize);
    let mut off = 0;
    while off < len {
        let l = slice.min(len - off);
        out.push((off, l));
        off += l;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_transfer_is_single_slice() {
        assert_eq!(decompose(1000, 64 << 10, 512), vec![(0, 1000)]);
        assert_eq!(decompose(64 << 10, 64 << 10, 512), vec![(0, 64 << 10)]);
    }

    #[test]
    fn zero_len_empty() {
        assert!(decompose(0, 64 << 10, 512).is_empty());
    }

    #[test]
    fn elephant_flow_uses_min_slice() {
        let spans = decompose(1 << 20, 64 << 10, 512);
        assert_eq!(spans.len(), 16);
        assert!(spans.iter().all(|&(_, l)| l == 64 << 10));
    }

    #[test]
    fn slice_count_is_capped() {
        // 64 MiB at 64 KiB minimum would be 1024 slices; cap at 512.
        let spans = decompose(64 << 20, 64 << 10, 512);
        assert_eq!(spans.len(), 512);
        assert!(spans.iter().all(|&(_, l)| l == 128 << 10));
    }

    #[test]
    fn spans_are_contiguous_and_complete() {
        for len in [1u64, 100, 65_537, 1 << 20, (64 << 20) + 12_345] {
            let spans = decompose(len, 64 << 10, 512);
            let mut expect_off = 0;
            for &(off, l) in &spans {
                assert_eq!(off, expect_off);
                assert!(l > 0);
                expect_off += l;
            }
            assert_eq!(expect_off, len, "len={len}");
            assert!(spans.len() <= 512);
        }
    }

    #[test]
    fn tail_slice_may_be_short() {
        let spans = decompose((64 << 10) + 5, 64 << 10, 512);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[1], (64 << 10, 5));
    }
}
