//! Trace-driven chaos harness (§6.3's resilience evaluation, made a
//! first-class subsystem).
//!
//! The harness replays a deterministic fault schedule against a live
//! [`Fleet`] while the production workload runs, and instruments the heal
//! path end to end:
//!
//! * [`schedule`] — declarative fault schedules: Table 1 trace events plus
//!   correlated scenarios (multi-rail storms, flapping links, slow drains,
//!   congestion ramps), pure in `(topology, seed, horizon, mix)` and
//!   serializable to a seed+schedule file so any run replays exactly.
//! * [`injector`] — walks the schedule against the shared fabric on its own
//!   thread, sleeping to each event's offset.
//! * [`probe`] — measures per-event healing latency (injection → first
//!   rerouted-slice completion on a surviving rail, stamped by the datapath
//!   itself) and goodput recovery (back to 90% of the pre-fault rate).
//!
//! [`run`] ties the three together around [`Fleet::run_workload`] and
//! returns a [`ChaosReport`]: the fleet report with healing/recovery
//! histograms merged in, the per-event outcome counts, and the applied
//! action log whose [`ChaosReport::replay_signature`] is byte-identical
//! across replays of the same seed+schedule — the replay contract
//! `tests/chaos_replay.rs` enforces and `benches/fig_resilience.rs` sweeps.

pub mod injector;
pub mod probe;
pub mod schedule;

pub use injector::AppliedAction;
pub use probe::{HealingOutcome, HealingProbe, ProbeConfig, ProbeHandle};
pub use schedule::{ActionKind, ChaosEvent, ChaosSchedule, ScenarioMix};

use crate::cluster::{Fleet, FleetReport, WorkloadConfig};
use crate::util::clock;
use crate::util::json::Json;
use crate::Result;
use std::sync::Arc;

/// Everything one chaos run produced.
pub struct ChaosReport {
    pub schedule_seed: u64,
    /// [`ChaosSchedule::digest`] of the schedule that was replayed.
    pub schedule_digest: u64,
    /// The injector's applied-action log (schedule-relative timestamps).
    pub applied: Vec<AppliedAction>,
    /// Per-event healing telemetry from the probe.
    pub outcome: HealingOutcome,
    /// The workload report, with `healing_hist` / `recovery_hist` populated.
    pub fleet: FleetReport,
}

impl ChaosReport {
    /// The deterministic identity of a replay: canonical JSON over the
    /// schedule seed, the schedule digest, and the applied-action log.
    /// Two runs of the same seed+schedule produce byte-identical
    /// signatures — wall-clock quantities (goodput, latency histograms)
    /// are deliberately excluded, since real threads never repeat them.
    pub fn replay_signature(&self) -> String {
        let actions = self
            .applied
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("at_ns", Json::num(a.at_ns as f64)),
                    ("rail", Json::num(a.rail.0 as f64)),
                    ("kind", Json::str(a.kind.name())),
                    ("factor", Json::num(a.factor)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("seed", Json::str(&self.schedule_seed.to_string())),
            ("digest", Json::str(&crate::util::canon::digest_hex(self.schedule_digest))),
            ("applied", Json::arr(actions)),
        ])
        .to_string()
    }

    /// P99 healing latency (ns) — the quantity the sub-50 ms gate scores.
    pub fn heal_p99_ns(&self) -> u64 {
        self.fleet.healing_hist.p99()
    }
}

/// Replay `schedule` against `fleet` while driving `workload`, with the
/// healing probe watching. The workload duration should exceed the
/// schedule horizon so late events still see traffic (the tests and bench
/// pad by a few hundred ms). On return every touched rail has been
/// recovered, so the fleet is immediately reusable.
pub fn run(
    fleet: &Fleet,
    schedule: &ChaosSchedule,
    workload: &WorkloadConfig,
    probe_cfg: ProbeConfig,
) -> Result<ChaosReport> {
    let fabric = Arc::clone(&fleet.cluster.fabric);
    injector::validate(&fabric, schedule)?;
    let probe = HealingProbe::spawn(fleet.engines().to_vec(), Arc::clone(&fabric), probe_cfg);
    let handle = probe.handle();
    // One anchor instant shared by the injector's event offsets and the
    // probe's outage bookkeeping.
    let start = clock::now_ns();
    let (applied, fleet_report) = std::thread::scope(|scope| {
        let inj = scope.spawn(|| injector::replay(&fabric, schedule, Some(&handle), start));
        let report = fleet.run_workload(workload);
        (inj.join().expect("chaos injector panicked"), report)
    });
    // Stop the probe and restore the fabric before error handling, so an
    // early return never leaks a polling thread or a failed rail.
    let outcome = probe.finish();
    injector::recover_touched(&fabric, schedule);
    let applied = applied?;
    let fleet_report = fleet_report?;
    fleet_report.healing_hist.merge(&outcome.healing);
    fleet_report.recovery_hist.merge(&outcome.recovery);
    Ok(ChaosReport {
        schedule_seed: schedule.seed,
        schedule_digest: schedule.digest(),
        applied,
        outcome,
        fleet: fleet_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::FleetConfig;
    use std::time::Duration;

    #[test]
    fn empty_schedule_run_is_a_plain_workload() {
        let fleet = Fleet::new(FleetConfig::new("h800_hgx", 2)).unwrap();
        let schedule = ChaosSchedule {
            seed: 42,
            horizon_ns: 50_000_000,
            events: Vec::new(),
        };
        let w = WorkloadConfig {
            duration: Duration::from_millis(120),
            submitters_per_engine: 1,
            ..Default::default()
        };
        let r = run(&fleet, &schedule, &w, ProbeConfig::default()).unwrap();
        assert!(r.applied.is_empty());
        assert_eq!(r.outcome.fails_injected, 0);
        assert_eq!(r.fleet.failed_batches, 0);
        assert_eq!(r.fleet.healing_hist.count(), 0);
        assert!(r.fleet.aggregate_goodput() > 0.0);
        // Identity is stable even for the empty schedule.
        assert_eq!(r.replay_signature(), r.replay_signature());
        assert_eq!(r.schedule_digest, schedule.digest());
    }
}
