//! Schedule replay: turn a [`ChaosSchedule`] into live fabric actions.
//!
//! The injector walks the time-sorted event list, sleeps to each event's
//! offset from a caller-supplied start instant, and applies it to the
//! shared [`Fabric`] — exactly the `inject_failure` / `inject_degradation` /
//! `recover` calls a human would script, but driven from the declarative
//! schedule so every run applies the identical sequence. The returned
//! applied-action log carries the *scheduled* offsets (not wall-clock
//! apply times), which is what makes two replays of the same schedule
//! byte-comparable: the log is a pure projection of the schedule.

use super::probe::ProbeHandle;
use super::schedule::{ActionKind, ChaosSchedule};
use crate::fabric::Fabric;
use crate::topology::RailId;
use crate::util::clock;
use crate::{Error, Result};
use std::collections::BTreeSet;

/// One action as applied (schedule-relative timestamps; deterministic).
#[derive(Clone, Debug, PartialEq)]
pub struct AppliedAction {
    pub at_ns: u64,
    pub rail: RailId,
    pub kind: ActionKind,
    pub factor: f64,
}

/// Project the schedule into the applied-action log without touching any
/// fabric — the pure "what would replay do" view the replay-contract tests
/// compare against live runs.
pub fn dry_run(schedule: &ChaosSchedule) -> Vec<AppliedAction> {
    schedule
        .events
        .iter()
        .map(|e| AppliedAction {
            at_ns: e.at_ns,
            rail: e.rail,
            kind: e.kind,
            factor: e.factor,
        })
        .collect()
}

/// Check every event targets a rail the fabric actually has.
pub fn validate(fabric: &Fabric, schedule: &ChaosSchedule) -> Result<()> {
    let n = fabric.rails.len() as u64;
    for e in &schedule.events {
        if e.rail.0 as u64 >= n {
            return Err(Error::Config(format!(
                "chaos schedule targets {} but the fabric has {} rails",
                e.rail, n
            )));
        }
    }
    Ok(())
}

/// Replay `schedule` against `fabric`, anchored at `start_ns` (an epoch-
/// relative instant from [`clock::now_ns`]). Blocks until the last event
/// has been applied; callers run it on its own thread next to the
/// workload. Fail injections are announced to `probe` so healing latency
/// is timed from the true injection instant.
pub fn replay(
    fabric: &Fabric,
    schedule: &ChaosSchedule,
    probe: Option<&ProbeHandle>,
    start_ns: u64,
) -> Result<Vec<AppliedAction>> {
    validate(fabric, schedule)?;
    let mut applied = Vec::with_capacity(schedule.events.len());
    for e in &schedule.events {
        clock::sleep_until_ns(start_ns + e.at_ns);
        match e.kind {
            ActionKind::Fail => {
                fabric.inject_failure(e.rail);
                if let Some(p) = probe {
                    p.on_fail(e.rail, clock::now_ns(), start_ns + e.until_ns);
                }
            }
            ActionKind::Degrade => {
                fabric.inject_degradation(e.rail, e.factor);
            }
            ActionKind::Recover => {
                fabric.recover(e.rail);
            }
        }
        applied.push(AppliedAction {
            at_ns: e.at_ns,
            rail: e.rail,
            kind: e.kind,
            factor: e.factor,
        });
    }
    Ok(applied)
}

/// Recover every rail the schedule ever touched (post-run cleanup, so the
/// fleet is reusable and the engines' probers re-admit everything).
/// `Fabric::recover` is a no-op on rails that are already healthy.
pub fn recover_touched(fabric: &Fabric, schedule: &ChaosSchedule) {
    let rails: BTreeSet<u32> = schedule.events.iter().map(|e| e.rail.0).collect();
    for r in rails {
        if (r as usize) < fabric.rails.len() {
            fabric.recover(RailId(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::schedule::{ChaosSchedule, ScenarioMix};
    use crate::fabric::{FabricConfig, RailHealth};
    use crate::topology::profile::build_profile;

    #[test]
    fn dry_run_projects_the_whole_schedule_in_order() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let s = ChaosSchedule::generate(&t, 5, 1_000_000_000, &ScenarioMix::default());
        let log = dry_run(&s);
        assert_eq!(log.len(), s.events.len());
        for (a, e) in log.iter().zip(&s.events) {
            assert_eq!(a.at_ns, e.at_ns);
            assert_eq!(a.rail, e.rail);
            assert_eq!(a.kind, e.kind);
        }
    }

    #[test]
    fn validate_rejects_out_of_range_rails() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = crate::fabric::Fabric::new(&t, FabricConfig::default());
        let mut s = ChaosSchedule {
            seed: 1,
            horizon_ns: 10,
            events: vec![],
        };
        s.events.push(crate::chaos::schedule::ChaosEvent {
            at_ns: 0,
            rail: RailId(10_000),
            kind: ActionKind::Fail,
            factor: 0.0,
            until_ns: 5,
            source: "test".into(),
        });
        assert!(validate(&f, &s).is_err());
        assert!(replay(&f, &s, None, clock::now_ns()).is_err());
    }

    #[test]
    fn replay_applies_and_cleanup_restores() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let f = crate::fabric::Fabric::new(&t, FabricConfig::default());
        // A tiny hand-built schedule: fail one rail, degrade another, and
        // deliberately never recover them in-schedule.
        let s = ChaosSchedule {
            seed: 9,
            horizon_ns: 2_000_000,
            events: vec![
                crate::chaos::schedule::ChaosEvent {
                    at_ns: 0,
                    rail: RailId(0),
                    kind: ActionKind::Fail,
                    factor: 0.0,
                    until_ns: 2_000_000,
                    source: "test".into(),
                },
                crate::chaos::schedule::ChaosEvent {
                    at_ns: 1_000_000,
                    rail: RailId(1),
                    kind: ActionKind::Degrade,
                    factor: 0.5,
                    until_ns: 2_000_000,
                    source: "test".into(),
                },
            ],
        };
        let log = replay(&f, &s, None, clock::now_ns()).unwrap();
        assert_eq!(log, dry_run(&s));
        assert_eq!(f.rail(RailId(0)).health(), RailHealth::Failed);
        assert_eq!(f.rail(RailId(1)).health(), RailHealth::Degraded);
        recover_touched(&f, &s);
        assert_eq!(f.rail(RailId(0)).health(), RailHealth::Healthy);
        assert_eq!(f.rail(RailId(1)).health(), RailHealth::Healthy);
        assert_eq!(f.rail(RailId(1)).bw_factor(), 1.0);
    }
}
