//! The healing probe: end-to-end heal-path instrumentation.
//!
//! For every injected hard failure the probe measures the paper's headline
//! resilience quantity — **healing latency**: the time from the injection
//! instant to the first *rerouted-slice completion* on a surviving rail
//! anywhere in the fleet. The completion side is not polled: the datapath
//! stamps `EngineStats::last_reroute_complete_ns` at the completion of
//! every retried slice, so the measured latency is poll-rate-independent
//! (a poll only discovers the stamp; the stamp carries the true time).
//!
//! A second, coarser signal tracks **throughput recovery**: fleet goodput
//! (per-NIC carried-byte counters) sampled in fixed windows, with the time
//! until the rate is back to `recovery_fraction` × the pre-fault trailing
//! rate recorded per event.
//!
//! Per-event outcomes:
//! * **healed** — a slice died on the failed rail and a rerouted slice
//!   completed afterwards; the latency lands in `HealingOutcome::healing`.
//! * **untouched** — the outage came and went without any slice failing on
//!   the rail (nothing needed healing; not a gate failure).
//! * **unhealed** — a slice died but no rerouted completion appeared within
//!   the grace window: the resilience layer failed. The acceptance gate
//!   requires zero of these.
//! * **unresolved** — still in flight when the probe was stopped.
//!
//! Overlapping events (storms inject several fails at the same instant)
//! share reroute completions: each open event closes on the first stamp
//! after *its own* injection time, which is exactly the "fleet keeps
//! moving traffic around every fault" property the gate is about.

use crate::engine::TentEngine;
use crate::fabric::Fabric;
use crate::topology::RailId;
use crate::util::clock;
use crate::util::hist::Histogram;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Probe tuning knobs.
#[derive(Clone, Debug)]
pub struct ProbeConfig {
    /// Poll interval for stamp/arm discovery.
    pub poll: Duration,
    /// How long after injection an armed event may wait for a rerouted
    /// completion before it is declared unhealed.
    pub heal_grace: Duration,
    /// Goodput sampling window.
    pub goodput_window: Duration,
    /// Recovery target as a fraction of the pre-fault trailing rate.
    pub recovery_fraction: f64,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig {
            poll: Duration::from_micros(200),
            heal_grace: Duration::from_secs(2),
            goodput_window: Duration::from_millis(5),
            recovery_fraction: 0.9,
        }
    }
}

/// Aggregated healing telemetry for one chaos run.
pub struct HealingOutcome {
    /// Injection → first rerouted-slice completion (ns), one per healed
    /// event.
    pub healing: Histogram,
    /// Injection → goodput back to `recovery_fraction` × pre-fault (ns).
    pub recovery: Histogram,
    pub fails_injected: u64,
    pub healed: u64,
    pub untouched: u64,
    pub unhealed: u64,
    pub unresolved: u64,
}

/// One open fail event being tracked.
struct OpenFail {
    rail: RailId,
    t_inj: u64,
    until_wall: u64,
    failed_snap: u64,
    pre_rate: f64,
    armed: bool,
    heal_closed: bool,
    recovered: bool,
}

struct ProbeShared {
    stop: AtomicBool,
    incoming: Mutex<Vec<(RailId, u64, u64)>>, // (rail, t_inj, until_wall)
    healing: Histogram,
    recovery: Histogram,
    fails_injected: AtomicU64,
    healed: AtomicU64,
    untouched: AtomicU64,
    unhealed: AtomicU64,
    unresolved: AtomicU64,
}

/// Injector-facing side of the probe (cheap to clone across threads).
#[derive(Clone)]
pub struct ProbeHandle {
    shared: Arc<ProbeShared>,
}

impl ProbeHandle {
    /// Announce a hard-failure injection at wall instant `t_inj`;
    /// `until_wall` is the scheduled recovery instant (wall clock).
    pub fn on_fail(&self, rail: RailId, t_inj: u64, until_wall: u64) {
        self.shared.fails_injected.fetch_add(1, Ordering::Relaxed);
        self.shared
            .incoming
            .lock()
            .unwrap()
            .push((rail, t_inj, until_wall));
    }
}

/// The probe: a sampling thread over the fleet's engines + fabric.
pub struct HealingProbe {
    shared: Arc<ProbeShared>,
    handle: JoinHandle<()>,
}

impl HealingProbe {
    pub fn spawn(engines: Vec<Arc<TentEngine>>, fabric: Arc<Fabric>, cfg: ProbeConfig) -> HealingProbe {
        let shared = Arc::new(ProbeShared {
            stop: AtomicBool::new(false),
            incoming: Mutex::new(Vec::new()),
            healing: Histogram::new(),
            recovery: Histogram::new(),
            fails_injected: AtomicU64::new(0),
            healed: AtomicU64::new(0),
            untouched: AtomicU64::new(0),
            unhealed: AtomicU64::new(0),
            unresolved: AtomicU64::new(0),
        });
        let sh = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("tent-chaos-probe".into())
            .spawn(move || probe_loop(sh, engines, fabric, cfg))
            .expect("spawn chaos probe");
        HealingProbe { shared, handle }
    }

    pub fn handle(&self) -> ProbeHandle {
        ProbeHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// Stop the probe (remaining open events are swept: armed ones past
    /// grace become unhealed, finished-outage quiet ones untouched, the
    /// rest unresolved) and return the aggregated outcome.
    pub fn finish(self) -> HealingOutcome {
        self.shared.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
        let out = HealingOutcome {
            healing: Histogram::new(),
            recovery: Histogram::new(),
            fails_injected: self.shared.fails_injected.load(Ordering::Relaxed),
            healed: self.shared.healed.load(Ordering::Relaxed),
            untouched: self.shared.untouched.load(Ordering::Relaxed),
            unhealed: self.shared.unhealed.load(Ordering::Relaxed),
            unresolved: self.shared.unresolved.load(Ordering::Relaxed),
        };
        out.healing.merge(&self.shared.healing);
        out.recovery.merge(&self.shared.recovery);
        out
    }
}

fn probe_loop(sh: Arc<ProbeShared>, engines: Vec<Arc<TentEngine>>, fabric: Arc<Fabric>, cfg: ProbeConfig) {
    // Margin after the scheduled recovery in which a straggler slice may
    // still fail on the rail (it raced the recover); quiet events are only
    // closed as untouched after it.
    const UNTOUCHED_MARGIN_NS: u64 = 5_000_000;
    let poll = cfg.poll.max(Duration::from_micros(50));
    let window_ns = (cfg.goodput_window.as_nanos() as u64).max(1_000_000);
    let grace_ns = cfg.heal_grace.as_nanos() as u64;

    let carried = |fabric: &Fabric| -> u64 {
        fabric.byte_counters().iter().map(|&(_, b)| b).sum()
    };
    let stamp = |engines: &[Arc<TentEngine>]| -> u64 {
        engines
            .iter()
            .map(|e| e.stats().last_reroute_complete_ns)
            .max()
            .unwrap_or(0)
    };
    let trailing_rate = |rates: &VecDeque<f64>| -> f64 {
        if rates.is_empty() {
            0.0
        } else {
            rates.iter().sum::<f64>() / rates.len() as f64
        }
    };

    let mut open: Vec<OpenFail> = Vec::new();
    let mut rates: VecDeque<f64> = VecDeque::with_capacity(8);
    let mut last_bytes = carried(&fabric);
    let mut window_start = clock::now_ns();

    loop {
        let stopping = sh.stop.load(Ordering::SeqCst);
        if !stopping {
            std::thread::sleep(poll);
        }
        let now = clock::now_ns();

        // Intake: injections announced since the last tick. The pre-fault
        // rate is pinned at intake, before the fault can dent the windows.
        for (rail, t_inj, until_wall) in sh.incoming.lock().unwrap().drain(..) {
            open.push(OpenFail {
                rail,
                t_inj,
                until_wall,
                failed_snap: fabric.rail(rail).slices_failed.load(Ordering::Relaxed),
                pre_rate: trailing_rate(&rates),
                armed: false,
                heal_closed: false,
                recovered: false,
            });
        }

        // Goodput windows.
        if now >= window_start + window_ns {
            let b = carried(&fabric);
            let dt_s = (now - window_start) as f64 / 1e9;
            let rate = (b.saturating_sub(last_bytes)) as f64 / dt_s.max(1e-9);
            for ev in open.iter_mut() {
                if !ev.recovered && ev.pre_rate > 0.0 && rate >= cfg.recovery_fraction * ev.pre_rate {
                    sh.recovery.record(now.saturating_sub(ev.t_inj));
                    ev.recovered = true;
                }
            }
            if rates.len() == 8 {
                rates.pop_front();
            }
            rates.push_back(rate);
            last_bytes = b;
            window_start = now;
        }

        // Heal detection: arm on the first slice death on the rail, close
        // on the first rerouted completion stamped after the injection.
        let ts = stamp(&engines);
        for ev in open.iter_mut() {
            if !ev.armed
                && fabric.rail(ev.rail).slices_failed.load(Ordering::Relaxed) > ev.failed_snap
            {
                ev.armed = true;
            }
            if ev.armed && !ev.heal_closed && ts > ev.t_inj {
                sh.healing.record(ts - ev.t_inj);
                sh.healed.fetch_add(1, Ordering::Relaxed);
                ev.heal_closed = true;
            }
        }

        // Expiry / final sweep.
        open.retain(|ev| {
            if ev.heal_closed {
                // Keep only while the recovery signal may still land.
                let keep = !ev.recovered
                    && ev.pre_rate > 0.0
                    && now < ev.until_wall.max(ev.t_inj) + grace_ns
                    && !stopping;
                return keep;
            }
            if ev.armed {
                if now >= ev.t_inj + grace_ns {
                    sh.unhealed.fetch_add(1, Ordering::Relaxed);
                    return false;
                }
                if stopping {
                    sh.unresolved.fetch_add(1, Ordering::Relaxed);
                    return false;
                }
                return true;
            }
            if now >= ev.until_wall + UNTOUCHED_MARGIN_NS {
                sh.untouched.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            if stopping {
                sh.unresolved.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            true
        });

        if stopping {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::topology::profile::build_profile;

    #[test]
    fn quiet_outage_counts_as_untouched() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Arc::new(Fabric::new(&t, FabricConfig::default()));
        let probe = HealingProbe::spawn(Vec::new(), Arc::clone(&f), ProbeConfig::default());
        let h = probe.handle();
        let now = clock::now_ns();
        // Outage window entirely in the past + margin elapses quickly; no
        // slice ever fails, so nothing needed healing.
        h.on_fail(RailId(0), now, now + 10_000_000);
        std::thread::sleep(Duration::from_millis(40));
        let out = probe.finish();
        assert_eq!(out.fails_injected, 1);
        assert_eq!(out.untouched, 1);
        assert_eq!(out.healed, 0);
        assert_eq!(out.unhealed, 0);
        assert_eq!(out.healing.count(), 0);
    }

    #[test]
    fn stop_sweeps_open_events_as_unresolved() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Arc::new(Fabric::new(&t, FabricConfig::default()));
        let probe = HealingProbe::spawn(Vec::new(), Arc::clone(&f), ProbeConfig::default());
        let h = probe.handle();
        let now = clock::now_ns();
        // Outage scheduled far in the future: still open at stop.
        h.on_fail(RailId(0), now, now + 60_000_000_000);
        std::thread::sleep(Duration::from_millis(5));
        let out = probe.finish();
        assert_eq!(out.fails_injected, 1);
        assert_eq!(out.unresolved, 1);
        assert_eq!(out.unhealed, 0);
    }
}
