//! Deterministic fault schedules: the declarative half of the chaos harness.
//!
//! A [`ChaosSchedule`] is a flat, time-sorted list of rail-visible actions
//! (hard-fail / degrade / recover) generated from a seed — Table 1 trace
//! events via [`TraceGenerator`] plus the correlated scenarios the single
//! event mix cannot express (simultaneous multi-rail storms, flapping links,
//! slow-drain degradation, background-congestion ramps). Generation is a
//! pure function of `(topology, seed, horizon, mix)`, and the schedule
//! serializes to/from a canonical JSON file, so any run replays exactly:
//! same seed + same schedule file → byte-identical action sequence.
//!
//! Generation keeps the fleet *survivable* by construction: per rail, fault
//! intervals never overlap, and per node, at most `max_down_fraction` of the
//! sprayable (RDMA) rails are hard-down at any instant — so the resilience
//! layer always has a live reroute target and a chaos run measures healing,
//! not partition behavior.

use crate::fabric::trace::{FailureEvent, RecoveryClass, TraceGenerator};
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use crate::{Error, Result};

/// One rail-visible action in a schedule.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ActionKind {
    /// Hard-fail the rail (slices on it error out).
    Fail,
    /// Degrade the rail to `factor` × nominal bandwidth.
    Degrade,
    /// Restore the rail to full health.
    Recover,
}

impl ActionKind {
    pub fn name(&self) -> &'static str {
        match self {
            ActionKind::Fail => "fail",
            ActionKind::Degrade => "degrade",
            ActionKind::Recover => "recover",
        }
    }

    pub fn parse(s: &str) -> Option<ActionKind> {
        Some(match s {
            "fail" => ActionKind::Fail,
            "degrade" => ActionKind::Degrade,
            "recover" => ActionKind::Recover,
            _ => return None,
        })
    }
}

/// One scheduled event. `until_ns` on a `Fail`/`Degrade` records when the
/// matching `Recover` is scheduled (clamped to the horizon when the fault
/// outlives the schedule — hard Table 1 events have a 160-minute MTTR);
/// zero on `Recover` events.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosEvent {
    /// Offset from replay start (ns, wall clock of the compressed sim).
    pub at_ns: u64,
    pub rail: RailId,
    pub kind: ActionKind,
    /// Bandwidth factor for `Degrade`; 0 otherwise.
    pub factor: f64,
    pub until_ns: u64,
    /// Originating scenario or Table 1 event name (labels, not semantics).
    pub source: String,
}

/// Scenario composition knobs for [`ChaosSchedule::generate`].
#[derive(Clone, Debug)]
pub struct ScenarioMix {
    /// Table 1 trace intensity (Poisson arrivals; production is 382/month,
    /// benches compress to several per second).
    pub trace_events_per_sec: f64,
    /// Correlated storms: simultaneous multi-rail kills on one node.
    pub storms: u32,
    /// Rails killed per storm.
    pub storm_rails: usize,
    /// Storm outage duration (ns).
    pub storm_outage_ns: u64,
    /// Down/up cycles a `NetworkLinkFlap` trace event expands into.
    pub flap_cycles: u32,
    /// Full flap period (down for half, up for half).
    pub flap_period_ns: u64,
    /// Slow-drain degradations: one rail stepped down in stages.
    pub slow_drains: u32,
    /// Background-congestion ramps: a spread of rails mildly degraded in
    /// escalating stages, then released.
    pub congestion_ramps: u32,
    /// Guardrail: at most this fraction of a node's sprayable rails may be
    /// hard-down at once (and never all of them).
    pub max_down_fraction: f64,
}

impl Default for ScenarioMix {
    fn default() -> Self {
        ScenarioMix {
            trace_events_per_sec: 4.0,
            storms: 1,
            storm_rails: 2,
            storm_outage_ns: 40_000_000, // 40 ms
            flap_cycles: 4,
            flap_period_ns: 20_000_000, // 20 ms
            slow_drains: 1,
            congestion_ramps: 1,
            max_down_fraction: 0.5,
        }
    }
}

/// A deterministic fault schedule (seed + time-sorted events).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosSchedule {
    pub seed: u64,
    pub horizon_ns: u64,
    pub events: Vec<ChaosEvent>,
}

/// Per-rail interval bookkeeping used by the generation guardrails.
struct DownBook {
    /// Any scheduled action interval per rail (faults never overlap on one
    /// rail, so every `Recover` is unambiguous).
    busy: Vec<Vec<(u64, u64)>>,
    /// Hard-down intervals per rail (the node budget counts these).
    down: Vec<Vec<(u64, u64)>>,
}

fn overlaps(ivs: &[(u64, u64)], t0: u64, t1: u64) -> bool {
    ivs.iter().any(|&(a, b)| t0 < b && a < t1)
}

impl DownBook {
    fn new(rails: usize) -> DownBook {
        DownBook {
            busy: vec![Vec::new(); rails],
            down: vec![Vec::new(); rails],
        }
    }

    fn node_down_count(&self, node_rails: &[RailId], t0: u64, t1: u64) -> usize {
        node_rails
            .iter()
            .filter(|r| overlaps(&self.down[r.0 as usize], t0, t1))
            .count()
    }

    /// Reserve a hard-down interval if the rail is free and the node stays
    /// within its concurrent-down budget.
    fn try_fail(&mut self, rail: RailId, node_rails: &[RailId], cap: usize, t0: u64, t1: u64) -> bool {
        let i = rail.0 as usize;
        if overlaps(&self.busy[i], t0, t1) || self.node_down_count(node_rails, t0, t1) + 1 > cap {
            return false;
        }
        self.busy[i].push((t0, t1));
        self.down[i].push((t0, t1));
        true
    }

    /// Reserve a degradation interval (degraded rails still carry traffic,
    /// so they do not count against the down budget).
    fn try_degrade(&mut self, rail: RailId, t0: u64, t1: u64) -> bool {
        let i = rail.0 as usize;
        if overlaps(&self.busy[i], t0, t1) {
            return false;
        }
        self.busy[i].push((t0, t1));
        true
    }
}

/// Sprayable fault targets: the inter-node RDMA rails, grouped by node.
/// Single-rail fabrics (a legacy node's lone TCP link) are never targeted —
/// failing the only path would test partitions, not healing.
fn eligible_rails(topo: &Topology) -> Vec<Vec<RailId>> {
    topo.nodes
        .iter()
        .map(|&n| topo.rails_of(n, FabricKind::Rdma))
        .filter(|rails| rails.len() >= 2)
        .collect()
}

impl ChaosSchedule {
    /// Generate a schedule: Table 1 trace + correlated scenarios, all
    /// placed under the survivability guardrails. Pure in
    /// `(topo, seed, horizon_ns, mix)`.
    pub fn generate(topo: &Topology, seed: u64, horizon_ns: u64, mix: &ScenarioMix) -> ChaosSchedule {
        let mut rng = Pcg64::new(seed, 0xC4A0);
        let mut book = DownBook::new(topo.rails.len());
        let by_node = eligible_rails(topo);
        let flat: Vec<(usize, RailId)> = by_node
            .iter()
            .enumerate()
            .flat_map(|(n, rails)| rails.iter().map(move |&r| (n, r)))
            .collect();
        let cap = |node: usize| -> usize {
            let n = by_node[node].len();
            (((n as f64) * mix.max_down_fraction) as usize).clamp(1, n - 1)
        };
        let mut events: Vec<ChaosEvent> = Vec::new();
        if flat.is_empty() || horizon_ns == 0 {
            return ChaosSchedule { seed, horizon_ns, events };
        }

        // --- 1. Table 1 empirical trace ----------------------------------
        let mut trace = TraceGenerator::new(seed);
        for a in trace.generate(horizon_ns, mix.trace_events_per_sec) {
            if a.event == FailureEvent::NetworkLinkFlap {
                // Flapping link: expand the single trace event into a
                // down/up cadence (the class the prober's re-admission
                // hysteresis exists for).
                let cycles = mix.flap_cycles.max(1) as u64;
                let period = mix.flap_period_ns.max(2);
                let span = cycles * period;
                let end = a.at_ns.saturating_add(span).min(horizon_ns);
                for _ in 0..8 {
                    let (node, rail) = *rng.choose(&flat);
                    if book.try_fail(rail, &by_node[node], cap(node), a.at_ns, end) {
                        for k in 0..cycles {
                            let t = a.at_ns + k * period;
                            if t >= horizon_ns {
                                break;
                            }
                            let up = (t + period / 2).min(end);
                            events.push(ChaosEvent {
                                at_ns: t,
                                rail,
                                kind: ActionKind::Fail,
                                factor: 0.0,
                                until_ns: up,
                                source: "flap".into(),
                            });
                            events.push(ChaosEvent {
                                at_ns: up,
                                rail,
                                kind: ActionKind::Recover,
                                factor: 0.0,
                                until_ns: 0,
                                source: "flap".into(),
                            });
                        }
                        break;
                    }
                }
                continue;
            }
            let end = a.at_ns.saturating_add(a.duration_ns).min(horizon_ns);
            let hard = a.hard || a.event.recovery_class() == RecoveryClass::Hard;
            for _ in 0..8 {
                let (node, rail) = *rng.choose(&flat);
                let placed = if hard {
                    book.try_fail(rail, &by_node[node], cap(node), a.at_ns, end)
                } else {
                    book.try_degrade(rail, a.at_ns, end)
                };
                if !placed {
                    continue;
                }
                let kind = if hard { ActionKind::Fail } else { ActionKind::Degrade };
                events.push(ChaosEvent {
                    at_ns: a.at_ns,
                    rail,
                    kind,
                    factor: if hard { 0.0 } else { a.degrade_factor },
                    until_ns: end,
                    source: a.event.name().to_string(),
                });
                if end < horizon_ns {
                    events.push(ChaosEvent {
                        at_ns: end,
                        rail,
                        kind: ActionKind::Recover,
                        factor: 0.0,
                        until_ns: 0,
                        source: a.event.name().to_string(),
                    });
                }
                break;
            }
        }

        // --- 2. Correlated storms: simultaneous multi-rail kills ----------
        for _ in 0..mix.storms {
            let outage = mix.storm_outage_ns.max(1).min(horizon_ns);
            let t0 = rng.gen_between(horizon_ns / 4, (3 * horizon_ns / 4).max(horizon_ns / 4 + 1));
            let end = t0.saturating_add(outage).min(horizon_ns);
            'storm: for _ in 0..8 {
                let node = rng.gen_range(by_node.len() as u64) as usize;
                let want = mix.storm_rails.clamp(1, cap(node));
                let mut rails = by_node[node].clone();
                rng.shuffle(&mut rails);
                let mut picked = Vec::new();
                for r in rails {
                    if picked.len() == want {
                        break;
                    }
                    if book.try_fail(r, &by_node[node], cap(node), t0, end) {
                        picked.push(r);
                    }
                }
                if picked.len() < want.clamp(1, 2) {
                    continue 'storm;
                }
                for r in picked {
                    events.push(ChaosEvent {
                        at_ns: t0,
                        rail: r,
                        kind: ActionKind::Fail,
                        factor: 0.0,
                        until_ns: end,
                        source: "storm".into(),
                    });
                    if end < horizon_ns {
                        events.push(ChaosEvent {
                            at_ns: end,
                            rail: r,
                            kind: ActionKind::Recover,
                            factor: 0.0,
                            until_ns: 0,
                            source: "storm".into(),
                        });
                    }
                }
                break 'storm;
            }
        }

        // --- 3. Slow drain: one rail stepped down in stages ---------------
        const DRAIN_FACTORS: [f64; 4] = [0.6, 0.4, 0.25, 0.15];
        for _ in 0..mix.slow_drains {
            let step = (horizon_ns / 12).max(1);
            let span = step * DRAIN_FACTORS.len() as u64;
            if span >= horizon_ns {
                break;
            }
            let t0 = rng.gen_between(horizon_ns / 8, horizon_ns - span);
            let end = t0 + span;
            for _ in 0..8 {
                let (_, rail) = *rng.choose(&flat);
                if !book.try_degrade(rail, t0, end) {
                    continue;
                }
                for (k, f) in DRAIN_FACTORS.iter().enumerate() {
                    events.push(ChaosEvent {
                        at_ns: t0 + k as u64 * step,
                        rail,
                        kind: ActionKind::Degrade,
                        factor: *f,
                        until_ns: end,
                        source: "slow-drain".into(),
                    });
                }
                events.push(ChaosEvent {
                    at_ns: end,
                    rail,
                    kind: ActionKind::Recover,
                    factor: 0.0,
                    until_ns: 0,
                    source: "slow-drain".into(),
                });
                break;
            }
        }

        // --- 4. Background congestion ramp: broad mild degradation --------
        const RAMP_FACTORS: [f64; 3] = [0.8, 0.65, 0.5];
        for _ in 0..mix.congestion_ramps {
            let step = (horizon_ns / 10).max(1);
            let span = step * RAMP_FACTORS.len() as u64;
            if span >= horizon_ns {
                break;
            }
            let t0 = rng.gen_between(horizon_ns / 8, horizon_ns - span);
            let end = t0 + span;
            let m = (flat.len() / 8).max(2);
            let mut order = flat.clone();
            rng.shuffle(&mut order);
            let mut taken = 0usize;
            for (_, rail) in order {
                if taken == m {
                    break;
                }
                if !book.try_degrade(rail, t0, end) {
                    continue;
                }
                taken += 1;
                for (k, f) in RAMP_FACTORS.iter().enumerate() {
                    events.push(ChaosEvent {
                        at_ns: t0 + k as u64 * step,
                        rail,
                        kind: ActionKind::Degrade,
                        factor: *f,
                        until_ns: end,
                        source: "congestion".into(),
                    });
                }
                events.push(ChaosEvent {
                    at_ns: end,
                    rail,
                    kind: ActionKind::Recover,
                    factor: 0.0,
                    until_ns: 0,
                    source: "congestion".into(),
                });
            }
        }

        // Stable sort: ties keep generation order, so the serialized
        // schedule is a pure function of the inputs.
        events.sort_by_key(|e| e.at_ns);
        ChaosSchedule { seed, horizon_ns, events }
    }

    /// Canonical JSON form. Object keys are BTreeMap-ordered and numbers
    /// print deterministically, so equal schedules serialize byte-equal.
    pub fn to_json(&self) -> String {
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("at_ns", Json::num(e.at_ns as f64)),
                    ("rail", Json::num(e.rail.0 as f64)),
                    ("kind", Json::str(e.kind.name())),
                    ("factor", Json::num(e.factor)),
                    ("until_ns", Json::num(e.until_ns as f64)),
                    ("source", Json::str(&e.source)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("version", Json::num(1.0)),
            // Full-width u64 seeds survive the f64 JSON number type as text.
            ("seed", Json::str(&self.seed.to_string())),
            ("horizon_ns", Json::num(self.horizon_ns as f64)),
            ("events", Json::arr(events)),
        ])
        .to_string()
    }

    pub fn from_json(s: &str) -> Result<ChaosSchedule> {
        let j = Json::parse(s).map_err(Error::Config)?;
        let seed = j
            .get("seed")
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .or_else(|| j.get("seed").as_u64())
            .ok_or_else(|| Error::Config("schedule: missing seed".into()))?;
        let horizon_ns = j
            .get("horizon_ns")
            .as_u64()
            .ok_or_else(|| Error::Config("schedule: missing horizon_ns".into()))?;
        let mut events = Vec::new();
        for (i, ev) in j
            .get("events")
            .as_arr()
            .ok_or_else(|| Error::Config("schedule: missing events".into()))?
            .iter()
            .enumerate()
        {
            let kind = ev
                .get("kind")
                .as_str()
                .and_then(ActionKind::parse)
                .ok_or_else(|| Error::Config(format!("schedule: bad kind in event {i}")))?;
            let rail = ev
                .get("rail")
                .as_u64()
                .ok_or_else(|| Error::Config(format!("schedule: bad rail in event {i}")))?;
            events.push(ChaosEvent {
                at_ns: ev.get("at_ns").as_u64().unwrap_or(0),
                rail: RailId(rail as u32),
                kind,
                factor: ev.get("factor").as_f64().unwrap_or(0.0),
                until_ns: ev.get("until_ns").as_u64().unwrap_or(0),
                source: ev.get("source").as_str().unwrap_or("").to_string(),
            });
        }
        Ok(ChaosSchedule { seed, horizon_ns, events })
    }

    /// Write the canonical form to a seed+schedule file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path, self.to_json()).map_err(Error::Io)
    }

    /// Load a schedule from a seed+schedule file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<ChaosSchedule> {
        ChaosSchedule::from_json(&std::fs::read_to_string(path).map_err(Error::Io)?)
    }

    /// FNV-1a digest of the canonical form — the replay-contract identity.
    /// Delegates to the shared [`crate::util::canon`] writer (the same one
    /// the plan journal uses), whose pinned vectors guarantee committed
    /// schedule digests never drift.
    pub fn digest(&self) -> u64 {
        crate::util::canon::fnv1a64(&self.to_json())
    }

    /// Number of `Fail` actions (the events the healing gate scores).
    pub fn fail_count(&self) -> usize {
        self.events.iter().filter(|e| e.kind == ActionKind::Fail).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::profile::build_profile;

    fn topo() -> Topology {
        build_profile("h800_hgx", 4).unwrap()
    }

    const HORIZON: u64 = 2_000_000_000; // 2 s

    #[test]
    fn generation_is_pure_in_seed() {
        let t = topo();
        let a = ChaosSchedule::generate(&t, 7, HORIZON, &ScenarioMix::default());
        let b = ChaosSchedule::generate(&t, 7, HORIZON, &ScenarioMix::default());
        assert_eq!(a, b);
        assert_eq!(a.to_json(), b.to_json());
        let c = ChaosSchedule::generate(&t, 8, HORIZON, &ScenarioMix::default());
        assert_ne!(a.to_json(), c.to_json());
    }

    #[test]
    fn events_sorted_and_faults_never_overlap_per_rail() {
        let t = topo();
        let mix = ScenarioMix {
            trace_events_per_sec: 10.0,
            ..Default::default()
        };
        let s = ChaosSchedule::generate(&t, 3, HORIZON, &mix);
        assert!(!s.events.is_empty());
        let mut last = 0;
        for e in &s.events {
            assert!(e.at_ns >= last, "unsorted at {}", e.at_ns);
            assert!(e.at_ns <= s.horizon_ns);
            last = e.at_ns;
        }
        // Fault intervals per rail never overlap (recover unambiguity).
        let mut per_rail: std::collections::HashMap<u32, Vec<(u64, u64)>> = Default::default();
        for e in &s.events {
            if e.kind != ActionKind::Recover && e.source != "flap" && e.source != "slow-drain" && e.source != "congestion" {
                per_rail.entry(e.rail.0).or_default().push((e.at_ns, e.until_ns));
            }
        }
        for (rail, mut ivs) in per_rail {
            ivs.sort();
            for w in ivs.windows(2) {
                assert!(w[0].1 <= w[1].0, "rail {rail}: {:?} overlaps {:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn node_down_budget_holds_at_every_fail_instant() {
        let t = topo();
        let mix = ScenarioMix {
            trace_events_per_sec: 20.0,
            storms: 2,
            ..Default::default()
        };
        let s = ChaosSchedule::generate(&t, 11, HORIZON, &mix);
        // Sweep the timeline: at each fail instant, count rails of the same
        // node simultaneously down; at least one sprayable rail per node
        // must remain up.
        let fails: Vec<&ChaosEvent> = s.events.iter().filter(|e| e.kind == ActionKind::Fail).collect();
        assert!(!fails.is_empty());
        for f in &fails {
            let node = t.rail(f.rail).node;
            let node_rails = t.rails_of(node, FabricKind::Rdma);
            let down = node_rails
                .iter()
                .filter(|&&r| {
                    fails.iter().any(|g| g.rail == r && g.at_ns < f.until_ns && f.at_ns < g.until_ns)
                })
                .count();
            assert!(
                down < node_rails.len(),
                "node {node:?} fully down at {}",
                f.at_ns
            );
        }
    }

    #[test]
    fn json_roundtrip_is_byte_identical() {
        let t = topo();
        let s = ChaosSchedule::generate(&t, 0xDEAD_BEEF_DEAD_BEEF, HORIZON, &ScenarioMix::default());
        let j = s.to_json();
        let r = ChaosSchedule::from_json(&j).unwrap();
        assert_eq!(s, r);
        assert_eq!(j, r.to_json());
        assert_eq!(s.digest(), r.digest());
    }

    #[test]
    fn digest_matches_the_pre_dedupe_inline_loop() {
        // PR 9 moved the FNV loop into util::canon. Re-run the original
        // inline implementation here so a change to the shared writer can
        // never silently re-key committed schedule files.
        let t = topo();
        let s = ChaosSchedule::generate(&t, 0xA11CE, HORIZON, &ScenarioMix::default());
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in s.to_json().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        assert_eq!(s.digest(), h);
    }

    #[test]
    fn rejects_malformed_schedule() {
        assert!(ChaosSchedule::from_json("{").is_err());
        assert!(ChaosSchedule::from_json("{\"seed\":\"1\"}").is_err());
        assert!(
            ChaosSchedule::from_json("{\"seed\":\"1\",\"horizon_ns\":5,\"events\":[{\"kind\":\"explode\"}]}")
                .is_err()
        );
    }
}
