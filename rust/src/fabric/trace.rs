//! Failure-trace generation from the paper's Table 1 datacenter breakdown.
//!
//! The paper reports 382 failure events/month in a representative fintech
//! deployment, with the class mix below. The generator samples that
//! empirical distribution to drive fault-injection benches: each event maps
//! to a fabric action (hard-fail / degrade) plus a duration drawn from the
//! class's recovery profile (T = transient, R = fast-recoverable, H = hard).

use crate::util::prng::Pcg64;

/// Failure event classes, weights exactly as in Table 1 (percent).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FailureEvent {
    GpuEccError,               // H    40.2
    GpuDeviceDropout,          // T/R  24.2
    GpuXidError,               // T/R   3.2
    GpuEnumerationFailure,     // R     2.4
    GpuOverTemperature,        // R     2.5
    NodeCrash,                 // R/H   7.9
    NodeBoardFailure,          // H     3.9
    NetworkCableFault,         // T/R   3.8
    NetworkLinkFlap,           // T     1.6
    NetworkNicHardware,        // H     1.0
    Other,                     // -     9.3
}

/// Recovery class from Table 1.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RecoveryClass {
    Transient,
    FastRecoverable,
    Hard,
}

impl FailureEvent {
    pub const TABLE1: [(FailureEvent, f64); 11] = [
        (FailureEvent::GpuEccError, 40.2),
        (FailureEvent::GpuDeviceDropout, 24.2),
        (FailureEvent::GpuXidError, 3.2),
        (FailureEvent::GpuEnumerationFailure, 2.4),
        (FailureEvent::GpuOverTemperature, 2.5),
        (FailureEvent::NodeCrash, 7.9),
        (FailureEvent::NodeBoardFailure, 3.9),
        (FailureEvent::NetworkCableFault, 3.8),
        (FailureEvent::NetworkLinkFlap, 1.6),
        (FailureEvent::NetworkNicHardware, 1.0),
        (FailureEvent::Other, 9.3),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FailureEvent::GpuEccError => "GPU: ECC Errors",
            FailureEvent::GpuDeviceDropout => "GPU: Device Dropout",
            FailureEvent::GpuXidError => "GPU: XID Errors",
            FailureEvent::GpuEnumerationFailure => "GPU: Device Enumeration Failures",
            FailureEvent::GpuOverTemperature => "GPU: Over-Temperature Events",
            FailureEvent::NodeCrash => "Node: Crashes",
            FailureEvent::NodeBoardFailure => "Node: Motherboard / PCIe / BMC Failures",
            FailureEvent::NetworkCableFault => "Network: Cable Fault",
            FailureEvent::NetworkLinkFlap => "Network: Frequent Link Down Events",
            FailureEvent::NetworkNicHardware => "Network: NIC Hardware Failures",
            FailureEvent::Other => "Others",
        }
    }

    pub fn recovery_class(&self) -> RecoveryClass {
        match self {
            FailureEvent::GpuEccError
            | FailureEvent::NodeBoardFailure
            | FailureEvent::NetworkNicHardware => RecoveryClass::Hard,
            FailureEvent::NetworkLinkFlap => RecoveryClass::Transient,
            FailureEvent::GpuDeviceDropout
            | FailureEvent::GpuXidError
            | FailureEvent::NetworkCableFault => RecoveryClass::Transient, // T/R: lean T
            _ => RecoveryClass::FastRecoverable,
        }
    }

    /// Does this event disturb the *communication* fabric (vs pure compute)?
    /// GPU-side disturbances frequently cascade into communication
    /// disruptions (§2.3), so most classes touch rails.
    pub fn affects_fabric(&self) -> bool {
        !matches!(self, FailureEvent::Other)
    }
}

/// A concrete injected fault: which rail-visible action, when, for how long.
#[derive(Clone, Debug)]
pub struct FaultAction {
    pub event: FailureEvent,
    /// Offset from trace start (ns, sim wall-clock).
    pub at_ns: u64,
    /// How long until recovery (ns). Hard failures get a long horizon.
    pub duration_ns: u64,
    /// True → hard-fail the rail; false → degrade it.
    pub hard: bool,
    /// Bandwidth factor when degrading.
    pub degrade_factor: f64,
}

/// Generates a fault timeline over `horizon_ns` with the Table 1 mix.
/// `events_per_sec` controls intensity (production: 382/month; benches
/// compress this to several per second).
pub struct TraceGenerator {
    rng: Pcg64,
    weights_cdf: Vec<(FailureEvent, f64)>,
}

impl TraceGenerator {
    pub fn new(seed: u64) -> Self {
        let total: f64 = FailureEvent::TABLE1.iter().map(|(_, w)| w).sum();
        let mut acc = 0.0;
        let weights_cdf = FailureEvent::TABLE1
            .iter()
            .map(|&(e, w)| {
                acc += w / total;
                (e, acc)
            })
            .collect();
        TraceGenerator {
            rng: Pcg64::new(seed, 0xFA17),
            weights_cdf,
        }
    }

    /// Sample one event class from the Table 1 distribution.
    pub fn sample_event(&mut self) -> FailureEvent {
        let u = self.rng.next_f64();
        for &(e, cum) in &self.weights_cdf {
            if u <= cum {
                return e;
            }
        }
        FailureEvent::Other
    }

    /// Build a full timeline: Poisson arrivals at `events_per_sec` over
    /// `horizon_ns`.
    pub fn generate(&mut self, horizon_ns: u64, events_per_sec: f64) -> Vec<FaultAction> {
        let mut out = Vec::new();
        let mean_gap_ns = 1e9 / events_per_sec.max(1e-9);
        let mut t = 0u64;
        loop {
            t += self.rng.gen_exp(mean_gap_ns) as u64;
            if t >= horizon_ns {
                break;
            }
            let event = self.sample_event();
            if !event.affects_fabric() {
                continue;
            }
            let (duration_ns, hard, degrade_factor) = match event.recovery_class() {
                // Transient: tens to hundreds of ms.
                RecoveryClass::Transient => (
                    self.rng.gen_between(20_000_000, 400_000_000),
                    self.rng.gen_bool(0.6),
                    0.05 + 0.3 * self.rng.next_f64(),
                ),
                // Fast-recoverable: seconds.
                RecoveryClass::FastRecoverable => (
                    self.rng.gen_between(500_000_000, 3_000_000_000),
                    self.rng.gen_bool(0.3),
                    0.1 + 0.4 * self.rng.next_f64(),
                ),
                // Hard: does not recover within any bench horizon
                // (paper MTTR: 160.21 min).
                RecoveryClass::Hard => (u64::MAX / 4, true, 0.0),
            };
            out.push(FaultAction {
                event,
                at_ns: t,
                duration_ns,
                hard,
                degrade_factor,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn distribution_matches_table1() {
        let mut g = TraceGenerator::new(7);
        let mut counts: HashMap<&'static str, u32> = HashMap::new();
        const N: u32 = 100_000;
        for _ in 0..N {
            *counts.entry(g.sample_event().name()).or_default() += 1;
        }
        for (e, pct) in FailureEvent::TABLE1 {
            let got = *counts.get(e.name()).unwrap_or(&0) as f64 / N as f64 * 100.0;
            assert!(
                (got - pct).abs() < 0.6,
                "{}: got {got:.2}% expected {pct}%",
                e.name()
            );
        }
    }

    #[test]
    fn timeline_sorted_and_within_horizon() {
        let mut g = TraceGenerator::new(3);
        let horizon = 10_000_000_000; // 10 s
        let actions = g.generate(horizon, 5.0);
        assert!(!actions.is_empty());
        let mut last = 0;
        for a in &actions {
            assert!(a.at_ns >= last && a.at_ns < horizon);
            last = a.at_ns;
        }
    }

    #[test]
    fn hard_failures_never_recover_in_horizon() {
        let mut g = TraceGenerator::new(11);
        let actions = g.generate(60_000_000_000, 20.0);
        let hard: Vec<_> = actions
            .iter()
            .filter(|a| a.event.recovery_class() == RecoveryClass::Hard)
            .collect();
        assert!(!hard.is_empty());
        for a in hard {
            assert!(a.hard);
            assert!(a.duration_ns > 60_000_000_000);
        }
    }

    #[test]
    fn intensity_scales_event_count() {
        let mut g1 = TraceGenerator::new(5);
        let mut g2 = TraceGenerator::new(5);
        let sparse = g1.generate(5_000_000_000, 2.0).len();
        let dense = g2.generate(5_000_000_000, 40.0).len();
        assert!(dense > 5 * sparse, "sparse={sparse} dense={dense}");
    }

    #[test]
    fn chi_squared_pins_table1_over_10k_samples() {
        // Pearson χ² against the Table 1 expected counts. 11 classes →
        // 10 degrees of freedom; the p = 0.001 critical value is 29.59,
        // so a pass means the sampler is statistically indistinguishable
        // from the published mix — a far tighter pin than per-class
        // percentage tolerances.
        let mut g = TraceGenerator::new(0x7AB1E);
        const N: u32 = 10_000;
        let mut counts: HashMap<&'static str, u32> = HashMap::new();
        for _ in 0..N {
            *counts.entry(g.sample_event().name()).or_default() += 1;
        }
        let total: f64 = FailureEvent::TABLE1.iter().map(|(_, w)| w).sum();
        let mut chi2 = 0.0;
        for (e, pct) in FailureEvent::TABLE1 {
            let expected = N as f64 * pct / total;
            let observed = *counts.get(e.name()).unwrap_or(&0) as f64;
            chi2 += (observed - expected).powi(2) / expected;
        }
        assert!(chi2 < 29.59, "chi² = {chi2:.2} exceeds the 10-df p=0.001 bound");
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let horizon = 8_000_000_000;
        let a = TraceGenerator::new(99).generate(horizon, 6.0);
        let b = TraceGenerator::new(99).generate(horizon, 6.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.event, y.event);
            assert_eq!(x.at_ns, y.at_ns);
            assert_eq!(x.duration_ns, y.duration_ns);
            assert_eq!(x.hard, y.hard);
            assert_eq!(x.degrade_factor, y.degrade_factor);
        }
        let c = TraceGenerator::new(100).generate(horizon, 6.0);
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.at_ns != y.at_ns),
            "distinct seeds produced identical timelines"
        );
    }

    #[test]
    fn fault_parameters_stay_in_class_envelopes() {
        let mut g = TraceGenerator::new(0xFA11);
        let actions = g.generate(30_000_000_000, 15.0);
        assert!(actions.len() > 100, "need a broad sample, got {}", actions.len());
        for a in &actions {
            // `Other` is pure-compute noise: it never reaches the fabric
            // timeline (affects_fabric() filters it at generation).
            assert_ne!(a.event, FailureEvent::Other);
            assert!(a.duration_ns > 0);
            match a.event.recovery_class() {
                RecoveryClass::Transient => {
                    assert!((20_000_000..=400_000_000).contains(&a.duration_ns), "{a:?}");
                    if !a.hard {
                        assert!(a.degrade_factor >= 0.05 && a.degrade_factor < 0.35, "{a:?}");
                    }
                }
                RecoveryClass::FastRecoverable => {
                    assert!(
                        (500_000_000..=3_000_000_000).contains(&a.duration_ns),
                        "{a:?}"
                    );
                    if !a.hard {
                        assert!(a.degrade_factor >= 0.1 && a.degrade_factor < 0.5, "{a:?}");
                    }
                }
                RecoveryClass::Hard => {
                    assert!(a.hard, "{a:?}");
                    assert_eq!(a.duration_ns, u64::MAX / 4);
                }
            }
        }
    }
}
