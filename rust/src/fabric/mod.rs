//! The simulated hardware fabric: per-rail pacing, degradation, failure
//! injection.
//!
//! Every rail is serviced by exactly one pinned worker thread (see
//! `engine::datapath`), so queueing discipline is physical: a slice's
//! completion time = time spent waiting in the rail's ring + the service
//! time computed here. Service time is derived from the rail's nominal
//! bandwidth, a degradation factor (failure injection / noisy neighbours),
//! a cross-NUMA penalty (remote-socket DMA runs slower — the §2.2
//! non-uniformity), and multiplicative jitter.
//!
//! Bytes are *actually copied* between segment backings by the transport
//! backends; the fabric only decides how long the wire would have taken.

pub mod trace;

use crate::log;
use crate::topology::{NodeId, RailId, Topology};
use crate::util::ewma::AtomicF64;
use crate::util::hist::Histogram;
use crate::util::prng::Pcg64;
use crate::util::sharded::ShardedU64;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

/// Number of QoS classes the per-rail telemetry is sized for. Kept in
/// compile-time lockstep with `engine::TransferClass::COUNT` (a const
/// assert in `engine` fails the build if they diverge).
pub const QOS_CLASSES: usize = 2;

/// Health of a rail as set by failure injection / the prober.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum RailHealth {
    Healthy = 0,
    /// Operating at reduced bandwidth (transient signal degradation).
    Degraded = 1,
    /// Hard-failed: slices error out (flapping NIC, dead link).
    Failed = 2,
}

impl RailHealth {
    fn from_u8(v: u8) -> RailHealth {
        match v {
            0 => RailHealth::Healthy,
            1 => RailHealth::Degraded,
            _ => RailHealth::Failed,
        }
    }
}

/// Runtime state of one rail.
pub struct RailState {
    pub id: RailId,
    health: AtomicU8,
    /// Bandwidth multiplier ∈ (0, 1]; 1 = nominal. Degradation lowers it.
    bw_factor: AtomicF64,
    /// Bytes scheduled onto this rail and not yet completed (the A_d of
    /// Algorithm 1), **per QoS class** — `[latency, bulk]`, indexed by
    /// `engine::TransferClass::index`. Maintained by the scheduler +
    /// datapath. Each lane is striped over per-engine cache-padded shards
    /// (`FabricConfig::counter_shards`) so a fleet of engines updating the
    /// same rail does not serialize on one cache line. Read the total via
    /// [`RailState::queued_bytes`], one lane via
    /// [`RailState::queued_bytes_class`] — per-class lanes are what lets
    /// the ω global-diffusion path stop feeding Bulk backlog into Latency
    /// predictions.
    queued: [ShardedU64; QOS_CLASSES],
    /// Total payload bytes carried (per-NIC byte counters, §5.1.3).
    pub bytes_carried: AtomicU64,
    pub slices_ok: AtomicU64,
    pub slices_failed: AtomicU64,
    /// Observed per-slice service latency (ns).
    pub latency: Histogram,
    /// Per-QoS-class observed slice latency, `[latency, bulk]` — indexed by
    /// `engine::TransferClass::index` (the fabric itself is class-agnostic;
    /// the datapath records here).
    pub class_latency: [Histogram; QOS_CLASSES],
    /// Generation counter bumped on every health transition (lets the
    /// resilience layer detect flaps without locks).
    pub health_gen: AtomicU64,
    /// Accumulated pacing overshoot (ns): OS sleeps overshoot their
    /// deadline, especially on small core counts; the debt is repaid by
    /// shortening subsequent sleeps so long-run rail bandwidth is exact.
    pace_debt_ns: AtomicU64,
    /// Static manufacturing/cabling variation (§2.2: "rail performance is
    /// highly non-uniform"): fixed multiplier on top of the dynamic factor.
    static_factor: f64,
}

impl RailState {
    fn new(id: RailId, static_factor: f64, counter_shards: usize) -> Self {
        RailState {
            id,
            health: AtomicU8::new(RailHealth::Healthy as u8),
            bw_factor: AtomicF64::new(1.0),
            queued: [
                ShardedU64::new(counter_shards),
                ShardedU64::new(counter_shards),
            ],
            bytes_carried: AtomicU64::new(0),
            slices_ok: AtomicU64::new(0),
            slices_failed: AtomicU64::new(0),
            latency: Histogram::new(),
            class_latency: [Histogram::new(), Histogram::new()],
            health_gen: AtomicU64::new(0),
            pace_debt_ns: AtomicU64::new(0),
            static_factor,
        }
    }

    pub fn health(&self) -> RailHealth {
        RailHealth::from_u8(self.health.load(Ordering::Acquire))
    }

    pub fn bw_factor(&self) -> f64 {
        self.bw_factor.load()
    }

    /// Current queued bytes (A_d), all classes: sum over lanes and shards.
    #[inline]
    pub fn queued_bytes(&self) -> u64 {
        self.queued.iter().map(|l| l.sum()).sum()
    }

    /// Current queued bytes of one QoS class lane (`class` is
    /// `engine::TransferClass::index`).
    #[inline]
    pub fn queued_bytes_class(&self, class: usize) -> u64 {
        self.queued[class].sum()
    }
}

/// Fabric-wide jitter / asymmetry knobs.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Multiplicative service-time jitter stddev (e.g. 0.05 = ±5%).
    pub jitter_sigma: f64,
    /// Bandwidth multiplier when the transfer's memory is on a different
    /// NUMA node than the rail (cross-socket DMA penalty, §2.2).
    pub cross_numa_bw_factor: f64,
    /// Extra fixed latency (ns) for cross-NUMA submissions.
    pub cross_numa_extra_ns: u64,
    /// Bandwidth multiplier for tier-2 paths (device buffer behind a
    /// different PCIe root than the NIC — traverses the PCIe switch).
    pub cross_root_bw_factor: f64,
    /// Extra fixed latency (ns) for cross-root paths.
    pub cross_root_extra_ns: u64,
    /// Std-dev of static per-rail bandwidth variation (§2.2 non-uniformity;
    /// 0 = perfectly uniform rails). Sampled once per rail at construction.
    pub rail_heterogeneity_sigma: f64,
    /// Seed for the static variation sampling (deterministic fabrics).
    pub seed: u64,
    /// Global speed multiplier for tests (greater = faster wall-clock).
    pub time_compression: f64,
    /// Stripes for the per-rail queued-bytes counters (rounded up to a
    /// power of two). 1 = the classic single atomic per rail; fleets size
    /// this to their engine count so each engine writes a private
    /// cache-padded shard (see `Fabric::register_engine`).
    pub counter_shards: usize,
    /// NUMA-style domain count for the shard→engine mapping (see
    /// `ShardedU64::shard_of_domain`): engines registered into domain `d`
    /// get shards from domain `d`'s contiguous block of the stripe array,
    /// so one socket's engines stay on cache lines that socket owns.
    /// `1` (default) reproduces the plain interleaved mapping exactly.
    pub numa_domains: usize,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            jitter_sigma: 0.04,
            cross_numa_bw_factor: 0.60,
            cross_numa_extra_ns: 30_000,
            cross_root_bw_factor: 0.75,
            cross_root_extra_ns: 15_000,
            rail_heterogeneity_sigma: 0.06,
            seed: 0xFAB,
            time_compression: 1.0,
            counter_shards: 1,
            numa_domains: 1,
        }
    }
}

/// Fabric-level contention telemetry: how hard the shared counters are
/// being exercised. Drives the `fig_scaling` bench's PASS/FAIL evidence.
pub struct FabricContention {
    /// Full shard-sum reads of rail queued-bytes counters (each read is
    /// O(counter_shards); the ω load-diffusion path is the hot reader).
    /// Itself striped per engine — a telemetry counter on the read hot
    /// path must not reintroduce the shared cache line the queued-bytes
    /// sharding removed. Read with `.sum()`.
    pub shard_sum_reads: ShardedU64,
    /// `sub_queued` calls that found less queued on the shard than they
    /// tried to remove and clamped to zero. Always an accounting bug for
    /// well-behaved engines; saturating semantics keep the fabric sane,
    /// this counter (plus a debug assertion) makes it observable. Cold
    /// path, so a plain atomic is fine.
    pub underflow_clamps: AtomicU64,
    /// Ingress claims or releases aimed at a node outside the ingress
    /// table (the fabric was built for a smaller topology than the plan
    /// references). Claim and release both clamp — symmetrically, so a
    /// clamped claim can never leave a phantom balance for the release to
    /// underflow — and both count here so skewed `rx_omega` pricing is
    /// observable instead of silent.
    pub ingress_oob_clamps: AtomicU64,
}

impl FabricContention {
    fn new(shards: usize) -> FabricContention {
        FabricContention {
            shard_sum_reads: ShardedU64::new(shards),
            underflow_clamps: AtomicU64::new(0),
            ingress_oob_clamps: AtomicU64::new(0),
        }
    }
}

/// The fabric: rail runtime state + service-time model + failure injection.
pub struct Fabric {
    pub rails: Vec<RailState>,
    pub config: FabricConfig,
    /// Shared-counter contention telemetry.
    pub contention: FabricContention,
    /// Monotonic engine registration sequence (shard assignment).
    engine_seq: AtomicUsize,
    /// Per-destination-node ingestion backlog, per QoS class — bytes
    /// dispatched *towards* a node and not yet completed. `predict_ns`
    /// historically priced only the sender's rail queue; these counters
    /// let the scheduler also price the receiver's ingest pressure
    /// (`SchedParams::rx_omega`), so sprays back off a node that many
    /// peers are incasting into even when the local rail looks idle.
    /// Same shard geometry as the rail queues.
    node_ingress: Vec<[ShardedU64; QOS_CLASSES]>,
    /// Per-node relay ledger `[bytes_in, bytes_out]`: payload buffered into
    /// / forwarded out of each node's host staging memory by multi-hop
    /// staged transfers. Conservation invariant once traffic drains:
    /// `in == out` at every relay node (no byte enters a relay without
    /// leaving it). Cold path — one pair of bumps per slice per relay.
    relay_ledger: Vec<[AtomicU64; 2]>,
}

impl Fabric {
    pub fn new(topo: &Topology, config: FabricConfig) -> Fabric {
        let mut rng = Pcg64::new(config.seed, 0x5747);
        let shards = config.counter_shards.max(1);
        let rails = topo
            .rails
            .iter()
            .map(|r| {
                let f = if config.rail_heterogeneity_sigma > 0.0 {
                    (1.0 + rng.gen_normal(0.0, config.rail_heterogeneity_sigma)).clamp(0.75, 1.2)
                } else {
                    1.0
                };
                RailState::new(r.id, f, shards)
            })
            .collect();
        let node_ingress = topo
            .nodes
            .iter()
            .map(|_| [ShardedU64::new(shards), ShardedU64::new(shards)])
            .collect();
        let relay_ledger = topo
            .nodes
            .iter()
            .map(|_| [AtomicU64::new(0), AtomicU64::new(0)])
            .collect();
        Fabric {
            rails,
            config,
            contention: FabricContention::new(shards),
            engine_seq: AtomicUsize::new(0),
            node_ingress,
            relay_ledger,
        }
    }

    /// Register an engine instance sharing this fabric and hand it a
    /// counter-shard id. With `counter_shards = 1` every engine maps to
    /// shard 0 (the single-counter baseline); with shards ≥ engines each
    /// engine's `add_queued`/`sub_queued` touches a private cache line.
    /// With `numa_domains > 1` engines are spread round-robin over the
    /// domains in registration order; callers that know their domain use
    /// [`Fabric::register_engine_in_domain`] instead.
    pub fn register_engine(&self) -> usize {
        let id = self.engine_seq.fetch_add(1, Ordering::AcqRel);
        let domains = self.config.numa_domains.max(1);
        // All rails share one shard geometry; rail 0 is representative.
        self.rails
            .first()
            .map(|r| {
                let q = &r.queued[0];
                if domains <= 1 {
                    q.shard_of(id)
                } else {
                    q.shard_of_domain(id / domains, id % domains, domains)
                }
            })
            .unwrap_or(0)
    }

    /// Register an engine that knows which NUMA domain it runs in (fleets
    /// group engines by node/socket): its shard is carved from that
    /// domain's contiguous stripe block. With `numa_domains <= 1` this is
    /// identical to [`Fabric::register_engine`].
    pub fn register_engine_in_domain(&self, domain: usize) -> usize {
        let id = self.engine_seq.fetch_add(1, Ordering::AcqRel);
        let domains = self.config.numa_domains.max(1);
        self.rails
            .first()
            .map(|r| r.queued[0].shard_of_domain(id, domain, domains))
            .unwrap_or(0)
    }

    #[inline]
    pub fn rail(&self, id: RailId) -> &RailState {
        &self.rails[id.0 as usize]
    }

    /// Compute the wire service time (ns) for `len` bytes on `rail`.
    /// `cross_numa` marks transfers whose buffer lives on the remote socket.
    /// Returns `None` if the rail is hard-failed (slice must error).
    pub fn service_ns(
        &self,
        topo: &Topology,
        rail: RailId,
        len: u64,
        affinity: crate::transport::PathAffinity,
        rng: &mut Pcg64,
    ) -> Option<u64> {
        let st = self.rail(rail);
        if st.health() == RailHealth::Failed {
            return None;
        }
        let def = topo.rail(rail);
        let mut bw = def.bw_bytes_per_sec * st.bw_factor() * st.static_factor;
        let mut lat = def.base_latency_ns as f64;
        if affinity.cross_numa {
            bw *= self.config.cross_numa_bw_factor;
            lat += self.config.cross_numa_extra_ns as f64;
        }
        if affinity.cross_root {
            bw *= self.config.cross_root_bw_factor;
            lat += self.config.cross_root_extra_ns as f64;
        }
        let serial = len as f64 / bw.max(1.0) * 1e9;
        let jitter = (1.0 + rng.gen_normal(0.0, self.config.jitter_sigma)).max(0.5);
        let total = (lat + serial) * jitter / self.config.time_compression.max(1e-9);
        Some(total as u64)
    }

    /// Pace a slice that started at `start_ns` out to `service_ns` of wire
    /// time, compensating accumulated OS-sleep overshoot (debt) so that the
    /// rail's *long-run* throughput equals its configured bandwidth even on
    /// oversubscribed hosts. Debt is capped so a long stall cannot cause an
    /// unbounded catch-up burst.
    pub fn pace(&self, rail: RailId, start_ns: u64, service_ns: u64) {
        const DEBT_CAP_NS: u64 = 20_000_000; // 20 ms
        let st = self.rail(rail);
        let debt = st.pace_debt_ns.swap(0, Ordering::Relaxed);
        let target = service_ns.saturating_sub(debt);
        crate::util::clock::sleep_until_ns(start_ns + target);
        let actual = crate::util::clock::now_ns().saturating_sub(start_ns);
        // leftover = what we still owe (unused debt) + fresh overshoot.
        let leftover = (debt + actual).saturating_sub(service_ns).min(DEBT_CAP_NS);
        if leftover > 0 {
            st.pace_debt_ns.fetch_add(leftover, Ordering::Relaxed);
        }
    }

    // ---- failure injection API (drives Fig 10 / §5.3) ----

    /// Transition a rail's health; returns whether a transition actually
    /// happened. A no-op transition (already in `h`) leaves `health_gen`
    /// untouched **and performs no RMW on the health word** — chaos
    /// schedules recover rails liberally, and both a spurious generation
    /// bump (reads as a flap to the resilience layer) and a redundant
    /// atomic store (cache-line traffic on the service-time hot path's
    /// read) would distort what the harness measures.
    fn set_health(&self, rail: RailId, h: RailHealth) -> bool {
        let st = self.rail(rail);
        let mut cur = st.health.load(Ordering::Acquire);
        loop {
            if cur == h as u8 {
                return false;
            }
            match st
                .health
                .compare_exchange_weak(cur, h as u8, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => {
                    st.health_gen.fetch_add(1, Ordering::AcqRel);
                    return true;
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Hard-fail a rail: in-flight and future slices on it error out.
    pub fn inject_failure(&self, rail: RailId) {
        if self.set_health(rail, RailHealth::Failed) {
            log::warn!("fabric: injecting hard failure on {rail}");
        }
    }

    /// Degrade a rail to `factor` × nominal bandwidth (0 < factor ≤ 1).
    /// Repeat calls on an already-degraded rail update the factor (a
    /// slow-drain ramp) without bumping the health generation.
    pub fn inject_degradation(&self, rail: RailId, factor: f64) {
        log::warn!("fabric: degrading {rail} to {factor}x");
        self.rail(rail).bw_factor.store(factor.clamp(0.01, 1.0));
        self.set_health(rail, RailHealth::Degraded);
    }

    /// Restore a rail to full health. Calling this on a rail that never
    /// failed (or was already recovered) is a complete no-op: no
    /// `health_gen` bump, no stores, no log line.
    pub fn recover(&self, rail: RailId) {
        let st = self.rail(rail);
        if st.health() == RailHealth::Healthy && st.bw_factor() == 1.0 {
            return;
        }
        log::info!("fabric: recovering {rail}");
        st.bw_factor.store(1.0);
        self.set_health(rail, RailHealth::Healthy);
    }

    /// Account bytes entering / leaving a rail's queue (A_d maintenance).
    /// `class` is the QoS lane (`engine::TransferClass::index`) — the
    /// fabric keeps the lanes separate so the global diffusion read can be
    /// class-scoped. Single-shard convenience forms; engines sharing the
    /// fabric use the `_at` variants with their `register_engine` shard so
    /// the hot-path RMWs stay on private cache lines.
    #[inline]
    pub fn add_queued(&self, rail: RailId, len: u64, class: usize) {
        self.add_queued_at(0, rail, len, class);
    }
    #[inline]
    pub fn sub_queued(&self, rail: RailId, len: u64, class: usize) {
        self.sub_queued_at(0, rail, len, class);
    }

    #[inline]
    pub fn add_queued_at(&self, shard: usize, rail: RailId, len: u64, class: usize) {
        self.rail(rail).queued[class].add(shard, len);
    }

    /// Saturating per-shard subtract. A clamp means some engine removed
    /// more than it ever added on its shard — an accounting bug upstream.
    /// The fabric stays sane (never wraps to ~2^64 queued bytes, which
    /// would poison every cost prediction on the rail), counts the event
    /// in `contention.underflow_clamps`, and trips a debug assertion.
    #[inline]
    pub fn sub_queued_at(&self, shard: usize, rail: RailId, len: u64, class: usize) {
        if self.rail(rail).queued[class].sub_saturating(shard, len) {
            self.contention.underflow_clamps.fetch_add(1, Ordering::Relaxed);
            log::warn!("fabric: queued-bytes underflow clamped on {rail} (shard {shard}, -{len})");
            debug_assert!(
                false,
                "queued-bytes underflow on {rail}: shard {shard} asked to drop {len} more than it holds"
            );
        }
    }

    /// Read a rail's queued bytes (A_d) across **all** classes, summing
    /// all counter shards. This is the ω load-diffusion read path; each
    /// call is counted (on the caller's telemetry stripe) so benches can
    /// weigh read amplification against write isolation.
    #[inline]
    pub fn queued_bytes_from(&self, shard: usize, rail: RailId) -> u64 {
        self.contention.shard_sum_reads.add(shard, 1);
        self.rail(rail).queued_bytes()
    }

    /// Class-scoped diffusion read: only `class`'s lane of the rail queue.
    /// Latency-class predictions use this so a Bulk flood on the shared
    /// fabric no longer pollutes their global queue term.
    #[inline]
    pub fn queued_bytes_class_from(&self, shard: usize, rail: RailId, class: usize) -> u64 {
        self.contention.shard_sum_reads.add(shard, 1);
        self.rail(rail).queued_bytes_class(class)
    }

    /// Single-stripe convenience form of [`Fabric::queued_bytes_from`].
    #[inline]
    pub fn queued_bytes(&self, rail: RailId) -> u64 {
        self.queued_bytes_from(0, rail)
    }

    // ---- receiver-side (dst-node) ingestion accounting ----

    /// Account bytes dispatched towards `node` (receiver-side pressure).
    /// A node outside the ingress table clamps the claim (counted in
    /// `contention.ingress_oob_clamps`) — symmetric with
    /// [`Fabric::sub_ingress_at`], so a clamped claim and its clamped
    /// release always balance.
    #[inline]
    pub fn add_ingress_at(&self, shard: usize, node: NodeId, len: u64, class: usize) {
        match self.node_ingress.get(node.0 as usize) {
            Some(lanes) => lanes[class].add(shard, len),
            None => {
                self.contention.ingress_oob_clamps.fetch_add(1, Ordering::Relaxed);
                log::warn!(
                    "fabric: ingress claim on out-of-range node {} clamped (shard {shard}, +{len})",
                    node.0
                );
            }
        }
    }

    /// Retire receiver-side bytes once the slice completes (or gives up).
    /// Saturating like [`Fabric::sub_queued_at`]; in-range underflows share
    /// that telemetry since both clamp for the same class of upstream bug.
    /// Out-of-range nodes clamp-and-count exactly like the claim path.
    #[inline]
    pub fn sub_ingress_at(&self, shard: usize, node: NodeId, len: u64, class: usize) {
        match self.node_ingress.get(node.0 as usize) {
            Some(lanes) => {
                if lanes[class].sub_saturating(shard, len) {
                    self.contention.underflow_clamps.fetch_add(1, Ordering::Relaxed);
                    log::warn!(
                        "fabric: ingress underflow clamped on node {} (shard {shard}, -{len})",
                        node.0
                    );
                    debug_assert!(
                        false,
                        "node-ingress underflow on node {}: shard {shard} asked to drop {len}",
                        node.0
                    );
                }
            }
            None => {
                self.contention.ingress_oob_clamps.fetch_add(1, Ordering::Relaxed);
                log::warn!(
                    "fabric: ingress release on out-of-range node {} clamped (shard {shard}, -{len})",
                    node.0
                );
            }
        }
    }

    /// Read a node's ingestion backlog for one class (all shards).
    #[inline]
    pub fn ingress_bytes_class_from(&self, shard: usize, node: NodeId, class: usize) -> u64 {
        self.contention.shard_sum_reads.add(shard, 1);
        self.node_ingress
            .get(node.0 as usize)
            .map(|lanes| lanes[class].sum())
            .unwrap_or(0)
    }

    /// Total ingestion backlog of a node across classes (telemetry).
    #[inline]
    pub fn ingress_bytes(&self, node: NodeId) -> u64 {
        self.node_ingress
            .get(node.0 as usize)
            .map(|lanes| lanes.iter().map(|l| l.sum()).sum())
            .unwrap_or(0)
    }

    // ---- relay byte ledger (multi-hop staged routes) ----

    /// Record `len` payload bytes buffered *into* `node`'s host staging
    /// memory by a multi-hop staged transfer. Out-of-range nodes are
    /// dropped silently: the ledger is pure telemetry, unlike the ingress
    /// claims it never feeds pricing.
    #[inline]
    pub fn relay_in(&self, node: NodeId, len: u64) {
        if let Some(pair) = self.relay_ledger.get(node.0 as usize) {
            pair[0].fetch_add(len, Ordering::Relaxed);
        }
    }

    /// Record `len` payload bytes forwarded *out of* `node`'s host staging
    /// memory towards the next hop.
    #[inline]
    pub fn relay_out(&self, node: NodeId, len: u64) {
        if let Some(pair) = self.relay_ledger.get(node.0 as usize) {
            pair[1].fetch_add(len, Ordering::Relaxed);
        }
    }

    /// `(bytes_in, bytes_out)` relayed through `node`. Once in-flight
    /// traffic drains the two must be equal at every relay node — the
    /// byte-conservation invariant multi-hop tests pin.
    #[inline]
    pub fn relay_bytes(&self, node: NodeId) -> (u64, u64) {
        self.relay_ledger
            .get(node.0 as usize)
            .map(|pair| (pair[0].load(Ordering::Relaxed), pair[1].load(Ordering::Relaxed)))
            .unwrap_or((0, 0))
    }

    /// Snapshot per-rail byte counters (Fig 6 "per-NIC byte counters").
    pub fn byte_counters(&self) -> Vec<(RailId, u64)> {
        self.rails
            .iter()
            .map(|r| (r.id, r.bytes_carried.load(Ordering::Relaxed)))
            .collect()
    }

    /// Reset all statistics (between bench phases).
    pub fn reset_stats(&self) {
        for r in &self.rails {
            r.bytes_carried.store(0, Ordering::Relaxed);
            r.slices_ok.store(0, Ordering::Relaxed);
            r.slices_failed.store(0, Ordering::Relaxed);
            r.latency.reset();
            for h in &r.class_latency {
                h.reset();
            }
        }
        for pair in &self.relay_ledger {
            pair[0].store(0, Ordering::Relaxed);
            pair[1].store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::profile::build_profile;
    use crate::topology::FabricKind;
    use crate::topology::NodeId;

    fn fabric() -> (Topology, Fabric) {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        (t, f)
    }

    #[test]
    fn service_time_scales_with_length() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let mut rng = Pcg64::new(1, 0);
        let small: u64 = (0..32)
            .map(|_| f.service_ns(&t, rail, 64 << 10, crate::transport::PathAffinity::default(), &mut rng).unwrap())
            .sum::<u64>()
            / 32;
        let large: u64 = (0..32)
            .map(|_| f.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap())
            .sum::<u64>()
            / 32;
        // 1 MiB is 16x the bytes of 64 KiB; with base latency the ratio is
        // a bit under 16 but far above 8.
        assert!(large > 8 * small, "small={small} large={large}");
    }

    #[test]
    fn cross_numa_is_slower() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let mut rng = Pcg64::new(1, 0);
        let near: u64 = (0..64)
            .map(|_| f.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap())
            .sum();
        let far: u64 = (0..64)
            .map(|_| f.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity { cross_numa: true, cross_root: false }, &mut rng).unwrap())
            .sum();
        assert!(far as f64 > 1.4 * near as f64, "near={near} far={far}");
    }

    #[test]
    fn failed_rail_returns_none() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let mut rng = Pcg64::new(1, 0);
        f.inject_failure(rail);
        assert!(f.service_ns(&t, rail, 4096, crate::transport::PathAffinity::default(), &mut rng).is_none());
        f.recover(rail);
        assert!(f.service_ns(&t, rail, 4096, crate::transport::PathAffinity::default(), &mut rng).is_some());
    }

    #[test]
    fn degradation_slows_rail_and_recovery_restores() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let mut rng = Pcg64::new(1, 0);
        let avg = |f: &Fabric, rng: &mut Pcg64| -> u64 {
            (0..32)
                .map(|_| f.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity::default(), rng).unwrap())
                .sum::<u64>()
                / 32
        };
        let healthy = avg(&f, &mut rng);
        f.inject_degradation(rail, 0.25);
        assert_eq!(f.rail(rail).health(), RailHealth::Degraded);
        let degraded = avg(&f, &mut rng);
        assert!(degraded as f64 > 3.0 * healthy as f64);
        f.recover(rail);
        let recovered = avg(&f, &mut rng);
        assert!((recovered as f64) < 1.3 * healthy as f64);
    }

    #[test]
    fn health_generation_counts_transitions() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let g0 = f.rail(rail).health_gen.load(Ordering::Relaxed);
        f.inject_failure(rail);
        f.inject_failure(rail); // same state: no bump
        f.recover(rail);
        let g1 = f.rail(rail).health_gen.load(Ordering::Relaxed);
        assert_eq!(g1 - g0, 2);
    }

    #[test]
    fn recover_on_never_failed_rail_is_a_noop() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let g0 = f.rail(rail).health_gen.load(Ordering::Relaxed);
        // Spurious recovers (a chaos schedule's cleanup sweep, a prober
        // being conservative) must not read as health transitions.
        f.recover(rail);
        f.recover(rail);
        assert_eq!(f.rail(rail).health(), RailHealth::Healthy);
        assert_eq!(f.rail(rail).health_gen.load(Ordering::Relaxed), g0);
        // A real failure still counts exactly one transition per edge,
        // no matter how many times recovery is re-asserted.
        f.inject_failure(rail);
        f.recover(rail);
        f.recover(rail);
        f.recover(rail);
        assert_eq!(f.rail(rail).health_gen.load(Ordering::Relaxed), g0 + 2);
    }

    #[test]
    fn recover_after_degradation_restores_factor_once() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let g0 = f.rail(rail).health_gen.load(Ordering::Relaxed);
        f.inject_degradation(rail, 0.3);
        // Slow-drain ramp: factor updates, still one Degraded transition.
        f.inject_degradation(rail, 0.2);
        assert_eq!(f.rail(rail).health_gen.load(Ordering::Relaxed), g0 + 1);
        f.recover(rail);
        assert_eq!(f.rail(rail).bw_factor(), 1.0);
        assert_eq!(f.rail(rail).health_gen.load(Ordering::Relaxed), g0 + 2);
        f.recover(rail); // no-op
        assert_eq!(f.rail(rail).health_gen.load(Ordering::Relaxed), g0 + 2);
    }

    #[test]
    fn queued_bytes_accounting_balances() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        f.add_queued(rail, 100, 1);
        f.sub_queued(rail, 60, 1);
        assert_eq!(f.rail(rail).queued_bytes(), 40);
        f.sub_queued(rail, 40, 1);
        assert_eq!(f.rail(rail).queued_bytes(), 0);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queued_bytes_underflow_clamps_and_is_loud() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        f.add_queued(rail, 40, 0);
        if cfg!(debug_assertions) {
            // Over-subtracting is an upstream accounting bug: debug builds
            // trip the assertion…
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f.sub_queued(rail, 100, 0)
            }));
            assert!(r.is_err(), "debug builds must assert on underflow");
        } else {
            f.sub_queued(rail, 100, 0);
        }
        // …but the counter itself saturates (never wraps) and the clamp is
        // counted, in every build.
        assert_eq!(f.rail(rail).queued_bytes(), 0);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queued_class_lanes_are_isolated() {
        let (t, f) = fabric();
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        f.add_queued(rail, 1_000, 0); // latency lane
        f.add_queued(rail, 50_000, 1); // bulk lane
        assert_eq!(f.rail(rail).queued_bytes(), 51_000);
        assert_eq!(f.rail(rail).queued_bytes_class(0), 1_000);
        assert_eq!(f.rail(rail).queued_bytes_class(1), 50_000);
        assert_eq!(f.queued_bytes_class_from(0, rail, 0), 1_000);
        // A bulk drain must not disturb the latency lane.
        f.sub_queued(rail, 50_000, 1);
        assert_eq!(f.rail(rail).queued_bytes_class(0), 1_000);
        assert_eq!(f.rail(rail).queued_bytes_class(1), 0);
        f.sub_queued(rail, 1_000, 0);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn sharded_counters_sum_across_engines() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let cfg = FabricConfig {
            counter_shards: 4,
            ..Default::default()
        };
        let f = Fabric::new(&t, cfg);
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let shards: Vec<usize> = (0..4).map(|_| f.register_engine()).collect();
        assert_eq!(shards, vec![0, 1, 2, 3]);
        for &s in &shards {
            f.add_queued_at(s, rail, 100, 1);
        }
        assert_eq!(f.queued_bytes(rail), 400);
        f.sub_queued_at(shards[2], rail, 100, 1);
        assert_eq!(f.queued_bytes_from(shards[1], rail), 300);
        assert!(f.contention.shard_sum_reads.sum() >= 2);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn numa_domain_registration_blocks_shards() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let cfg = FabricConfig {
            counter_shards: 8,
            numa_domains: 2,
            ..Default::default()
        };
        let f = Fabric::new(&t, cfg);
        // Engines that declare their domain get shards from that domain's
        // contiguous block: domain 0 → shards 0..4, domain 1 → shards 4..8.
        let d0: Vec<usize> = (0..2).map(|_| f.register_engine_in_domain(0)).collect();
        let d1: Vec<usize> = (0..2).map(|_| f.register_engine_in_domain(1)).collect();
        assert!(d0.iter().all(|&s| s < 4), "{d0:?}");
        assert!(d1.iter().all(|&s| (4..8).contains(&s)), "{d1:?}");
    }

    #[test]
    fn node_ingress_accounting_per_class() {
        let (t, f) = fabric();
        let node = t.nodes[0];
        assert_eq!(f.ingress_bytes(node), 0);
        f.add_ingress_at(0, node, 4_000, 0);
        f.add_ingress_at(0, node, 60_000, 1);
        assert_eq!(f.ingress_bytes(node), 64_000);
        assert_eq!(f.ingress_bytes_class_from(0, node, 0), 4_000);
        assert_eq!(f.ingress_bytes_class_from(0, node, 1), 60_000);
        f.sub_ingress_at(0, node, 4_000, 0);
        f.sub_ingress_at(0, node, 60_000, 1);
        assert_eq!(f.ingress_bytes(node), 0);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 0);
        // Out-of-range nodes clamp-and-count symmetrically on both the
        // claim and the release path — neither mutates any counter, and
        // neither trips the in-range underflow telemetry. Regression for
        // the staged-path bug where an ignored claim paired with a
        // decrementing release skewed rx_omega pricing.
        f.add_ingress_at(0, NodeId(9_999), 1, 0);
        assert_eq!(f.contention.ingress_oob_clamps.load(Ordering::Relaxed), 1);
        f.sub_ingress_at(0, NodeId(9_999), 1, 0);
        assert_eq!(f.contention.ingress_oob_clamps.load(Ordering::Relaxed), 2);
        assert_eq!(f.ingress_bytes(NodeId(9_999)), 0);
        assert_eq!(f.contention.underflow_clamps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn relay_ledger_tracks_in_and_out() {
        let (_t, f) = fabric();
        let node = NodeId(0);
        assert_eq!(f.relay_bytes(node), (0, 0));
        f.relay_in(node, 1_000);
        f.relay_in(node, 24);
        f.relay_out(node, 1_024);
        assert_eq!(f.relay_bytes(node), (1_024, 1_024));
        // Out-of-range nodes are inert telemetry, never a panic.
        f.relay_in(NodeId(9_999), 7);
        assert_eq!(f.relay_bytes(NodeId(9_999)), (0, 0));
        f.reset_stats();
        assert_eq!(f.relay_bytes(node), (0, 0));
    }

    #[test]
    fn time_compression_speeds_up() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let cfg = FabricConfig {
            time_compression: 10.0,
            ..Default::default()
        };
        let fast = Fabric::new(&t, cfg);
        let slow = Fabric::new(&t, FabricConfig::default());
        let rail = t.rails_of(NodeId(0), FabricKind::Rdma)[0];
        let mut rng = Pcg64::new(2, 0);
        let a = fast.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        let b = slow.service_ns(&t, rail, 1 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        assert!(b > 5 * a);
    }
}
