//! Monotonic time helpers used throughout the datapath.
//!
//! All engine-internal timestamps are `u64` nanoseconds since an arbitrary
//! process-local epoch, so they fit in atomics and subtract cheaply.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since process epoch (monotonic).
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Sleep until the given epoch-relative deadline with a short yield tail.
///
/// The build box may have a single core, so busy-spinning would *delay*
/// other rail workers; the tail uses `yield_now` instead, and the residual
/// OS-timer overshoot is compensated by the fabric's pacing-debt accounting
/// (see `fabric::Fabric::pace`).
pub fn sleep_until_ns(deadline_ns: u64) {
    const YIELD_TAIL_NS: u64 = 60_000; // yield-spin the last 60 µs
    loop {
        let now = now_ns();
        if now >= deadline_ns {
            return;
        }
        let remain = deadline_ns - now;
        if remain > YIELD_TAIL_NS {
            std::thread::sleep(Duration::from_nanos(remain - YIELD_TAIL_NS));
        } else {
            std::thread::yield_now();
        }
    }
}

/// Sleep for `ns` nanoseconds (pacing helper).
#[inline]
pub fn sleep_ns(ns: u64) {
    sleep_until_ns(now_ns() + ns);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn sleep_accuracy() {
        let start = now_ns();
        sleep_ns(2_000_000); // 2 ms
        let took = now_ns() - start;
        assert!(took >= 2_000_000, "took {took}");
        assert!(took < 12_000_000, "took {took}"); // generous upper bound
    }

    #[test]
    fn sleep_until_past_deadline_returns_immediately() {
        let start = now_ns();
        sleep_until_ns(start.saturating_sub(1));
        assert!(now_ns() - start < 1_000_000);
    }
}
