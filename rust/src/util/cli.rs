//! Tiny declarative CLI argument parser (clap is not in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse directly from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| parse_size(v).unwrap_or_else(|| panic!("--{name}: bad number '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get_u64(name, default as u64) as usize
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: bad float '{v}'")))
            .unwrap_or(default)
    }
}

/// Parse a size with optional K/M/G suffix (binary units): "64K" → 65536.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    num.trim().parse::<u64>().ok().map(|n| n * mult)
}

/// Parse a comma-separated list of sizes: "4K,64K,1M".
pub fn parse_size_list(s: &str) -> Vec<u64> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| parse_size(p).unwrap_or_else(|| panic!("bad size '{p}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn flags_and_options() {
        let a = args(&["--verbose", "--threads", "8", "--size=64K", "bench"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_u64("threads", 1), 8);
        assert_eq!(a.get_u64("size", 0), 64 * 1024);
        assert_eq!(a.positional, vec!["bench"]);
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.get_u64("missing", 42), 42);
        assert_eq!(a.get_str("name", "x"), "x");
        assert_eq!(a.get_f64("f", 1.5), 1.5);
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("4k"), Some(4096));
        assert_eq!(parse_size("2M"), Some(2 << 20));
        assert_eq!(parse_size("1G"), Some(1 << 30));
        assert_eq!(parse_size("x"), None);
    }

    #[test]
    fn size_list() {
        assert_eq!(parse_size_list("4K,1M"), vec![4096, 1 << 20]);
    }

    #[test]
    fn trailing_flag_not_eating_positional() {
        // "--verbose bench": "bench" doesn't start with --, so it is consumed
        // as the value of --verbose. Callers must order accordingly; the
        // =value form is unambiguous.
        let a = args(&["bench", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["bench"]);
    }
}
