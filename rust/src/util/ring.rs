//! Lock-free bounded multi-producer / single-consumer ring buffer.
//!
//! This is the §4.4 datapath primitive: submission threads push slice
//! descriptors, a pinned rail worker drains them in batches. The design is a
//! classic Vyukov-style MPSC array queue: producers claim a slot with a
//! single `fetch_add`-free CAS loop on `tail`, publish by storing a sequence
//! number; the consumer reads sequenced slots without any atomics contention
//! with other consumers (there are none).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Aligns a value to 128 bytes so the producer cursor, consumer cursor, and
/// backlog counter land on distinct cache lines (no false sharing between
/// submission threads and the rail worker). Stand-in for crossbeam's
/// `CachePadded`; 128 covers the spatial prefetcher pair on x86 and the
/// 128-byte lines on newer aarch64.
#[repr(align(128))]
pub struct CachePadded<T>(T);

impl<T> CachePadded<T> {
    pub const fn new(t: T) -> Self {
        CachePadded(t)
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

struct Slot<T> {
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// The shared ring state.
pub struct Ring<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    tail: CachePadded<AtomicUsize>, // producers
    head: CachePadded<AtomicUsize>, // consumer
    /// Bytes enqueued minus bytes dequeued — exported so the scheduler can
    /// see backlog *before* it reaches the rail (part of A_d).
    pub backlog_items: CachePadded<AtomicU64>,
}

unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

/// Producer handle (clonable).
pub struct Producer<T> {
    ring: Arc<Ring<T>>,
}

impl<T> Clone for Producer<T> {
    fn clone(&self) -> Self {
        Producer {
            ring: Arc::clone(&self.ring),
        }
    }
}

/// Consumer handle (exactly one per ring).
pub struct Consumer<T> {
    ring: Arc<Ring<T>>,
}

/// Create a ring with capacity rounded up to a power of two.
pub fn ring<T>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let cap = capacity.next_power_of_two().max(2);
    let buf: Box<[Slot<T>]> = (0..cap)
        .map(|i| Slot {
            seq: AtomicUsize::new(i),
            value: UnsafeCell::new(MaybeUninit::uninit()),
        })
        .collect();
    let ring = Arc::new(Ring {
        buf,
        mask: cap - 1,
        tail: CachePadded::new(AtomicUsize::new(0)),
        head: CachePadded::new(AtomicUsize::new(0)),
        backlog_items: CachePadded::new(AtomicU64::new(0)),
    });
    (
        Producer {
            ring: Arc::clone(&ring),
        },
        Consumer { ring },
    )
}

impl<T> Producer<T> {
    /// Push; returns `Err(v)` if the ring is full (caller decides whether to
    /// spin, yield, or apply backpressure — the engine yields).
    pub fn push(&self, v: T) -> Result<(), T> {
        let r = &*self.ring;
        let mut tail = r.tail.load(Ordering::Relaxed);
        loop {
            let slot = &r.buf[tail & r.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == tail {
                match r.tail.compare_exchange_weak(
                    tail,
                    tail.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(v) };
                        slot.seq.store(tail.wrapping_add(1), Ordering::Release);
                        r.backlog_items.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    Err(actual) => tail = actual,
                }
            } else if (seq as isize).wrapping_sub(tail as isize) < 0 {
                return Err(v); // full
            } else {
                tail = r.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Push, yielding the thread while the ring is full.
    pub fn push_blocking(&self, mut v: T) {
        loop {
            match self.push(v) {
                Ok(()) => return,
                Err(back) => {
                    v = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Items currently enqueued (approximate).
    pub fn backlog(&self) -> u64 {
        self.ring.backlog_items.load(Ordering::Relaxed)
    }
}

impl<T> Consumer<T> {
    /// Pop one item, non-blocking.
    pub fn pop(&mut self) -> Option<T> {
        let r = &*self.ring;
        let head = r.head.load(Ordering::Relaxed);
        let slot = &r.buf[head & r.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq == head.wrapping_add(1) {
            let v = unsafe { (*slot.value.get()).assume_init_read() };
            slot.seq
                .store(head.wrapping_add(r.mask + 1), Ordering::Release);
            r.head.store(head.wrapping_add(1), Ordering::Relaxed);
            r.backlog_items.fetch_sub(1, Ordering::Relaxed);
            Some(v)
        } else {
            None
        }
    }

    /// Drain up to `max` items into `out` (batched dequeue, §4.4).
    pub fn pop_batch(&mut self, out: &mut Vec<T>, max: usize) -> usize {
        let mut n = 0;
        while n < max {
            match self.pop() {
                Some(v) => {
                    out.push(v);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Items currently enqueued (approximate).
    pub fn backlog(&self) -> u64 {
        self.ring.backlog_items.load(Ordering::Relaxed)
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // Drop any undelivered items.
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Relaxed);
        let mut i = head;
        while i != tail {
            let slot = &self.buf[i & self.mask];
            if slot.seq.load(Ordering::Relaxed) == i.wrapping_add(1) {
                unsafe { (*slot.value.get()).assume_init_drop() };
            }
            i = i.wrapping_add(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread;

    #[test]
    fn fifo_single_thread() {
        let (p, mut c) = ring::<u32>(8);
        for i in 0..8 {
            p.push(i).unwrap();
        }
        assert!(p.push(99).is_err(), "ring should be full");
        for i in 0..8 {
            assert_eq!(c.pop(), Some(i));
        }
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn backlog_tracks() {
        let (p, mut c) = ring::<u32>(16);
        assert_eq!(p.backlog(), 0);
        p.push(1).unwrap();
        p.push(2).unwrap();
        assert_eq!(p.backlog(), 2);
        c.pop();
        assert_eq!(c.backlog(), 1);
    }

    #[test]
    fn wraparound() {
        let (p, mut c) = ring::<u64>(4);
        for round in 0..100u64 {
            p.push(round).unwrap();
            assert_eq!(c.pop(), Some(round));
        }
    }

    #[test]
    fn batch_pop() {
        let (p, mut c) = ring::<u32>(32);
        for i in 0..20 {
            p.push(i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(c.pop_batch(&mut out, 16), 16);
        assert_eq!(c.pop_batch(&mut out, 16), 4);
        assert_eq!(out, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn mpsc_all_items_delivered_once() {
        let (p, mut c) = ring::<u64>(1024);
        const PRODUCERS: u64 = 8;
        const PER: u64 = 10_000;
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|t| {
                let p = p.clone();
                thread::spawn(move || {
                    for i in 0..PER {
                        p.push_blocking(t * PER + i);
                    }
                })
            })
            .collect();
        let mut seen = HashSet::new();
        while seen.len() < (PRODUCERS * PER) as usize {
            if let Some(v) = c.pop() {
                assert!(seen.insert(v), "duplicate {v}");
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.pop(), None);
        assert_eq!(seen.len(), (PRODUCERS * PER) as usize);
    }

    #[test]
    fn drops_undelivered_items() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        {
            let (p, mut c) = ring::<D>(8);
            p.push(D).ok();
            p.push(D).ok();
            p.push(D).ok();
            drop(c.pop()); // one delivered + dropped
        }
        assert_eq!(DROPS.load(Ordering::SeqCst), 3);
    }
}
