//! Canonical-form serialization helpers shared by every replay contract.
//!
//! Two subsystems identify runs by a digest over a canonical JSON form:
//! the chaos harness (`ChaosSchedule::digest`, `ChaosReport::
//! replay_signature`) and the plan journal (`plan::Journal::digest`). Both
//! previously hand-rolled the same FNV-1a loop; this module is the single
//! implementation, regression-pinned so existing chaos signatures can
//! never drift.
//!
//! Canonical form means: [`crate::util::json::Json`] with `Obj` backed by a
//! `BTreeMap` (sorted keys), deterministic number formatting (integers
//! print without a fraction), and full-width `u64` values carried as
//! strings — so equal values serialize byte-equal and the digest is a pure
//! function of the data.

use crate::util::json::Json;

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit digest of a byte slice.
pub fn fnv1a64_bytes(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64-bit digest of a string's UTF-8 bytes.
pub fn fnv1a64(s: &str) -> u64 {
    fnv1a64_bytes(s.as_bytes())
}

/// The zero-padded hex form every replay contract prints (`{:016x}`).
pub fn digest_hex(digest: u64) -> String {
    format!("{digest:016x}")
}

/// Serialize a [`Json`] value in canonical form and digest it in one step.
pub fn digest_json(j: &Json) -> u64 {
    fnv1a64(&j.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known FNV-1a 64-bit vectors. These pins are the regression contract:
    // if they move, every committed chaos schedule digest and journal
    // digest silently changes meaning.
    #[test]
    fn fnv_vectors_are_pinned() {
        assert_eq!(fnv1a64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64("foobar"), 0x8594_4171_f739_67e8);
        assert_eq!(fnv1a64_bytes(b"foobar"), fnv1a64("foobar"));
    }

    #[test]
    fn hex_form_is_zero_padded() {
        assert_eq!(digest_hex(0x1a2b), "0000000000001a2b");
        assert_eq!(digest_hex(u64::MAX), "ffffffffffffffff");
    }

    #[test]
    fn digest_json_matches_manual_loop() {
        let j = Json::obj(vec![
            ("b", Json::num(2.0)),
            ("a", Json::str("x")),
        ]);
        // BTreeMap ordering: "a" before "b" regardless of insertion order.
        let s = j.to_string();
        assert_eq!(s, r#"{"a":"x","b":2}"#);
        let mut h: u64 = FNV_OFFSET;
        for b in s.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        assert_eq!(digest_json(&j), h);
    }
}
