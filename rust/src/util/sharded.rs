//! Striped atomic counters for write-hot shared statistics.
//!
//! At fleet scale (§2.3 "thousands of GPUs"), every engine maintaining the
//! per-rail queued-bytes statistic `A_d` through one `AtomicU64` turns that
//! counter's cache line into a coherence hot spot: 64 engines bounce the
//! line on every `add_queued`/`sub_queued`, twice per slice. A
//! [`ShardedU64`] stripes the value over cache-padded shards — each engine
//! writes only its own shard (uncontended RMW) and readers sum all shards.
//! Reads are O(shards) and slightly stale, which is exactly the tolerance
//! the cost model already has for queue statistics.

use crate::util::ring::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// A `u64` counter striped over cache-padded shards.
///
/// Writers pick a shard (engines use their fabric-assigned shard id, see
/// `Fabric::register_engine`); `sum()` folds all shards. With one shard this
/// degenerates to a plain atomic — the single-counter baseline the
/// `fig_scaling` bench ablates against.
pub struct ShardedU64 {
    shards: Box<[CachePadded<AtomicU64>]>,
    mask: usize,
}

impl ShardedU64 {
    /// Create with `shards` stripes (rounded up to a power of two, min 1).
    pub fn new(shards: usize) -> ShardedU64 {
        let n = shards.next_power_of_two().max(1);
        ShardedU64 {
            shards: (0..n).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            mask: n - 1,
        }
    }

    #[inline]
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Map an arbitrary writer id onto a shard index.
    #[inline]
    pub fn shard_of(&self, writer: usize) -> usize {
        writer & self.mask
    }

    /// NUMA-style shard mapping: partition the stripe space into `domains`
    /// contiguous blocks and keep a writer's shard inside its domain's
    /// block. On multi-socket hosts this keeps an engine's counter stripe
    /// on the cache lines its own socket already owns, instead of letting
    /// `writer & mask` interleave sockets across the whole array. With
    /// `domains <= 1` this is exactly [`ShardedU64::shard_of`].
    #[inline]
    pub fn shard_of_domain(&self, writer: usize, domain: usize, domains: usize) -> usize {
        let n = self.shards.len();
        if domains <= 1 || domains > n {
            return self.shard_of(writer);
        }
        // `n` is a power of two; use the largest power-of-two domain count
        // that fits so block boundaries stay aligned and the math stays
        // mask-based (no division on the hot path).
        let doms = prev_power_of_two(domains.min(n));
        let block = n / doms;
        (domain % doms) * block + (writer % block)
    }

    #[inline]
    pub fn add(&self, shard: usize, v: u64) {
        self.shards[shard & self.mask].fetch_add(v, Ordering::Relaxed);
    }

    /// Saturating subtract on one shard. Returns `true` if the shard held
    /// fewer than `v` and the subtraction clamped to zero — for a
    /// well-behaved writer (never subtracting more than it added to its own
    /// shard) that is an accounting bug, so callers surface it.
    #[inline]
    #[must_use]
    pub fn sub_saturating(&self, shard: usize, v: u64) -> bool {
        let mut clamped = false;
        let _ = self.shards[shard & self.mask].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |cur| {
                clamped = cur < v;
                Some(cur.saturating_sub(v))
            },
        );
        clamped
    }

    /// Fold all shards. O(shard_count); tolerably stale under concurrency
    /// (each shard load is atomic, the sum is not a snapshot).
    #[inline]
    pub fn sum(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .sum()
    }

    /// Reset every shard to zero (bench phase boundaries only — racing
    /// writers may survive the reset).
    pub fn reset(&self) {
        for s in self.shards.iter() {
            s.store(0, Ordering::Relaxed);
        }
    }
}

/// Largest power of two `<= v` (`v >= 1`).
#[inline]
fn prev_power_of_two(v: usize) -> usize {
    debug_assert!(v >= 1);
    1 << (usize::BITS - 1 - v.leading_zeros())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_degenerates_to_plain_counter() {
        let c = ShardedU64::new(1);
        assert_eq!(c.shard_count(), 1);
        c.add(0, 100);
        c.add(7, 20); // any writer id maps onto shard 0
        assert_eq!(c.sum(), 120);
        assert!(!c.sub_saturating(3, 120));
        assert_eq!(c.sum(), 0);
    }

    #[test]
    fn shards_round_up_to_power_of_two() {
        assert_eq!(ShardedU64::new(0).shard_count(), 1);
        assert_eq!(ShardedU64::new(3).shard_count(), 4);
        assert_eq!(ShardedU64::new(8).shard_count(), 8);
    }

    #[test]
    fn sum_folds_all_shards() {
        let c = ShardedU64::new(4);
        for w in 0..8 {
            c.add(w, 10);
        }
        assert_eq!(c.sum(), 80);
        assert!(!c.sub_saturating(0, 20)); // shard 0 got writers 0 and 4
        assert_eq!(c.sum(), 60);
    }

    #[test]
    fn sub_clamps_and_reports_per_shard() {
        let c = ShardedU64::new(2);
        c.add(0, 50);
        c.add(1, 50);
        // Shard 1 only holds 50 even though the total is 100.
        assert!(c.sub_saturating(1, 80));
        assert_eq!(c.sum(), 50);
        assert!(!c.sub_saturating(0, 50));
        assert!(c.sub_saturating(0, 1));
        assert_eq!(c.sum(), 0);
    }

    #[test]
    fn concurrent_balanced_writers_return_to_zero() {
        let c = std::sync::Arc::new(ShardedU64::new(8));
        let handles: Vec<_> = (0..8usize)
            .map(|w| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.add(w, 3);
                        assert!(!c.sub_saturating(w, 3));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.sum(), 0);
    }

    #[test]
    fn domain_mapping_stays_inside_domain_block() {
        let c = ShardedU64::new(8);
        // 2 domains over 8 shards: domain 0 owns shards 0..4, domain 1 owns
        // shards 4..8, regardless of writer id.
        for w in 0..32 {
            let s0 = c.shard_of_domain(w, 0, 2);
            let s1 = c.shard_of_domain(w, 1, 2);
            assert!(s0 < 4, "writer {w} escaped domain 0: shard {s0}");
            assert!((4..8).contains(&s1), "writer {w} escaped domain 1: shard {s1}");
        }
        // Writers still spread across the block, not onto one shard.
        let spread: std::collections::BTreeSet<usize> =
            (0..4).map(|w| c.shard_of_domain(w, 0, 2)).collect();
        assert_eq!(spread.len(), 4);
    }

    #[test]
    fn domain_mapping_degenerates_without_domains() {
        let c = ShardedU64::new(4);
        for w in 0..16 {
            assert_eq!(c.shard_of_domain(w, 0, 1), c.shard_of(w));
            assert_eq!(c.shard_of_domain(w, 3, 0), c.shard_of(w));
            // More domains than shards: fall back to plain interleave.
            assert_eq!(c.shard_of_domain(w, 2, 8), c.shard_of(w));
        }
    }

    #[test]
    fn domain_count_rounds_down_to_power_of_two() {
        let c = ShardedU64::new(8);
        // 3 domains rounds down to 2 blocks of 4.
        for w in 0..8 {
            assert!(c.shard_of_domain(w, 0, 3) < 4);
            assert!((4..8).contains(&c.shard_of_domain(w, 1, 3)));
            // Domain index wraps modulo the effective domain count.
            assert_eq!(c.shard_of_domain(w, 2, 3), c.shard_of_domain(w, 0, 3));
        }
        assert_eq!(prev_power_of_two(1), 1);
        assert_eq!(prev_power_of_two(2), 2);
        assert_eq!(prev_power_of_two(3), 2);
        assert_eq!(prev_power_of_two(8), 8);
    }

    #[test]
    fn reset_zeroes() {
        let c = ShardedU64::new(4);
        c.add(1, 5);
        c.add(2, 6);
        c.reset();
        assert_eq!(c.sum(), 0);
    }
}
