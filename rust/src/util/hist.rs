//! Log-bucketed latency histogram with percentile queries.
//!
//! HdrHistogram-style: values are bucketed with bounded relative error
//! (~1/32 per octave sub-bucket), so P50/P90/P99 queries over millions of
//! slice latencies cost O(buckets) and recording is a single atomic add —
//! safe to share between rail workers.

use std::sync::atomic::{AtomicU64, Ordering};

const SUB_BITS: u32 = 5; // 32 sub-buckets per octave → ≤ ~3% relative error
const SUB: usize = 1 << SUB_BITS;
const OCTAVES: usize = 50; // covers 1 ns .. ~35 years
const NBUCKETS: usize = OCTAVES * SUB;

#[inline]
fn bucket_of(v: u64) -> usize {
    let v = v.max(1);
    let msb = 63 - v.leading_zeros() as usize;
    if msb < SUB_BITS as usize {
        return v as usize;
    }
    let octave = msb - SUB_BITS as usize + 1;
    let sub = (v >> (octave - 1)) as usize - SUB;
    (octave * SUB + sub).min(NBUCKETS - 1)
}

#[inline]
fn bucket_upper(idx: usize) -> u64 {
    if idx < SUB {
        return idx as u64;
    }
    let octave = idx / SUB;
    let sub = idx % SUB;
    ((SUB + sub + 1) as u64) << (octave - 1)
}

/// Concurrent histogram; record from any thread, snapshot for queries.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    /// Record one value (e.g. slice latency in ns).
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
    }

    /// Record a batch of values with one atomic RMW per *touched bucket*
    /// plus four for the aggregates, instead of five per value. The final
    /// histogram contents are identical to calling [`Histogram::record`]
    /// per value — this is purely a completion-path contention optimisation
    /// (see `engine/datapath.rs` batched feedback).
    pub fn record_batch(&self, values: &[u64]) {
        if values.is_empty() {
            return;
        }
        // Batches come from one drain pass (≤ ~128 slices), so a tiny
        // linear-probe accumulator beats hashing and allocates at most one
        // small Vec.
        let mut touched: Vec<(usize, u64)> = Vec::with_capacity(values.len().min(16));
        let mut sum = 0u64;
        let mut max = 0u64;
        let mut min = u64::MAX;
        for &v in values {
            sum += v;
            max = max.max(v);
            min = min.min(v);
            let b = bucket_of(v);
            match touched.iter_mut().find(|(idx, _)| *idx == b) {
                Some((_, n)) => *n += 1,
                None => touched.push((b, 1)),
            }
        }
        for (idx, n) in touched {
            self.buckets[idx].fetch_add(n, Ordering::Relaxed);
        }
        self.count.fetch_add(values.len() as u64, Ordering::Relaxed);
        self.sum.fetch_add(sum, Ordering::Relaxed);
        self.max.fetch_max(max, Ordering::Relaxed);
        self.min.fetch_min(min, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    /// Value at quantile `q` ∈ [0,1] (upper bucket bound; ≤ ~3% high).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return bucket_upper(i).min(self.max());
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Zero all state.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
    }

    /// Merge another histogram into this one.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min
            .fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn single_value() {
        let h = Histogram::new();
        h.record(1_000_000);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 1_000_000);
        let p = h.p50();
        assert!(p >= 1_000_000 && p as f64 <= 1_000_000.0 * 1.04, "p={p}");
    }

    #[test]
    fn quantiles_bounded_relative_error() {
        let h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, expect) in [(0.5, 50_000.0), (0.9, 90_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - expect).abs() / expect < 0.05,
                "q={q} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn small_values_exact() {
        let h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
    }

    #[test]
    fn merge_combines() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in 0..1000 {
            a.record(v);
            b.record(v + 10_000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        assert!(a.quantile(0.75) > 9_000);
    }

    #[test]
    fn random_values_mean_matches() {
        let h = Histogram::new();
        let mut r = Pcg64::new(7, 0);
        let mut sum = 0u64;
        for _ in 0..100_000 {
            let v = r.gen_range(1 << 30);
            h.record(v);
            sum += v;
        }
        let expect = sum as f64 / 100_000.0;
        assert!((h.mean() - expect).abs() < 1.0);
    }

    #[test]
    fn reset_zeroes() {
        let h = Histogram::new();
        h.record(5);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0);
    }

    #[test]
    fn record_batch_matches_per_value_record() {
        let batched = Histogram::new();
        let scalar = Histogram::new();
        let mut r = Pcg64::new(42, 1);
        let mut batch = Vec::new();
        for _ in 0..5_000 {
            let v = r.gen_range(1 << 34);
            scalar.record(v);
            batch.push(v);
            if batch.len() == 64 {
                batched.record_batch(&batch);
                batch.clear();
            }
        }
        batched.record_batch(&batch);
        assert_eq!(batched.count(), scalar.count());
        assert_eq!(batched.max(), scalar.max());
        assert_eq!(batched.min(), scalar.min());
        assert_eq!(batched.mean(), scalar.mean());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(batched.quantile(q), scalar.quantile(q), "q={q}");
        }
    }

    #[test]
    fn record_batch_empty_is_noop() {
        let h = Histogram::new();
        h.record_batch(&[]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn bucket_monotonicity() {
        let mut last = 0;
        for v in (0..10_000_000u64).step_by(997) {
            let b = bucket_of(v);
            assert!(b >= last || bucket_upper(b) >= v, "v={v}");
            last = last.max(b);
        }
    }
}
