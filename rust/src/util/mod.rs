//! Dependency-free building blocks.
//!
//! The build environment has no network access to crates.io, so the usual
//! suspects (tokio, clap, serde, criterion, proptest, rand) are unavailable.
//! Everything in this module is hand-rolled — which happens to be faithful to
//! the paper's own datapath (§4.4): pinned workers draining lock-free MPSC
//! rings, hierarchical atomic completion counters, no async runtime.

pub mod cli;
pub mod clock;
pub mod ewma;
pub mod hist;
pub mod json;
pub mod logging;
pub mod prng;
pub mod ring;

/// Format a byte count human-readably (e.g. `64.0 KiB`).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format a bandwidth in bytes/sec as MB/s (sim units).
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    format!("{:.1} MB/s", bytes_per_sec / 1e6)
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{} ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.1} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(64 * 1024), "64.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(20), "20 ns");
        assert_eq!(fmt_ns(1_500), "1.5 us");
        assert_eq!(fmt_ns(2_000_000), "2.00 ms");
        assert_eq!(fmt_ns(1_500_000_000), "1.500 s");
    }
}
