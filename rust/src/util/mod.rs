//! Dependency-free building blocks.
//!
//! The build environment has no network access to crates.io, so the usual
//! suspects (tokio, clap, serde, criterion, proptest, rand) are unavailable.
//! Everything in this module is hand-rolled — which happens to be faithful to
//! the paper's own datapath (§4.4): pinned workers draining lock-free MPSC
//! rings, hierarchical atomic completion counters, no async runtime.

pub mod canon;
pub mod cli;
pub mod clock;
pub mod ewma;
pub mod hist;
pub mod json;
pub mod logging;
pub mod prng;
pub mod ring;
pub mod sharded;

/// RAII guard for a disk-pool backing file in `$TMPDIR`.
///
/// Serving tests and benches used to `remove_file` their KV disk pools at
/// the end of the test body — which never runs when an assertion fails, so
/// failed runs leaked multi-hundred-MiB pool files into `/tmp`. `TempPool`
/// removes the file on `Drop`, which runs during unwind too. Paths are
/// unique per (pid, tag, sequence), so parallel tests in one binary never
/// collide.
pub struct TempPool {
    path: std::path::PathBuf,
}

impl TempPool {
    /// Reserve a fresh pool path (the file itself is created by whoever
    /// registers the file segment).
    pub fn new(tag: &str) -> TempPool {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        TempPool {
            path: std::env::temp_dir()
                .join(format!("tent_{tag}_{}_{n}.pool", std::process::id())),
        }
    }

    pub fn path(&self) -> std::path::PathBuf {
        self.path.clone()
    }
}

impl Drop for TempPool {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Format a byte count human-readably (e.g. `64.0 KiB`).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format a bandwidth in bytes/sec as MB/s (sim units).
pub fn fmt_bw(bytes_per_sec: f64) -> String {
    format!("{:.1} MB/s", bytes_per_sec / 1e6)
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{} ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.1} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temp_pool_removes_file_on_drop() {
        let path = {
            let pool = TempPool::new("utest");
            std::fs::write(pool.path(), b"x").unwrap();
            assert!(pool.path().exists());
            pool.path()
        };
        assert!(!path.exists());
    }

    #[test]
    fn temp_pool_paths_are_unique() {
        let a = TempPool::new("utest");
        let b = TempPool::new("utest");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(64 * 1024), "64.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(20), "20 ns");
        assert_eq!(fmt_ns(1_500), "1.5 us");
        assert_eq!(fmt_ns(2_000_000), "2.00 ms");
        assert_eq!(fmt_ns(1_500_000_000), "1.500 s");
    }
}
