//! Small, fast, deterministic PRNG + the distributions the workload
//! generators need (uniform, exponential inter-arrivals, Zipf popularity,
//! normal jitter).
//!
//! `Pcg64` here is the PCG-XSH-RR variant on a 128-bit LCG — statistically
//! solid for simulation workloads and reproducible across platforms.

/// SplitMix64 — used to seed / derive streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic PCG-style PRNG.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create from a 64-bit seed; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        const MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsh = (((self.state >> 64) ^ self.state) >> 27) as u64;
        xsh.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn gen_between(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.gen_range(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed value with mean `mean` (inter-arrival gaps).
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0,1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine here).
    pub fn gen_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mu + sigma * z
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Zipf-distributed sampler over `{0, .., n-1}` with exponent `s`
/// (models skewed prefix popularity in KV cache workloads).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the CDF once; O(n).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample an index in `[0, n)`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(1, 0);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg64::new(3, 0);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg64::new(9, 0);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gen_exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Pcg64::new(11, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
        assert!((var - 9.0).abs() < 0.5, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Pcg64::new(5, 0);
        let z = Zipf::new(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(8, 0);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
