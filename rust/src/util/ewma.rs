//! EWMA filter and the per-rail linear completion-time model of Algorithm 1.
//!
//! The paper models the expected completion time of a slice of length `L` on
//! device `d` as
//!
//! ```text
//!   t̂_d = β0_d + β1_d · (A_d + L) / B_d          (Eq. 1)
//! ```
//!
//! where `A_d` is the queued bytes on the rail, `B_d` its nominal bandwidth,
//! and (β0, β1) are *dynamic correction factors* updated from the observed
//! prediction error via an exponential weighted moving average. A periodic
//! state reset re-admits previously degraded paths (§4.2 "Feedback").

use std::sync::atomic::{AtomicU64, Ordering};

/// Plain EWMA over f64 values.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` ∈ (0,1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    /// Fold in an observation, returning the new smoothed value.
    pub fn observe(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    /// Current smoothed value (None until first observation).
    pub fn get(&self) -> Option<f64> {
        self.value
    }

    /// Forget all history (periodic reset, §4.2).
    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Atomic f64 (bit-cast through u64) so the cost model can be shared between
/// submission threads (prediction) and rail workers (feedback) without locks.
#[derive(Debug)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn new(v: f64) -> Self {
        AtomicF64(AtomicU64::new(v.to_bits()))
    }
    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
    #[inline]
    pub fn store(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed)
    }
    /// Lock-free read-modify-write.
    pub fn update<F: Fn(f64) -> f64>(&self, f: F) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = f(f64::from_bits(cur)).to_bits();
            match self
                .0
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return f64::from_bits(next),
                Err(actual) => cur = actual,
            }
        }
    }
}

/// The per-rail linear completion-time model (Eq. 1), shared across threads.
///
/// β0 is in **nanoseconds** (fixed per-slice cost: posting, doorbell, base
/// propagation); β1 is dimensionless (corrects the bandwidth term for incast,
/// switch congestion, pacing error). Both adapt online.
#[derive(Debug)]
pub struct LinearCostModel {
    beta0_ns: AtomicF64,
    beta1: AtomicF64,
    alpha: f64,
    init_beta0_ns: f64,
    init_beta1: f64,
}

impl LinearCostModel {
    pub fn new(init_beta0_ns: f64, init_beta1: f64, alpha: f64) -> Self {
        LinearCostModel {
            beta0_ns: AtomicF64::new(init_beta0_ns),
            beta1: AtomicF64::new(init_beta1),
            alpha,
            init_beta0_ns,
            init_beta1,
        }
    }

    /// Predict completion time (ns) for a slice of `len` bytes given
    /// `queued` bytes already in flight and nominal bandwidth `bw` (B/s).
    #[inline]
    pub fn predict_ns(&self, len: u64, queued: u64, bw_bytes_per_sec: f64) -> f64 {
        let serial_ns = (queued + len) as f64 / bw_bytes_per_sec.max(1.0) * 1e9;
        self.beta0_ns.load() + self.beta1.load() * serial_ns
    }

    /// Maximum fixed-cost estimate (ns). β0 models per-slice posting /
    /// propagation costs (tens of µs); letting it absorb queueing noise
    /// destabilizes scores at deep queues (a β0 spread larger than γ·s_min
    /// collapses the tolerance window onto one rail and causes bursts).
    const BETA0_CAP_NS: f64 = 250_000.0;

    /// Feedback (§4.2): decompose the observed completion time into a slope
    /// against the serial term (→ β1: bandwidth mis-estimate, congestion,
    /// incast) and a bounded fixed residual (→ β0: posting/propagation).
    /// Both move by EWMA and are clamped so a single outlier cannot wedge
    /// the model.
    pub fn observe_ns(&self, _predicted_ns: f64, observed_ns: f64, serial_ns: f64) {
        let alpha = self.alpha;
        let mut b1_now = self.beta1.load();
        if serial_ns > 1.0 {
            let target_b1 = ((observed_ns - self.beta0_ns.load()) / serial_ns).clamp(0.05, 100.0);
            b1_now = self
                .beta1
                .update(|b1| (b1 + alpha * (target_b1 - b1)).clamp(0.05, 100.0));
        }
        // Fixed residual after the learned slope explains the serial part.
        let resid = (observed_ns - b1_now * serial_ns).clamp(0.0, Self::BETA0_CAP_NS);
        self.beta0_ns
            .update(|b0| (b0 + alpha * (resid - b0)).clamp(0.0, Self::BETA0_CAP_NS));
    }

    /// Batched feedback: fold `n` completions with mean observed/serial
    /// times into the model in one pass. Uses the effective smoothing
    /// factor `α_eff = 1 − (1−α)^n`, which is exactly the total weight `n`
    /// successive per-slice EWMA steps would have given to new data — so a
    /// batch of n identical observations lands the model in the same place
    /// as n scalar [`LinearCostModel::observe_ns`] calls, at 1/n the atomic
    /// traffic (see the batched completion path in `engine/datapath.rs`).
    pub fn observe_batch_ns(&self, n: u64, mean_observed_ns: f64, mean_serial_ns: f64) {
        if n == 0 {
            return;
        }
        let alpha = 1.0 - (1.0 - self.alpha).powi(n.min(i32::MAX as u64) as i32);
        let mut b1_now = self.beta1.load();
        if mean_serial_ns > 1.0 {
            let target_b1 =
                ((mean_observed_ns - self.beta0_ns.load()) / mean_serial_ns).clamp(0.05, 100.0);
            b1_now = self
                .beta1
                .update(|b1| (b1 + alpha * (target_b1 - b1)).clamp(0.05, 100.0));
        }
        let resid = (mean_observed_ns - b1_now * mean_serial_ns).clamp(0.0, Self::BETA0_CAP_NS);
        self.beta0_ns
            .update(|b0| (b0 + alpha * (resid - b0)).clamp(0.0, Self::BETA0_CAP_NS));
    }

    /// Periodic state reset (§4.2): forget learned penalties so degraded
    /// paths are re-probed once they recover.
    pub fn reset(&self) {
        self.beta0_ns.store(self.init_beta0_ns);
        self.beta1.store(self.init_beta1);
    }

    pub fn beta0_ns(&self) -> f64 {
        self.beta0_ns.load()
    }
    pub fn beta1(&self) -> f64 {
        self.beta1.load()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.3);
        for _ in 0..100 {
            e.observe(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_tracks_step_change() {
        let mut e = Ewma::new(0.5);
        for _ in 0..20 {
            e.observe(1.0);
        }
        for _ in 0..20 {
            e.observe(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 0.01);
    }

    #[test]
    fn ewma_reset_forgets() {
        let mut e = Ewma::new(0.5);
        e.observe(42.0);
        e.reset();
        assert!(e.get().is_none());
    }

    #[test]
    fn atomic_f64_roundtrip_and_update() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(-2.25);
        assert_eq!(a.load(), -2.25);
        a.update(|v| v * 2.0);
        assert_eq!(a.load(), -4.5);
    }

    #[test]
    fn cost_model_predicts_linear_in_queue() {
        let m = LinearCostModel::new(10_000.0, 1.0, 0.2);
        let bw = 250e6; // 250 MB/s
        let t_empty = m.predict_ns(65_536, 0, bw);
        let t_loaded = m.predict_ns(65_536, 10 * 65_536, bw);
        assert!(t_loaded > t_empty);
        // 64 KiB at 250 MB/s ≈ 262 µs serial + 10 µs fixed.
        assert!((t_empty - (10_000.0 + 65_536.0 / 250e6 * 1e9)).abs() < 1.0);
    }

    #[test]
    fn cost_model_learns_degraded_link() {
        let m = LinearCostModel::new(0.0, 1.0, 0.3);
        let bw = 250e6;
        let len = 1 << 20;
        // Link actually runs at 1/4 the nominal bandwidth: observed = 4x predicted.
        for _ in 0..50 {
            let serial = len as f64 / bw * 1e9;
            let pred = m.predict_ns(len as u64, 0, bw);
            m.observe_ns(pred, 4.0 * serial, serial);
        }
        assert!(m.beta1() > 3.0, "beta1={}", m.beta1());
        // After learning, predictions on this link are ~4x those of a healthy one.
        let healthy = LinearCostModel::new(0.0, 1.0, 0.3);
        assert!(m.predict_ns(len, 0, bw) > 3.0 * healthy.predict_ns(len, 0, bw));
    }

    #[test]
    fn batch_of_one_matches_scalar_observe() {
        let a = LinearCostModel::new(10_000.0, 1.0, 0.1);
        let b = LinearCostModel::new(10_000.0, 1.0, 0.1);
        a.observe_ns(0.0, 500_000.0, 100_000.0);
        b.observe_batch_ns(1, 500_000.0, 100_000.0);
        assert!((a.beta1() - b.beta1()).abs() < 1e-12);
        assert!((a.beta0_ns() - b.beta0_ns()).abs() < 1e-9);
    }

    #[test]
    fn batch_of_identical_observations_matches_scalar_sequence() {
        let scalar = LinearCostModel::new(20_000.0, 1.0, 0.1);
        let batched = LinearCostModel::new(20_000.0, 1.0, 0.1);
        let (observed, serial) = (800_000.0, 200_000.0);
        for _ in 0..16 {
            scalar.observe_ns(0.0, observed, serial);
        }
        batched.observe_batch_ns(16, observed, serial);
        // α_eff gives the batch the same total new-data weight; the scalar
        // path re-reads β0 each step so the two differ only by the (small)
        // β0/β1 cross-coupling within the sequence.
        assert!(
            (scalar.beta1() - batched.beta1()).abs() / scalar.beta1() < 0.05,
            "scalar={} batched={}",
            scalar.beta1(),
            batched.beta1()
        );
        assert!(
            (scalar.beta0_ns() - batched.beta0_ns()).abs() < 0.1 * LinearCostModel::BETA0_CAP_NS,
            "scalar={} batched={}",
            scalar.beta0_ns(),
            batched.beta0_ns()
        );
    }

    #[test]
    fn batch_zero_is_noop() {
        let m = LinearCostModel::new(5_000.0, 1.0, 0.2);
        m.observe_batch_ns(0, 1e9, 1e6);
        assert_eq!(m.beta0_ns(), 5_000.0);
        assert_eq!(m.beta1(), 1.0);
    }

    #[test]
    fn cost_model_reset_restores_initial() {
        let m = LinearCostModel::new(5.0, 1.0, 0.5);
        m.observe_ns(100.0, 10_000.0, 50.0);
        assert!(m.beta1() != 1.0 || m.beta0_ns() != 5.0);
        m.reset();
        assert_eq!(m.beta0_ns(), 5.0);
        assert_eq!(m.beta1(), 1.0);
    }
}
