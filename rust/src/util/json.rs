//! Minimal JSON parser/serializer (no serde in the offline vendor set).
//!
//! Used for cluster topology profiles, bench result dumps, and the CLI's
//! `--dump-json` outputs. Supports the full JSON grammar minus exotic escape
//! edge cases we don't emit (surrogate pairs are decoded best-effort).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Convenience constructors for building result dumps.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {:?}", other)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {:?}", other)),
            }
        }
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write(self, &mut s);
        f.write_str(&s)
    }
}

fn write(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => esc(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null],"s":"he\"llo","nested":{"x":-1}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::Obj(Default::default()));
    }
}
