//! Stderr logging backend with relative timestamps.
//!
//! The macro facade lives in [`crate::log`] (the offline stand-in for the
//! `log` crate); this module keeps the `util::logging::init` entry point the
//! binaries and examples call.

pub use crate::log::Level;

/// Install the logger. `TENT_LOG` env var overrides the level:
/// error|warn|info|debug|trace. Idempotent; the last call wins.
pub fn init(default_level: Level) {
    let level = std::env::var("TENT_LOG")
        .ok()
        .and_then(|s| s.parse::<Level>().ok())
        .unwrap_or(default_level);
    crate::log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::log;

    #[test]
    fn init_is_idempotent() {
        init(Level::Warn);
        init(Level::Info); // second call must not panic
        log::warn!("logging smoke test");
    }
}
