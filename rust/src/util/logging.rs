//! Minimal `log` facade backend writing to stderr with relative timestamps.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }
    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = crate::util::clock::now_ns() as f64 / 1e9;
            eprintln!(
                "[{t:10.4}s {:5} {}] {}",
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }
    fn flush(&self) {}
}

/// Install the logger once. `TENT_LOG` env var overrides: error|warn|info|debug|trace.
pub fn init(default_level: Level) {
    let level = std::env::var("TENT_LOG")
        .ok()
        .and_then(|s| s.parse::<Level>().ok())
        .unwrap_or(default_level);
    let _ = log::set_boxed_logger(Box::new(StderrLogger { level }));
    log::set_max_level(LevelFilter::from(level.to_level_filter()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init(Level::Warn);
        init(Level::Info); // second call must not panic
        log::warn!("logging smoke test");
    }
}
