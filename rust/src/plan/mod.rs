//! Declarative transfer-plan DSL with a deterministic replay journal
//! (`docs/DSL.md` is the spec; `plans/*.tent` are the shipped examples).
//!
//! A plan declares *what* should move — HiCache fetch storms, checkpoint
//! broadcasts, RL parameter-update rounds, mixed-QoS floods, staged
//! point-to-point streams with `route` relay constraints, optionally
//! with an embedded chaos schedule — and the engine decides how. The
//! pipeline is `parse → resolve/typecheck → compile → PlanDag`:
//!
//! * [`parser`] — the line-oriented `.tent` form and its equivalent
//!   canonical-JSON form, with span-carrying errors and byte-identical
//!   round trips ([`PlanSpec::to_json`] / [`PlanSpec::from_json`]).
//! * [`compile`] — name resolution, per-kind field validation, DAG
//!   lowering into waves of stages whose every op (peer choices included)
//!   is drawn from PRNG streams seeded by `(plan seed, stage name)` at
//!   compile time.
//! * [`exec`] — `Fleet::run_plan`: wave-parallel execution with the
//!   `run_workload` submission idiom, chaos replayed on its own thread.
//! * [`journal`] — the append-only execution record: canonical-JSON
//!   events + FNV digest (the `ChaosReport::replay_signature` contract),
//!   so any run replays byte-identically from `(plan file, seed)`.
//!
//! ```
//! use tent::plan::{compile, PlanSpec};
//!
//! let spec = PlanSpec::parse(
//!     "plan demo\nnodes 2\nseed 3\n\
//!      workload fetch {\n kind hicache_fetch\n ops 4\n}\n\
//!      workload push {\n kind broadcast\n payload 1M\n after fetch\n}\n",
//! )
//! .unwrap();
//! let dag = compile(&spec).unwrap();
//! // `push` waits on `fetch`: two waves.
//! assert_eq!(dag.waves.len(), 2);
//! // The DSL and its canonical JSON are the same plan.
//! let json = PlanSpec::from_json(&spec.to_json()).unwrap();
//! assert_eq!(compile(&json).unwrap().digest, dag.digest);
//! ```

pub mod compile;
pub mod exec;
pub mod journal;
pub mod parser;

pub use compile::{compile, PlanDag, PlanOp, SegDecl, Stage, StreamOps};
pub use exec::{fleet_for, run, PlanReport, StageOutcome};
pub use journal::Journal;
pub use parser::{PlanSpec, RouteSpec, WorkloadKind, WorkloadSpec};

/// Every key the parser accepts, by stanza — `tests/plan_replay.rs` checks
/// each one appears in `docs/DSL.md`, so the spec can't silently drift
/// from the implementation.
pub fn known_keys() -> Vec<(&'static str, &'static [&'static str])> {
    vec![
        ("plan", parser::PLAN_KEYS),
        ("workload", parser::WORKLOAD_KEYS),
        ("chaos", parser::CHAOS_KEYS),
        ("route", parser::ROUTE_KEYS),
        ("kind", parser::WORKLOAD_KINDS),
    ]
}
