//! Plan compilation: resolve names, typecheck per-kind fields, and lower
//! each workload stanza into a verified transfer DAG.
//!
//! A [`PlanDag`] is pure data: segment declarations, per-stream op lists
//! (concrete `src/dst/off/len/class` tuples over stage-local segment
//! indices), and stage dependencies already decomposed into execution
//! waves. Everything random — fetch peers, source slots — is drawn from
//! PRNG streams seeded by `(plan seed, stage name, stream index)` at
//! *compile* time, so the op sequence is a pure function of
//! `(plan file, seed)` and execution-order jitter can never leak into the
//! replay journal.

use super::parser::{PlanSpec, WorkloadKind, WorkloadSpec};
use crate::chaos::{ChaosSchedule, ScenarioMix};
use crate::engine::TransferClass;
use crate::topology::profile::build_profile;
use crate::util::canon;
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use crate::{Error, Result};

/// Source-slot fan per store segment (random read offsets land on one of
/// these slots, so concurrent reads stay cheap to reason about).
const SRC_SLOTS: u64 = 4;

/// One segment a stage registers before running (stage-local index space).
#[derive(Clone, Debug, PartialEq)]
pub struct SegDecl {
    pub node: u16,
    pub len: u64,
    /// Device index for GPU/NPU-resident segments; `None` = host memory.
    /// Only `staged` workloads declare device endpoints.
    pub gpu: Option<u8>,
}

/// One concrete transfer op. `src`/`dst` index the owning stage's `segs`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanOp {
    pub read: bool,
    pub src: usize,
    pub src_off: u64,
    pub dst: usize,
    pub dst_off: u64,
    pub len: u64,
    pub class: TransferClass,
}

/// One submission stream: a window-pipelined op sequence driven from one
/// engine.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamOps {
    /// Node whose engine submits this stream.
    pub engine: u16,
    pub ops: Vec<PlanOp>,
}

/// One executable unit of the DAG (a workload stanza, or one round of an
/// `rl_update`).
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    pub name: String,
    /// Indices into [`PlanDag::stages`] that must complete first.
    pub deps: Vec<usize>,
    pub segs: Vec<SegDecl>,
    pub streams: Vec<StreamOps>,
    /// Outstanding batches per stream (pipelining depth).
    pub window: usize,
    /// FNV digest of the canonical op listing — journaled per stage, so a
    /// replay that compiled different ops is caught immediately.
    pub ops_digest: u64,
    /// Source line of the originating stanza (0 for JSON-born specs).
    pub line: u32,
}

impl Stage {
    pub fn ops_count(&self) -> u64 {
        self.streams.iter().map(|s| s.ops.len() as u64).sum()
    }

    pub fn bytes(&self) -> u64 {
        self.streams.iter().flat_map(|s| s.ops.iter().map(|o| o.len)).sum()
    }
}

/// A compiled, verified plan: ready for `Fleet::run_plan`.
#[derive(Clone, Debug)]
pub struct PlanDag {
    pub spec: PlanSpec,
    /// `canon::fnv1a64` of the spec's canonical JSON — the plan identity
    /// every journal leads with.
    pub digest: u64,
    pub stages: Vec<Stage>,
    /// Stage indices grouped by dependency depth; wave `k+1` starts only
    /// after every stage in wave `k` completed.
    pub waves: Vec<Vec<usize>>,
    /// Embedded fault schedule, generated from the `chaos` stanza at
    /// compile time (pure in the plan seed).
    pub chaos: Option<ChaosSchedule>,
}

impl PlanDag {
    pub fn total_ops(&self) -> u64 {
        self.stages.iter().map(|s| s.ops_count()).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.bytes()).sum()
    }

    /// Human-readable stage table (the CLI's `--check` view).
    pub fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "plan {} profile={} nodes={} seed={:#x} digest={}",
            self.spec.name,
            self.spec.profile,
            self.spec.nodes,
            self.spec.seed,
            canon::digest_hex(self.digest)
        );
        let _ = writeln!(
            out,
            "  {:<20} {:>5} {:>8} {:>8} {:>12}  deps",
            "stage", "wave", "streams", "ops", "bytes"
        );
        for (k, wave) in self.waves.iter().enumerate() {
            for &i in wave {
                let s = &self.stages[i];
                let deps = if s.deps.is_empty() {
                    "-".to_string()
                } else {
                    s.deps
                        .iter()
                        .map(|&d| self.stages[d].name.as_str())
                        .collect::<Vec<_>>()
                        .join(",")
                };
                let _ = writeln!(
                    out,
                    "  {:<20} {:>5} {:>8} {:>8} {:>12}  {}",
                    s.name,
                    k,
                    s.streams.len(),
                    s.ops_count(),
                    crate::util::fmt_bytes(s.bytes()),
                    deps
                );
            }
        }
        if let Some(c) = &self.chaos {
            let _ = writeln!(
                out,
                "  chaos: {} events over {} (digest {})",
                c.events.len(),
                crate::util::fmt_ns(c.horizon_ns),
                canon::digest_hex(c.digest())
            );
        }
        out
    }
}

fn cerr(line: u32, msg: impl std::fmt::Display) -> Error {
    Error::Config(format!("line {line}: {msg}"))
}

/// Integer-valued parameter with a default and a lower bound.
fn param_u64(w: &WorkloadSpec, key: &str, default: u64, min: u64) -> Result<u64> {
    let Some(p) = w.params.iter().find(|p| p.key == key) else {
        return Ok(default);
    };
    if !p.value.is_finite() || p.value < min as f64 || p.value.fract() != 0.0 {
        return Err(cerr(
            p.line,
            format!(
                "workload `{}`: `{key}` must be an integer >= {min} (got {})",
                w.name, p.value
            ),
        ));
    }
    Ok(p.value as u64)
}

/// Per-kind parameter vocabulary (beyond the structural `kind`/`class`/
/// `after`). `window` is valid everywhere.
fn kind_keys(kind: WorkloadKind) -> &'static [&'static str] {
    match kind {
        WorkloadKind::HicacheFetch => &["clients", "ops", "block", "window"],
        WorkloadKind::Broadcast => &["root", "payload", "chunk", "fanout", "window"],
        WorkloadKind::RlUpdate => &["rounds", "root", "payload", "chunk", "ranks", "window"],
        WorkloadKind::Flood => {
            &["streams", "ops", "latency_block", "bulk_block", "bulk_every", "window"]
        }
        WorkloadKind::Staged => {
            &["src", "dst", "src_gpu", "dst_gpu", "payload", "chunk", "window"]
        }
    }
}

/// Optional small-integer parameter (device indices).
fn param_opt_u8(w: &WorkloadSpec, key: &str) -> Result<Option<u8>> {
    let Some(p) = w.params.iter().find(|p| p.key == key) else {
        return Ok(None);
    };
    if !p.value.is_finite() || p.value < 0.0 || p.value > u8::MAX as f64 || p.value.fract() != 0.0 {
        return Err(cerr(
            p.line,
            format!("workload `{}`: `{key}` must be a device index 0..=255 (got {})", w.name, p.value),
        ));
    }
    Ok(Some(p.value as u8))
}

/// Compile a parsed spec into an executable DAG. Pure: equal specs produce
/// equal DAGs (including all PRNG-drawn op parameters).
pub fn compile(spec: &PlanSpec) -> Result<PlanDag> {
    let digest = canon::fnv1a64(&spec.to_json());
    // Validate the profile/node-count pair up front (also feeds the chaos
    // generator, which needs the concrete topology).
    let topo = build_profile(&spec.profile, spec.nodes)
        .map_err(|e| Error::Config(format!("plan `{}`: {e}", spec.name)))?;

    // -- resolve: names are unique, dependencies exist ---------------------
    let mut by_name: std::collections::BTreeMap<&str, usize> = Default::default();
    for (i, w) in spec.workloads.iter().enumerate() {
        if by_name.insert(w.name.as_str(), i).is_some() {
            return Err(cerr(w.line, format!("duplicate workload name `{}`", w.name)));
        }
    }
    for w in &spec.workloads {
        for dep in &w.after {
            if dep == &w.name {
                return Err(cerr(w.line, format!("workload `{}` depends on itself", w.name)));
            }
            if !by_name.contains_key(dep.as_str()) {
                return Err(cerr(
                    w.line,
                    format!("workload `{}`: unknown dependency `{dep}`", w.name),
                ));
            }
        }
    }

    // -- typecheck: every param key must be valid for its kind -------------
    for w in &spec.workloads {
        let valid = kind_keys(w.kind);
        for p in &w.params {
            if !valid.contains(&p.key.as_str()) {
                return Err(cerr(
                    p.line,
                    format!(
                        "workload `{}`: field `{}` not valid for kind `{}` (valid: {})",
                        w.name,
                        p.key,
                        w.kind.name(),
                        valid.join(", ")
                    ),
                ));
            }
        }
    }

    // -- lower each workload into one or more stages -----------------------
    let mut stages: Vec<Stage> = Vec::new();
    // Workload index → (first stage, last stage) for dependency wiring.
    let mut span: Vec<(usize, usize)> = Vec::with_capacity(spec.workloads.len());
    for w in &spec.workloads {
        let first = stages.len();
        match w.kind {
            WorkloadKind::HicacheFetch => stages.push(lower_hicache(spec, w)?),
            WorkloadKind::Broadcast => stages.push(lower_broadcast_like(spec, w, &w.name, "fanout")?),
            WorkloadKind::RlUpdate => {
                let rounds = param_u64(w, "rounds", 2, 1)?;
                for r in 0..rounds {
                    let name = format!("{}#r{r}", w.name);
                    let mut st = lower_broadcast_like(spec, w, &name, "ranks")?;
                    if r > 0 {
                        // Round r+1 reuses round r's parameter buffers only
                        // after the previous install completed.
                        st.deps.push(stages.len() - 1);
                    }
                    stages.push(st);
                }
            }
            WorkloadKind::Flood => stages.push(lower_flood(spec, w)?),
            WorkloadKind::Staged => stages.push(lower_staged(spec, w)?),
        }
        span.push((first, stages.len() - 1));
    }

    // -- resolve route stanzas against workloads and the topology ----------
    for r in &spec.routes {
        let Some(&wi) = by_name.get(r.name.as_str()) else {
            return Err(cerr(r.line, format!("route for unknown workload `{}`", r.name)));
        };
        let w = &spec.workloads[wi];
        if w.kind != WorkloadKind::Staged {
            return Err(cerr(
                r.line,
                format!(
                    "route `{}` targets a `{}` workload (routes apply to kind `staged`)",
                    r.name,
                    w.kind.name()
                ),
            ));
        }
        let max_legs = r.max_legs.unwrap_or(crate::topology::MAX_RELAY_LEGS as u32);
        if !(1..=crate::topology::MAX_RELAY_LEGS as u32).contains(&max_legs) {
            return Err(cerr(
                r.line,
                format!(
                    "route `{}`: `max_legs` must be 1..={} (got {max_legs})",
                    r.name,
                    crate::topology::MAX_RELAY_LEGS
                ),
            ));
        }
        let src = param_u64(w, "src", 0, 0)? as u16;
        let dst = param_u64(w, "dst", 1, 0)? as u16;
        use crate::topology::NodeId;
        if !r.via.is_empty() {
            // Pinned relay path: every hop must have a shared host fabric.
            if r.via.len() as u32 + 1 > max_legs {
                return Err(cerr(
                    r.line,
                    format!(
                        "route `{}`: {} relays need {} legs but `max_legs` is {max_legs}",
                        r.name,
                        r.via.len(),
                        r.via.len() + 1
                    ),
                ));
            }
            let mut path = vec![src];
            path.extend_from_slice(&r.via);
            path.push(dst);
            for pair in path.windows(2) {
                let (a, b) = (pair[0], pair[1]);
                if a as u64 >= spec.nodes as u64 || b as u64 >= spec.nodes as u64 {
                    return Err(cerr(
                        r.line,
                        format!("route `{}`: node {} out of range (nodes = {})", r.name, a.max(b), spec.nodes),
                    ));
                }
                if topo.host_net_between(NodeId(a), NodeId(b)).is_none() {
                    return Err(cerr(
                        r.line,
                        format!("route `{}`: no shared host fabric between nodes {a} and {b}", r.name),
                    ));
                }
            }
        } else if topo.host_net_between(NodeId(src), NodeId(dst)).is_none()
            && topo.relay_routes(NodeId(src), NodeId(dst), max_legs as usize).is_empty()
        {
            return Err(cerr(
                r.line,
                format!(
                    "route `{}`: nodes {src} and {dst} are unreachable within {max_legs} legs",
                    r.name
                ),
            ));
        }
    }

    // -- wire cross-workload deps onto each workload's first stage ---------
    for (wi, w) in spec.workloads.iter().enumerate() {
        for dep in &w.after {
            let di = by_name[dep.as_str()];
            let (first, _) = span[wi];
            let (_, dep_last) = span[di];
            stages[first].deps.push(dep_last);
        }
        let (first, _) = span[wi];
        stages[first].deps.sort_unstable();
        stages[first].deps.dedup();
    }

    // -- Kahn: decompose into waves; leftovers mean a cycle ----------------
    let n = stages.len();
    let mut indeg: Vec<usize> = stages.iter().map(|s| s.deps.len()).collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, s) in stages.iter().enumerate() {
        for &d in &s.deps {
            children[d].push(i);
        }
    }
    let mut waves: Vec<Vec<usize>> = Vec::new();
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut done = 0usize;
    while !ready.is_empty() {
        let wave = std::mem::take(&mut ready);
        done += wave.len();
        for &i in &wave {
            for &c in &children[i] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.push(c);
                }
            }
        }
        ready.sort_unstable();
        waves.push(wave);
    }
    if done < n {
        let cyc: Vec<&str> = (0..n)
            .filter(|&i| indeg[i] > 0)
            .map(|i| stages[i].name.as_str())
            .collect();
        let line = (0..n).find(|&i| indeg[i] > 0).map(|i| stages[i].line).unwrap_or(0);
        return Err(cerr(
            line,
            format!("dependency cycle involving: {}", cyc.join(" -> ")),
        ));
    }

    // -- embedded chaos schedule ------------------------------------------
    let chaos = match &spec.chaos {
        None => None,
        Some(c) => {
            let getf = |key: &str, default: f64| -> f64 {
                c.param(key).unwrap_or(default)
            };
            let max_down = getf("max_down_fraction", 0.5);
            if !(0.0..=1.0).contains(&max_down) {
                return Err(cerr(c.line, format!("`max_down_fraction` out of [0,1]: {max_down}")));
            }
            let mix = ScenarioMix {
                trace_events_per_sec: getf("eps", 4.0),
                storms: getf("storms", 1.0) as u32,
                storm_rails: getf("storm_rails", 2.0) as usize,
                storm_outage_ns: getf("storm_outage", 40_000_000.0) as u64,
                flap_cycles: getf("flap_cycles", 4.0) as u32,
                flap_period_ns: getf("flap_period", 20_000_000.0) as u64,
                slow_drains: getf("slow_drains", 1.0) as u32,
                congestion_ramps: getf("ramps", 1.0) as u32,
                max_down_fraction: max_down,
            };
            let horizon = getf("horizon", 250_000_000.0) as u64;
            // Distinct stream from the op generators: the chaos schedule is
            // seeded off the plan seed, so `--seed` re-rolls faults too.
            Some(ChaosSchedule::generate(
                &topo,
                spec.seed ^ 0xC4A0_5EED,
                horizon,
                &mix,
            ))
        }
    };

    Ok(PlanDag {
        spec: spec.clone(),
        digest,
        stages,
        waves,
        chaos,
    })
}

/// PRNG for one (stage, stream) pair — pure in the plan seed and names.
fn stage_rng(spec: &PlanSpec, stage: &str, stream: u64) -> Pcg64 {
    Pcg64::new(spec.seed ^ canon::fnv1a64(stage), 0x91A7 + stream)
}

/// Digest the canonical op listing of a stage (engine + full op tuples).
fn ops_digest(name: &str, streams: &[StreamOps]) -> u64 {
    let sj = streams
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("engine", Json::num(s.engine as f64)),
                (
                    "ops",
                    Json::arr(s.ops.iter().map(|o| {
                        Json::arr(vec![
                            Json::num(if o.read { 1.0 } else { 0.0 }),
                            Json::num(o.src as f64),
                            Json::num(o.src_off as f64),
                            Json::num(o.dst as f64),
                            Json::num(o.dst_off as f64),
                            Json::num(o.len as f64),
                            Json::str(o.class.name()),
                        ])
                    })),
                ),
            ])
        })
        .collect::<Vec<_>>();
    canon::digest_json(&Json::obj(vec![
        ("stage", Json::str(name)),
        ("streams", Json::arr(sj)),
    ]))
}

fn stage_window(spec: &PlanSpec, w: &WorkloadSpec) -> Result<usize> {
    Ok(param_u64(w, "window", spec.window as u64, 1)? as usize)
}

/// HiCache fetch storm: `clients` streams of latency-class reads, each
/// pulling random slice-aligned blocks from random peers' stores.
fn lower_hicache(spec: &PlanSpec, w: &WorkloadSpec) -> Result<Stage> {
    let nodes = spec.nodes as u64;
    let clients = param_u64(w, "clients", nodes, 1)?;
    let ops = param_u64(w, "ops", 32, 1)?;
    let block = param_u64(w, "block", 256 << 10, 1)?;
    let window = stage_window(spec, w)?;
    let class = w.class.unwrap_or(TransferClass::Latency);

    let mut segs: Vec<SegDecl> = (0..spec.nodes)
        .map(|n| SegDecl { node: n, len: block * SRC_SLOTS, gpu: None })
        .collect();
    let mut streams = Vec::with_capacity(clients as usize);
    for c in 0..clients {
        let engine = (c % nodes) as u16;
        let scratch = segs.len();
        segs.push(SegDecl { node: engine, len: block * window as u64, gpu: None });
        let mut rng = stage_rng(spec, &w.name, c);
        let mut ops_v = Vec::with_capacity(ops as usize);
        for i in 0..ops {
            let peer = if nodes == 1 {
                0
            } else {
                // Uniform over peers != the submitting node.
                let r = rng.gen_range(nodes - 1);
                if r >= engine as u64 {
                    r + 1
                } else {
                    r
                }
            };
            let slot = rng.gen_range(SRC_SLOTS);
            ops_v.push(PlanOp {
                read: true,
                src: peer as usize,
                src_off: slot * block,
                dst: scratch,
                dst_off: (i % window as u64) * block,
                len: block,
                class,
            });
        }
        streams.push(StreamOps { engine, ops: ops_v });
    }
    let digest = ops_digest(&w.name, &streams);
    Ok(Stage {
        name: w.name.clone(),
        deps: Vec::new(),
        segs,
        streams,
        window,
        ops_digest: digest,
        line: w.line,
    })
}

/// Broadcast lowering shared by `broadcast` (fan key `fanout`) and each
/// `rl_update` round (fan key `ranks`): chunked bulk pushes from `root` to
/// the next `fan` ring peers, one stream per destination.
fn lower_broadcast_like(
    spec: &PlanSpec,
    w: &WorkloadSpec,
    stage_name: &str,
    fan_key: &str,
) -> Result<Stage> {
    let nodes = spec.nodes as u64;
    if nodes < 2 {
        return Err(cerr(
            w.line,
            format!("workload `{}`: kind `{}` needs >= 2 nodes", w.name, w.kind.name()),
        ));
    }
    let root = param_u64(w, "root", 0, 0)?;
    if root >= nodes {
        return Err(cerr(
            w.line,
            format!("workload `{}`: root {root} out of range (nodes = {nodes})", w.name),
        ));
    }
    let payload = param_u64(w, "payload", 8 << 20, 1)?;
    let chunk = param_u64(w, "chunk", 1 << 20, 1)?.min(payload);
    let fan = param_u64(w, fan_key, nodes - 1, 1)?.min(nodes - 1);
    let window = stage_window(spec, w)?;
    let class = w.class.unwrap_or(TransferClass::Bulk);

    let nchunks = payload.div_ceil(chunk);
    // Source staging buffer: one window of chunk slots on the root.
    let mut segs = vec![SegDecl { node: root as u16, len: chunk * window as u64, gpu: None }];
    let mut streams = Vec::with_capacity(fan as usize);
    for k in 0..fan {
        let dst_node = ((root + 1 + k) % nodes) as u16;
        let dst = segs.len();
        segs.push(SegDecl { node: dst_node, len: payload, gpu: None });
        let mut ops_v = Vec::with_capacity(nchunks as usize);
        for j in 0..nchunks {
            let len = if j == nchunks - 1 { payload - j * chunk } else { chunk };
            ops_v.push(PlanOp {
                read: false,
                src: 0,
                src_off: (j % window as u64) * chunk,
                dst,
                dst_off: j * chunk,
                len,
                class,
            });
        }
        streams.push(StreamOps { engine: root as u16, ops: ops_v });
    }
    let digest = ops_digest(stage_name, &streams);
    Ok(Stage {
        name: stage_name.to_string(),
        deps: Vec::new(),
        segs,
        streams,
        window,
        ops_digest: digest,
        line: w.line,
    })
}

/// Mixed QoS flood: per-stream sequences interleaving latency-class
/// random-peer reads with a bulk push to the ring neighbour every
/// `bulk_every`-th op — the `Fleet::run_workload` traffic mix as data.
fn lower_flood(spec: &PlanSpec, w: &WorkloadSpec) -> Result<Stage> {
    let nodes = spec.nodes as u64;
    let nstreams = param_u64(w, "streams", nodes, 1)?;
    let ops = param_u64(w, "ops", 32, 1)?;
    let lat_block = param_u64(w, "latency_block", 256 << 10, 1)?;
    let bulk_block = param_u64(w, "bulk_block", 2 << 20, 1)?;
    let bulk_every = param_u64(w, "bulk_every", 4, 0)?;
    let window = stage_window(spec, w)?;

    let mut segs: Vec<SegDecl> = (0..spec.nodes)
        .map(|n| SegDecl { node: n, len: (lat_block * SRC_SLOTS).max(bulk_block), gpu: None })
        .collect();
    let mut streams = Vec::with_capacity(nstreams as usize);
    for s in 0..nstreams {
        let engine = (s % nodes) as u16;
        let scratch = segs.len();
        segs.push(SegDecl { node: engine, len: lat_block * window as u64, gpu: None });
        let bulk_dst = segs.len();
        segs.push(SegDecl {
            node: ((engine as u64 + 1) % nodes) as u16,
            len: bulk_block * window as u64,
            gpu: None,
        });
        let mut rng = stage_rng(spec, &w.name, 0xF10 + s);
        let mut ops_v = Vec::with_capacity(ops as usize);
        for i in 0..ops {
            let slot = i % window as u64;
            let bulk = bulk_every > 0 && i % bulk_every == bulk_every - 1;
            if bulk {
                ops_v.push(PlanOp {
                    read: false,
                    src: engine as usize,
                    src_off: 0,
                    dst: bulk_dst,
                    dst_off: slot * bulk_block,
                    len: bulk_block,
                    class: w.class.unwrap_or(TransferClass::Bulk),
                });
            } else {
                let peer = if nodes == 1 {
                    0
                } else {
                    let r = rng.gen_range(nodes - 1);
                    if r >= engine as u64 {
                        r + 1
                    } else {
                        r
                    }
                };
                let src_slot = rng.gen_range(SRC_SLOTS);
                ops_v.push(PlanOp {
                    read: true,
                    src: peer as usize,
                    src_off: src_slot * lat_block,
                    dst: scratch,
                    dst_off: slot * lat_block,
                    len: lat_block,
                    class: w.class.unwrap_or(TransferClass::Latency),
                });
            }
        }
        streams.push(StreamOps { engine, ops: ops_v });
    }
    let digest = ops_digest(&w.name, &streams);
    Ok(Stage {
        name: w.name.clone(),
        deps: Vec::new(),
        segs,
        streams,
        window,
        ops_digest: digest,
        line: w.line,
    })
}

/// Point-to-point staged stream: chunked pushes `src` → `dst`, optionally
/// between device endpoints (`src_gpu`/`dst_gpu`). On profiles where the
/// endpoints share no direct backend the engine's planner realizes each op
/// as a k-hop relay through host memory on intermediate nodes — a `route`
/// stanza naming this workload declares (and compile-validates) that such
/// a path exists in the topology.
fn lower_staged(spec: &PlanSpec, w: &WorkloadSpec) -> Result<Stage> {
    let nodes = spec.nodes as u64;
    if nodes < 2 {
        return Err(cerr(
            w.line,
            format!("workload `{}`: kind `staged` needs >= 2 nodes", w.name),
        ));
    }
    let src = param_u64(w, "src", 0, 0)?;
    let dst = param_u64(w, "dst", 1, 0)?;
    for (key, n) in [("src", src), ("dst", dst)] {
        if n >= nodes {
            return Err(cerr(
                w.line,
                format!("workload `{}`: `{key}` {n} out of range (nodes = {nodes})", w.name),
            ));
        }
    }
    if src == dst {
        return Err(cerr(
            w.line,
            format!("workload `{}`: `src` and `dst` are both node {src}", w.name),
        ));
    }
    let payload = param_u64(w, "payload", 4 << 20, 1)?;
    let chunk = param_u64(w, "chunk", 1 << 20, 1)?.min(payload);
    let window = stage_window(spec, w)?;
    let class = w.class.unwrap_or(TransferClass::Bulk);

    let nchunks = payload.div_ceil(chunk);
    let segs = vec![
        SegDecl {
            node: src as u16,
            len: chunk * window as u64,
            gpu: param_opt_u8(w, "src_gpu")?,
        },
        SegDecl {
            node: dst as u16,
            len: payload,
            gpu: param_opt_u8(w, "dst_gpu")?,
        },
    ];
    let mut ops_v = Vec::with_capacity(nchunks as usize);
    for j in 0..nchunks {
        let len = if j == nchunks - 1 { payload - j * chunk } else { chunk };
        ops_v.push(PlanOp {
            read: false,
            src: 0,
            src_off: (j % window as u64) * chunk,
            dst: 1,
            dst_off: j * chunk,
            len,
            class,
        });
    }
    let streams = vec![StreamOps { engine: src as u16, ops: ops_v }];
    let digest = ops_digest(&w.name, &streams);
    Ok(Stage {
        name: w.name.clone(),
        deps: Vec::new(),
        segs,
        streams,
        window,
        ops_digest: digest,
        line: w.line,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::parser::PlanSpec;

    fn spec(src: &str) -> PlanSpec {
        PlanSpec::parse(src).unwrap()
    }

    #[test]
    fn compile_is_pure_in_the_spec() {
        let s = spec(
            "plan p\nnodes 4\nseed 9\nworkload a {\n kind hicache_fetch\n ops 8\n}\n\
             workload b {\n kind broadcast\n payload 2M\n after a\n}\n",
        );
        let d1 = compile(&s).unwrap();
        let d2 = compile(&s).unwrap();
        assert_eq!(d1.digest, d2.digest);
        assert_eq!(d1.stages, d2.stages);
        // Stage-level op digests are stable too.
        for (a, b) in d1.stages.iter().zip(&d2.stages) {
            assert_eq!(a.ops_digest, b.ops_digest);
        }
        // A different seed re-rolls ops and the plan identity.
        let mut s2 = s.clone();
        s2.seed = 10;
        let d3 = compile(&s2).unwrap();
        assert_ne!(d1.digest, d3.digest);
        assert_ne!(d1.stages[0].ops_digest, d3.stages[0].ops_digest);
    }

    #[test]
    fn waves_respect_dependencies() {
        let s = spec(
            "plan p\nnodes 2\nworkload a {\n kind flood\n ops 4\n}\n\
             workload b {\n kind flood\n ops 4\n after a\n}\n\
             workload c {\n kind flood\n ops 4\n}\n",
        );
        let d = compile(&s).unwrap();
        assert_eq!(d.waves.len(), 2);
        assert_eq!(d.waves[0], vec![0, 2]); // a, c
        assert_eq!(d.waves[1], vec![1]); // b after a
        assert_eq!(d.stages[1].deps, vec![0]);
    }

    #[test]
    fn rl_update_chains_rounds() {
        let s = spec(
            "plan p\nnodes 4\nworkload upd {\n kind rl_update\n rounds 3\n payload 1M\n chunk 256K\n}\n",
        );
        let d = compile(&s).unwrap();
        assert_eq!(d.stages.len(), 3);
        assert_eq!(d.stages[0].name, "upd#r0");
        assert_eq!(d.stages[1].deps, vec![0]);
        assert_eq!(d.stages[2].deps, vec![1]);
        assert_eq!(d.waves.len(), 3);
        // 4 chunks to 3 ranks per round.
        assert_eq!(d.stages[0].ops_count(), 12);
        assert_eq!(d.stages[0].bytes(), 3 << 20);
    }

    #[test]
    fn rejects_cycles_with_spans() {
        let s = spec(
            "plan p\nnodes 2\nworkload a {\n kind flood\n after b\n}\n\
             workload b {\n kind flood\n after a\n}\n",
        );
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("cycle"), "{e}");
        assert!(e.contains("line 3"), "{e}");
        assert!(e.contains("a") && e.contains("b"), "{e}");
    }

    #[test]
    fn rejects_bad_fields_for_kind() {
        let s = spec("plan p\nnodes 2\nworkload w {\n kind flood\n payload 1M\n}\n");
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("line 5") && e.contains("payload") && e.contains("flood"), "{e}");

        let s = spec("plan p\nworkload w {\n kind broadcast\n root 7\n}\n");
        // default nodes = 4, root out of range
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("root"), "{e}");

        let s = spec("plan p\nworkload a {\n kind flood\n}\nworkload a {\n kind flood\n}\n");
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("duplicate workload name"), "{e}");

        let s = spec("plan p\nworkload a {\n kind flood\n after ghost\n}\n");
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("ghost"), "{e}");

        let s = spec("plan p\nnodes 1\nworkload a {\n kind broadcast\n}\n");
        assert!(compile(&s).is_err(), "broadcast on one node");
    }

    #[test]
    fn broadcast_chunks_cover_the_payload_exactly() {
        let s = spec(
            "plan p\nnodes 3\nworkload b {\n kind broadcast\n payload 2500K\n chunk 1M\n}\n",
        );
        let d = compile(&s).unwrap();
        let st = &d.stages[0];
        assert_eq!(st.streams.len(), 2);
        for stream in &st.streams {
            let total: u64 = stream.ops.iter().map(|o| o.len).sum();
            assert_eq!(total, 2500 << 10);
            // Chunks tile the destination without overlap.
            let mut covered = 0u64;
            for o in &stream.ops {
                assert_eq!(o.dst_off, covered);
                covered += o.len;
            }
            // Every op stays inside the destination segment.
            let dst_len = st.segs[stream.ops[0].dst].len;
            assert!(covered <= dst_len);
        }
    }

    #[test]
    fn embedded_chaos_is_seeded_from_the_plan() {
        let src = "plan p\nnodes 4\nseed 21\nworkload a {\n kind flood\n ops 4\n}\n\
                   chaos {\n eps 6\n horizon 200ms\n}\n";
        let d1 = compile(&spec(src)).unwrap();
        let d2 = compile(&spec(src)).unwrap();
        let c1 = d1.chaos.as_ref().unwrap();
        let c2 = d2.chaos.as_ref().unwrap();
        assert_eq!(c1.digest(), c2.digest());
        assert_eq!(c1.horizon_ns, 200_000_000);
        let mut s3 = spec(src);
        s3.seed = 22;
        let d3 = compile(&s3).unwrap();
        assert_ne!(c1.digest(), d3.chaos.as_ref().unwrap().digest());
    }

    #[test]
    fn staged_workload_lowers_with_device_endpoints() {
        let s = spec(
            "plan p\nprofile silo_fleet\nnodes 3\nworkload push {\n kind staged\n src 0\n dst 1\n \
             src_gpu 0\n dst_gpu 2\n payload 1M\n chunk 256K\n}\nroute push {\n via 2\n}\n",
        );
        let d = compile(&s).unwrap();
        let st = &d.stages[0];
        assert_eq!(st.segs[0].gpu, Some(0));
        assert_eq!(st.segs[1].gpu, Some(2));
        assert_eq!(st.segs[0].node, 0);
        assert_eq!(st.segs[1].node, 1);
        assert_eq!(st.streams.len(), 1);
        assert_eq!(st.bytes(), 1 << 20);
        // Deterministic: the route stanza is part of the plan identity.
        assert_eq!(compile(&s).unwrap().digest, d.digest);
        let mut bare = s.clone();
        bare.routes.clear();
        assert_ne!(compile(&bare).unwrap().digest, d.digest);
    }

    #[test]
    fn route_stanza_is_validated_against_the_topology() {
        // Unknown workload target.
        let s = spec("plan p\nworkload w {\n kind flood\n}\nroute ghost {\n via 1\n}\n");
        assert!(compile(&s).unwrap_err().to_string().contains("unknown workload"));

        // Routes only apply to staged workloads.
        let s = spec("plan p\nworkload w {\n kind flood\n}\nroute w {\n via 1\n}\n");
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("staged"), "{e}");

        // A pinned relay path must have a host fabric on every hop:
        // silo_fleet prefill (0) and decode (1) share none directly, so
        // `via` pinning the direct hop 0->1 cannot compile...
        let bad = spec(
            "plan p\nprofile silo_fleet\nnodes 3\nworkload w {\n kind staged\n src 0\n dst 1\n}\n\
             route w {\n max_legs 1\n}\n",
        );
        let e = compile(&bad).unwrap_err().to_string();
        assert!(e.contains("unreachable") && e.contains("1 legs"), "{e}");
        // ...while bouncing through the gateway (2) does.
        let ok = spec(
            "plan p\nprofile silo_fleet\nnodes 3\nworkload w {\n kind staged\n src 0\n dst 1\n}\n\
             route w {\n via 2\n}\n",
        );
        assert!(compile(&ok).is_ok());

        // max_legs out of range.
        let s = spec(
            "plan p\nnodes 2\nworkload w {\n kind staged\n}\nroute w {\n max_legs 9\n}\n",
        );
        assert!(compile(&s).unwrap_err().to_string().contains("max_legs"));

        // via longer than the leg budget.
        let s = spec(
            "plan p\nprofile silo_fleet\nnodes 6\nworkload w {\n kind staged\n src 0\n dst 1\n}\n\
             route w {\n max_legs 2\n via 2,5\n}\n",
        );
        let e = compile(&s).unwrap_err().to_string();
        assert!(e.contains("3 legs"), "{e}");
    }

    #[test]
    fn staged_rejects_bad_endpoints() {
        let s = spec("plan p\nnodes 2\nworkload w {\n kind staged\n src 0\n dst 0\n}\n");
        assert!(compile(&s).unwrap_err().to_string().contains("both node 0"));
        let s = spec("plan p\nnodes 2\nworkload w {\n kind staged\n dst 7\n}\n");
        assert!(compile(&s).unwrap_err().to_string().contains("out of range"));
        let s = spec("plan p\nnodes 2\nworkload w {\n kind staged\n src_gpu 300\n}\n");
        assert!(compile(&s).unwrap_err().to_string().contains("device index"));
    }

    #[test]
    fn every_op_is_in_bounds() {
        let s = spec(
            "plan p\nnodes 4\nworkload a {\n kind hicache_fetch\n clients 6\n ops 16\n}\n\
             workload b {\n kind flood\n ops 16\n}\nworkload c {\n kind rl_update\n rounds 2\n}\n",
        );
        let d = compile(&s).unwrap();
        for st in &d.stages {
            for stream in &st.streams {
                for o in &stream.ops {
                    assert!(o.src < st.segs.len() && o.dst < st.segs.len());
                    assert!(o.src_off + o.len <= st.segs[o.src].len, "{}: src oob", st.name);
                    assert!(o.dst_off + o.len <= st.segs[o.dst].len, "{}: dst oob", st.name);
                    assert!((st.segs[o.src].node as u64) < 4);
                }
            }
        }
    }
}
