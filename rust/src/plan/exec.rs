//! Plan execution: drive a compiled [`PlanDag`] through a live
//! [`Fleet`], wave by wave, with the embedded chaos schedule (if any)
//! replaying on its own thread — and emit the deterministic journal.
//!
//! Stage streams use the exact submission idiom of
//! `Fleet::run_workload`: one thread per stream, a `VecDeque` pipeline
//! window of outstanding batches, reap via `wait_any`. The difference is
//! that every op was already decided at compile time, so the only
//! run-to-run variance is wall-clock — which the journal excludes.

use super::compile::{PlanDag, Stage};
use super::journal::Journal;
use super::parser::PlanSpec;
use crate::chaos::injector;
use crate::cluster::{Fleet, FleetConfig};
use crate::engine::{TentEngine, TransferClass, TransferReq};
use crate::segment::{Location, SegmentId};
use crate::util::canon;
use crate::util::clock;
use crate::util::hist::Histogram;
use crate::util::json::Json;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-stage measured outcome (informational; not journaled).
pub struct StageOutcome {
    pub name: String,
    /// Scheduled op count (compile-time fact).
    pub ops: u64,
    /// Ops that failed or could not be submitted.
    pub failed: u64,
    /// Scheduled payload bytes.
    pub bytes: u64,
    pub wall_ns: u64,
}

/// Everything one plan run produced.
pub struct PlanReport {
    pub plan: String,
    pub seed: u64,
    /// Plan identity: `canon::fnv1a64` of the spec's canonical JSON.
    pub digest: u64,
    pub nodes: usize,
    pub wall_ns: u64,
    pub total_ops: u64,
    pub failed_ops: u64,
    /// Scheduled payload bytes across all stages.
    pub total_bytes: u64,
    pub stages: Vec<StageOutcome>,
    pub latency_hist: Histogram,
    pub bulk_hist: Histogram,
    /// Applied chaos actions (empty without a `chaos` stanza).
    pub chaos_actions: usize,
    /// The deterministic execution journal — replays of `(plan, seed)`
    /// produce byte-identical `journal.to_jsonl()`.
    pub journal: Journal,
}

impl PlanReport {
    pub fn journal_digest(&self) -> u64 {
        self.journal.digest()
    }

    /// One-line run identity, printed above the stage table.
    pub fn header(&self) -> String {
        format!(
            "plan={} nodes={} seed={:#x} plan_digest={} journal_digest={}",
            self.plan,
            self.nodes,
            self.seed,
            canon::digest_hex(self.digest),
            self.journal.digest_hex()
        )
    }

    /// Per-stage outcome table.
    pub fn table(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "  {:<20} {:>8} {:>8} {:>12} {:>12}",
            "stage", "ops", "failed", "bytes", "wall"
        );
        for s in &self.stages {
            let _ = writeln!(
                out,
                "  {:<20} {:>8} {:>8} {:>12} {:>12}",
                s.name,
                s.ops,
                s.failed,
                crate::util::fmt_bytes(s.bytes),
                crate::util::fmt_ns(s.wall_ns)
            );
        }
        let _ = writeln!(
            out,
            "  total: {} ops ({} failed), {} in {}, chaos_actions={}",
            self.total_ops,
            self.failed_ops,
            crate::util::fmt_bytes(self.total_bytes),
            crate::util::fmt_ns(self.wall_ns),
            self.chaos_actions
        );
        out
    }

    /// Machine-readable summary for the CLI's `--json` and the bench.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", Json::str(&self.plan)),
            ("seed", Json::str(&self.seed.to_string())),
            ("plan_digest", Json::str(&canon::digest_hex(self.digest))),
            ("journal_digest", Json::str(&self.journal.digest_hex())),
            ("nodes", Json::num(self.nodes as f64)),
            ("stages", Json::num(self.stages.len() as f64)),
            ("ops", Json::num(self.total_ops as f64)),
            ("failed", Json::num(self.failed_ops as f64)),
            ("bytes", Json::num(self.total_bytes as f64)),
            ("wall_ns", Json::num(self.wall_ns as f64)),
            ("chaos_actions", Json::num(self.chaos_actions as f64)),
        ])
    }
}

/// Build a fleet shaped for this plan: its profile, node count, and
/// fabric time compression. The CLI, bench, and tests all go through this
/// so every plan knob that shapes execution is actually honored.
pub fn fleet_for(spec: &PlanSpec) -> Result<Fleet> {
    let mut cfg = FleetConfig::new(&spec.profile, spec.nodes);
    cfg.fabric.time_compression = spec.time_compression;
    Fleet::new(cfg)
}

/// Run a compiled plan against the fleet. The fleet must have been built
/// for the plan's node count (use [`fleet_for`]).
pub fn run(fleet: &Fleet, dag: &PlanDag) -> Result<PlanReport> {
    if fleet.nodes() != dag.spec.nodes as usize {
        return Err(Error::Config(format!(
            "plan `{}` compiled for {} nodes but the fleet has {}",
            dag.spec.name,
            dag.spec.nodes,
            fleet.nodes()
        )));
    }
    let fabric = Arc::clone(&fleet.cluster.fabric);
    if let Some(sched) = &dag.chaos {
        injector::validate(&fabric, sched)?;
    }

    let lat_hist = Histogram::new();
    let bulk_hist = Histogram::new();
    let mut outcomes: Vec<StageOutcome> = dag
        .stages
        .iter()
        .map(|s| StageOutcome {
            name: s.name.clone(),
            ops: s.ops_count(),
            failed: 0,
            bytes: s.bytes(),
            wall_ns: 0,
        })
        .collect();

    let start = clock::now_ns();
    // The injector thread spans the whole run; waves execute sequentially
    // inside, each stage of a wave on its own thread. An early error exit
    // still joins the injector (scope guarantees it).
    let applied = std::thread::scope(|scope| -> Result<Vec<injector::AppliedAction>> {
        let inj = dag.chaos.as_ref().map(|sched| {
            let fab = &fabric;
            scope.spawn(move || injector::replay(fab, sched, None, start))
        });
        for wave in &dag.waves {
            let results: Vec<(usize, Result<(u64, u64)>)> = std::thread::scope(|ws| {
                let handles: Vec<_> = wave
                    .iter()
                    .map(|&i| {
                        let lat = &lat_hist;
                        let bulk = &bulk_hist;
                        (i, ws.spawn(move || run_stage(fleet, &dag.stages[i], lat, bulk)))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|(i, h)| (i, h.join().expect("plan stage thread panicked")))
                    .collect()
            });
            for (i, r) in results {
                let (failed, wall_ns) = r?;
                outcomes[i].failed = failed;
                outcomes[i].wall_ns = wall_ns;
            }
        }
        match inj {
            None => Ok(Vec::new()),
            Some(h) => h.join().expect("chaos injector panicked"),
        }
    });
    // Restore the fabric before error handling, so a failed run never
    // leaves rails down for the next plan on this fleet.
    if let Some(sched) = &dag.chaos {
        injector::recover_touched(&fabric, sched);
    }
    let applied = applied?;
    let wall_ns = clock::now_ns().saturating_sub(start);

    // -- assemble the journal in deterministic order -----------------------
    let mut journal = Journal::new();
    journal.record_plan(dag);
    if let Some(sched) = &dag.chaos {
        journal.record_chaos(sched);
    }
    for (i, st) in dag.stages.iter().enumerate() {
        journal.record_stage(i, st);
    }
    for a in &applied {
        journal.record_action(a);
    }
    journal.record_end(dag.total_ops(), dag.stages.len());

    Ok(PlanReport {
        plan: dag.spec.name.clone(),
        seed: dag.spec.seed,
        digest: dag.digest,
        nodes: fleet.nodes(),
        wall_ns,
        total_ops: dag.total_ops(),
        failed_ops: outcomes.iter().map(|o| o.failed).sum(),
        total_bytes: dag.total_bytes(),
        stages: outcomes,
        latency_hist: lat_hist,
        bulk_hist,
        chaos_actions: applied.len(),
        journal,
    })
}

/// One outstanding batch in a stream's pipeline window.
struct PendingOp {
    batch: crate::engine::BatchId,
    t0: u64,
    class: TransferClass,
}

/// Execute one stage: register its segments, run every stream with window
/// pipelining, unregister. Returns `(failed_ops, wall_ns)`.
fn run_stage(
    fleet: &Fleet,
    stage: &Stage,
    lat_hist: &Histogram,
    bulk_hist: &Histogram,
) -> Result<(u64, u64)> {
    // The segment namespace is cluster-wide, so one engine can register on
    // behalf of all (run_workload registers cross-node stores the same way).
    let reg = fleet.engine(0);
    let mut ids: Vec<SegmentId> = Vec::with_capacity(stage.segs.len());
    for s in &stage.segs {
        let loc = match s.gpu {
            Some(g) => Location::device(s.node, g),
            None => Location::host(s.node, 0),
        };
        ids.push(reg.register_segment(loc, s.len)?);
    }
    let failed = AtomicU64::new(0);
    let window = stage.window.max(1);
    let t0 = clock::now_ns();
    std::thread::scope(|scope| {
        for stream in &stage.streams {
            let engine = Arc::clone(fleet.engine(stream.engine));
            let ids = &ids;
            let failed = &failed;
            scope.spawn(move || {
                let mut inflight: VecDeque<PendingOp> = VecDeque::with_capacity(window);
                let reap = |engine: &TentEngine, q: &mut VecDeque<PendingOp>| {
                    if let Some(p) = q.pop_front() {
                        let ok = engine
                            .wait_any(p.batch, Duration::from_secs(120))
                            .map(|st| st.ok())
                            .unwrap_or(false);
                        let _ = engine.release_batch(p.batch);
                        if ok {
                            let dt = clock::now_ns().saturating_sub(p.t0);
                            match p.class {
                                TransferClass::Latency => lat_hist.record(dt),
                                TransferClass::Bulk => bulk_hist.record(dt),
                            }
                        } else {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                };
                for (n, op) in stream.ops.iter().enumerate() {
                    let req = if op.read {
                        TransferReq::read(ids[op.src], op.src_off, ids[op.dst], op.dst_off, op.len)
                    } else {
                        TransferReq::write(ids[op.src], op.src_off, ids[op.dst], op.dst_off, op.len)
                    }
                    .class(op.class);
                    let batch = engine.allocate_batch();
                    let t0 = clock::now_ns();
                    if engine.submit(batch, &[req]).is_err() {
                        // Cluster shutting down: everything not yet
                        // submitted counts as failed.
                        let _ = engine.release_batch(batch);
                        failed.fetch_add((stream.ops.len() - n) as u64, Ordering::Relaxed);
                        break;
                    }
                    inflight.push_back(PendingOp {
                        batch,
                        t0,
                        class: op.class,
                    });
                    if inflight.len() >= window {
                        reap(&engine, &mut inflight);
                    }
                }
                while !inflight.is_empty() {
                    reap(&engine, &mut inflight);
                }
            });
        }
    });
    let wall_ns = clock::now_ns().saturating_sub(t0);
    for id in ids {
        let _ = reg.unregister_segment(id);
    }
    Ok((failed.load(Ordering::Relaxed), wall_ns))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::compile::compile;

    fn fleet(spec: &PlanSpec) -> Fleet {
        fleet_for(spec).unwrap()
    }

    #[test]
    fn plan_run_journals_deterministically() {
        let spec = PlanSpec::parse(
            "plan t\nnodes 2\nseed 5\nworkload f {\n kind flood\n ops 8\n streams 2\n}\n\
             workload b {\n kind broadcast\n payload 1M\n chunk 256K\n after f\n}\n",
        )
        .unwrap();
        let dag = compile(&spec).unwrap();
        let r1 = run(&fleet(&spec), &dag).unwrap();
        let r2 = run(&fleet(&spec), &dag).unwrap();
        assert_eq!(r1.failed_ops, 0, "no failures without chaos");
        assert_eq!(r1.total_ops, dag.total_ops());
        assert_eq!(
            r1.journal.to_jsonl(),
            r2.journal.to_jsonl(),
            "replay must be byte-identical"
        );
        assert_eq!(r1.journal_digest(), r2.journal_digest());
        // plan + 2 flood/broadcast stages + end.
        assert_eq!(r1.journal.len(), 1 + dag.stages.len() + 1);
        assert!(r1.latency_hist.count() > 0 && r1.bulk_hist.count() > 0);
        assert!(r1.header().contains("journal_digest="));
    }

    #[test]
    fn chaos_plan_replays_with_identical_action_log() {
        let spec = PlanSpec::parse(
            "plan c\nnodes 2\nseed 13\nworkload f {\n kind flood\n ops 24\n}\n\
             chaos {\n eps 8\n horizon 60ms\n storms 0\n flap_cycles 0\n slow_drains 0\n ramps 0\n}\n",
        )
        .unwrap();
        let dag = compile(&spec).unwrap();
        assert!(dag.chaos.is_some());
        let r1 = run(&fleet(&spec), &dag).unwrap();
        let r2 = run(&fleet(&spec), &dag).unwrap();
        assert_eq!(r1.journal.to_jsonl(), r2.journal.to_jsonl());
        // The fleet heals and stays reusable after the run.
        let f = fleet(&spec);
        let _ = run(&f, &dag).unwrap();
        let again = run(&f, &dag).unwrap();
        assert_eq!(again.journal_digest(), r1.journal_digest());
    }

    #[test]
    fn rejects_a_mis_sized_fleet() {
        let spec =
            PlanSpec::parse("plan t\nnodes 4\nworkload f {\n kind flood\n ops 2\n}\n").unwrap();
        let dag = compile(&spec).unwrap();
        let small = Fleet::new(FleetConfig::new("h800_hgx", 2)).unwrap();
        let e = run(&small, &dag).unwrap_err().to_string();
        assert!(e.contains("4 nodes") && e.contains("2"), "{e}");
    }
}
