//! Append-only execution journal: one canonical-JSON event per line, with
//! an FNV-1a digest over the whole text (the same `util::canon` writer and
//! digest the chaos subsystem uses for `ChaosReport::replay_signature`).
//!
//! Every journaled quantity is *scheduled*, not measured: op counts, op
//! digests, chaos actions at their schedule-relative offsets. Wall-clock
//! values (goodput, latency histograms, failure counts under injected
//! faults) never enter — real threads never repeat them, and the journal's
//! whole point is that two runs of `(plan file, seed)` produce
//! byte-identical text.

use super::compile::{PlanDag, Stage};
use crate::chaos::{AppliedAction, ChaosSchedule};
use crate::util::canon;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// An append-only event log with a canonical serialized form.
#[derive(Clone, Debug, Default)]
pub struct Journal {
    events: Vec<Json>,
}

impl Journal {
    pub fn new() -> Journal {
        Journal::default()
    }

    pub fn push(&mut self, ev: Json) {
        self.events.push(ev);
    }

    pub fn events(&self) -> &[Json] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Canonical text form: one sorted-key JSON object per line, trailing
    /// newline. This is what [`Journal::digest`] hashes and what `save`
    /// writes, so a journal loaded back from disk digests identically.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            out.push_str(&ev.to_string());
            out.push('\n');
        }
        out
    }

    /// FNV-1a 64 over the canonical text.
    pub fn digest(&self) -> u64 {
        canon::fnv1a64(&self.to_jsonl())
    }

    pub fn digest_hex(&self) -> String {
        canon::digest_hex(self.digest())
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_jsonl()).map_err(Error::Io)
    }

    /// Parse a journal back from jsonl text. Key order in the input does
    /// not matter — events re-canonicalize on parse, so
    /// `from_jsonl(j.to_jsonl())` always digests equal to `j`.
    pub fn from_jsonl(src: &str) -> Result<Journal> {
        let mut events = Vec::new();
        for (i, line) in src.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let ev = Json::parse(line).map_err(|e| {
                Error::Config(format!("journal line {}: {e}", i + 1))
            })?;
            if ev.as_obj().is_none() || ev.get("ev").as_str().is_none() {
                return Err(Error::Config(format!(
                    "journal line {}: event without an `ev` tag",
                    i + 1
                )));
            }
            events.push(ev);
        }
        Ok(Journal { events })
    }

    pub fn load(path: &Path) -> Result<Journal> {
        let src = std::fs::read_to_string(path).map_err(Error::Io)?;
        Journal::from_jsonl(&src)
    }

    /// First divergence between two journals, or `None` if byte-identical.
    pub fn diff(&self, other: &Journal) -> Option<String> {
        let a = self.to_jsonl();
        let b = other.to_jsonl();
        if a == b {
            return None;
        }
        for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
            if la != lb {
                return Some(format!("event {}: `{la}` != `{lb}`", i + 1));
            }
        }
        Some(format!(
            "event counts differ: {} vs {}",
            self.events.len(),
            other.events.len()
        ))
    }

    // -- typed event constructors -----------------------------------------

    /// Leading event: the plan identity the rest of the journal hangs off.
    pub fn record_plan(&mut self, dag: &PlanDag) {
        self.push(Json::obj(vec![
            ("ev", Json::str("plan")),
            ("version", Json::num(1.0)),
            ("plan", Json::str(&dag.spec.name)),
            ("digest", Json::str(&canon::digest_hex(dag.digest))),
            ("profile", Json::str(&dag.spec.profile)),
            ("nodes", Json::num(dag.spec.nodes as f64)),
            ("seed", Json::str(&dag.spec.seed.to_string())),
            ("stages", Json::num(dag.stages.len() as f64)),
            ("waves", Json::num(dag.waves.len() as f64)),
        ]));
    }

    /// The embedded fault schedule, if the plan carries one.
    pub fn record_chaos(&mut self, sched: &ChaosSchedule) {
        self.push(Json::obj(vec![
            ("ev", Json::str("chaos")),
            ("digest", Json::str(&canon::digest_hex(sched.digest()))),
            ("events", Json::num(sched.events.len() as f64)),
            ("horizon_ns", Json::num(sched.horizon_ns as f64)),
        ]));
    }

    /// One executed stage. Only *scheduled* quantities enter: which ops ran
    /// is a compile-time fact; how many failed under injected faults is a
    /// wall-clock fact and stays in the [`super::exec::PlanReport`].
    pub fn record_stage(&mut self, idx: usize, stage: &Stage) {
        self.push(Json::obj(vec![
            ("ev", Json::str("stage")),
            ("idx", Json::num(idx as f64)),
            ("name", Json::str(&stage.name)),
            ("ops", Json::num(stage.ops_count() as f64)),
            ("ops_digest", Json::str(&canon::digest_hex(stage.ops_digest))),
        ]));
    }

    /// One applied chaos action, at its *scheduled* offset.
    pub fn record_action(&mut self, a: &AppliedAction) {
        self.push(Json::obj(vec![
            ("ev", Json::str("chaos_action")),
            ("at_ns", Json::num(a.at_ns as f64)),
            ("rail", Json::num(a.rail.0 as f64)),
            ("kind", Json::str(a.kind.name())),
            ("factor", Json::num(a.factor)),
        ]));
    }

    /// Closing event: total scheduled ops and stage count.
    pub fn record_end(&mut self, ops: u64, stages: usize) {
        self.push(Json::obj(vec![
            ("ev", Json::str("end")),
            ("ops", Json::num(ops as f64)),
            ("stages", Json::num(stages as f64)),
        ]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Journal {
        let mut j = Journal::new();
        j.push(Json::obj(vec![
            ("ev", Json::str("plan")),
            ("plan", Json::str("t")),
            ("seed", Json::str("7")),
        ]));
        j.push(Json::obj(vec![
            ("ev", Json::str("end")),
            ("ok", Json::Bool(true)),
            ("ops", Json::num(12.0)),
        ]));
        j
    }

    #[test]
    fn roundtrip_preserves_the_digest() {
        let j = sample();
        let back = Journal::from_jsonl(&j.to_jsonl()).unwrap();
        assert_eq!(j.digest(), back.digest());
        assert_eq!(j.to_jsonl(), back.to_jsonl());
        // Scrambled key order in the input still canonicalizes.
        let scrambled = "{\"seed\":\"7\",\"plan\":\"t\",\"ev\":\"plan\"}\n\
                         {\"ops\":12,\"ok\":true,\"ev\":\"end\"}\n";
        let j2 = Journal::from_jsonl(scrambled).unwrap();
        assert_eq!(j.digest(), j2.digest());
    }

    #[test]
    fn digest_is_sensitive_to_every_event() {
        let j = sample();
        let mut j2 = sample();
        j2.record_end(12, 1);
        assert_ne!(j.digest(), j2.digest());
        let d = j.diff(&j2).unwrap();
        assert!(d.contains("counts differ"), "{d}");
    }

    #[test]
    fn diff_pinpoints_the_first_divergence() {
        let j = sample();
        let mut k = Journal::new();
        k.push(j.events()[0].clone());
        k.push(Json::obj(vec![
            ("ev", Json::str("end")),
            ("ok", Json::Bool(false)),
            ("ops", Json::num(12.0)),
        ]));
        let d = j.diff(&k).unwrap();
        assert!(d.starts_with("event 2:"), "{d}");
        assert!(j.diff(&j).is_none());
    }

    #[test]
    fn rejects_untagged_lines() {
        assert!(Journal::from_jsonl("{\"no_tag\":1}\n").is_err());
        assert!(Journal::from_jsonl("not json\n").is_err());
        // Blank lines are tolerated.
        let j = Journal::from_jsonl("\n{\"ev\":\"end\"}\n\n").unwrap();
        assert_eq!(j.len(), 1);
    }
}
