//! The `.tent` plan format: a line-oriented DSL plus an equivalent
//! canonical-JSON form.
//!
//! The DSL is deliberately tiny — comments, one `plan <name>` declaration,
//! flat `key value` header lines, and two brace-stanza kinds (`workload`,
//! `chaos`). Every field the parser accepts is listed in [`PLAN_KEYS`] /
//! [`WORKLOAD_KEYS`] / [`CHAOS_KEYS`]; `tests/plan_replay.rs` enumerates
//! those tables against `docs/DSL.md`, so the spec and this file cannot
//! drift apart. All errors carry the 1-based source line (`line N: ...`).
//!
//! The canonical-JSON form ([`PlanSpec::to_json`]) flattens each stanza
//! into one object with BTreeMap-sorted keys and deterministic number
//! formatting, so equal specs serialize byte-equal — the plan digest
//! (`fnv1a64(to_json())`) identifies a plan the same way
//! `ChaosSchedule::digest` identifies a fault schedule.

use crate::engine::TransferClass;
use crate::util::cli::parse_size;
use crate::util::json::Json;
use crate::{Error, Result};

/// Plan-header fields (`key value` lines before/between stanzas).
pub const PLAN_KEYS: &[&str] = &["profile", "nodes", "seed", "time_compression", "window"];

/// Workload-stanza fields. `kind`, `class`, and `after` are structural;
/// the rest are per-kind parameters validated in `compile`.
pub const WORKLOAD_KEYS: &[&str] = &[
    "kind",
    "class",
    "after",
    "clients",
    "ops",
    "block",
    "window",
    "root",
    "payload",
    "chunk",
    "fanout",
    "rounds",
    "ranks",
    "streams",
    "latency_block",
    "bulk_block",
    "bulk_every",
    "src",
    "dst",
    "src_gpu",
    "dst_gpu",
];

/// Route-stanza fields (`route <workload> { ... }`): constraints on the
/// relay path a `staged` workload's transfers may take.
pub const ROUTE_KEYS: &[&str] = &["max_legs", "via"];

/// Chaos-stanza fields (all optional; defaults mirror
/// `chaos::ScenarioMix::default`).
pub const CHAOS_KEYS: &[&str] = &[
    "eps",
    "horizon",
    "storms",
    "storm_rails",
    "storm_outage",
    "flap_cycles",
    "flap_period",
    "slow_drains",
    "ramps",
    "max_down_fraction",
];

/// Workload-kind vocabulary accepted by `kind`.
pub const WORKLOAD_KINDS: &[&str] =
    &["hicache_fetch", "broadcast", "rl_update", "flood", "staged"];

/// Fields holding durations (accept `ns`/`us`/`ms`/`s` suffixes; stored ns).
const DURATION_KEYS: &[&str] = &["horizon", "storm_outage", "flap_period"];
/// Fields holding plain floats.
const FLOAT_KEYS: &[&str] = &["eps", "max_down_fraction", "time_compression"];

/// What a workload stanza compiles into (see `plan::compile`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum WorkloadKind {
    /// HiCache fetch storm: latency-class random-peer KV-block reads.
    HicacheFetch,
    /// Checkpoint broadcast: bulk-class chunked pushes root → peers.
    Broadcast,
    /// OrchestrRL-style parameter-update rounds: chained broadcasts.
    RlUpdate,
    /// Mixed QoS flood: interleaved latency reads + bulk pushes.
    Flood,
    /// Point-to-point staged stream `src` → `dst` (optionally device
    /// endpoints via `src_gpu`/`dst_gpu`) — the declarative k-hop relay
    /// scenario, constrainable with a `route` stanza.
    Staged,
}

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::HicacheFetch => "hicache_fetch",
            WorkloadKind::Broadcast => "broadcast",
            WorkloadKind::RlUpdate => "rl_update",
            WorkloadKind::Flood => "flood",
            WorkloadKind::Staged => "staged",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadKind> {
        Some(match s {
            "hicache_fetch" => WorkloadKind::HicacheFetch,
            "broadcast" => WorkloadKind::Broadcast,
            "rl_update" => WorkloadKind::RlUpdate,
            "flood" => WorkloadKind::Flood,
            "staged" => WorkloadKind::Staged,
            _ => return None,
        })
    }
}

/// One explicitly-set parameter, with its source line for error spans.
/// Only explicit fields are stored (defaults apply at compile time), so
/// DSL → JSON → DSL round-trips reproduce exactly what was written.
#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub key: String,
    pub value: f64,
    /// 1-based source line; 0 when the spec came from JSON.
    pub line: u32,
}

/// One `workload <name> { ... }` stanza.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    pub kind: WorkloadKind,
    /// QoS override; each kind has a natural default class.
    pub class: Option<TransferClass>,
    /// DAG dependencies: names of workloads that must complete first.
    pub after: Vec<String>,
    pub params: Vec<Param>,
    /// Source line of the stanza header.
    pub line: u32,
}

impl WorkloadSpec {
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|p| p.key == key).map(|p| p.value)
    }
}

/// One `chaos { ... }` stanza (at most one per plan).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosStanza {
    pub params: Vec<Param>,
    pub line: u32,
}

impl ChaosStanza {
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|p| p.key == key).map(|p| p.value)
    }
}

/// One `route <workload> { ... }` stanza: relay-path constraints for a
/// `staged` workload. `via` pins the exact relay-node sequence the compiled
/// plan must be able to realize; `max_legs` bounds the route search when
/// `via` is absent. Resolution against the topology happens in
/// `plan::compile` (so stanza order relative to the workload doesn't
/// matter).
#[derive(Clone, Debug, PartialEq)]
pub struct RouteSpec {
    /// Name of the staged workload this route constrains.
    pub name: String,
    /// Network-leg bound for the route search (validated 1..=3 at compile).
    pub max_legs: Option<u32>,
    /// Explicit relay nodes (intermediates only, in hop order).
    pub via: Vec<u16>,
    /// Source line of the stanza header; 0 when from JSON.
    pub line: u32,
}

/// A parsed, structurally-valid plan (resolve/compile happens in
/// `plan::compile`).
#[derive(Clone, Debug, PartialEq)]
pub struct PlanSpec {
    pub name: String,
    /// Topology profile (any name `topology::profile::build_profile` takes).
    pub profile: String,
    pub nodes: u16,
    /// Full-width u64; serialized as a string in the JSON form.
    pub seed: u64,
    /// Fabric time compression for execution (default 20.0, the fleet
    /// bench default).
    pub time_compression: f64,
    /// Default pipelining window for workloads that don't set their own.
    pub window: usize,
    pub workloads: Vec<WorkloadSpec>,
    pub chaos: Option<ChaosStanza>,
    /// Relay-route constraints, one per staged workload at most.
    pub routes: Vec<RouteSpec>,
}

impl Default for PlanSpec {
    fn default() -> Self {
        PlanSpec {
            name: String::new(),
            profile: "h800_hgx".to_string(),
            nodes: 4,
            seed: 7,
            time_compression: 20.0,
            window: 4,
            workloads: Vec::new(),
            chaos: None,
            routes: Vec::new(),
        }
    }
}

fn err(line: u32, msg: impl std::fmt::Display) -> Error {
    Error::Config(format!("line {line}: {msg}"))
}

fn parse_u64_any(s: &str) -> Option<u64> {
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse::<u64>().ok()
    }
}

/// Parse a duration with an optional `ns`/`us`/`ms`/`s` suffix into ns.
/// Bare numbers are nanoseconds.
pub fn parse_duration_ns(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = if let Some(p) = s.strip_suffix("ms") {
        (p, 1_000_000.0)
    } else if let Some(p) = s.strip_suffix("us") {
        (p, 1_000.0)
    } else if let Some(p) = s.strip_suffix("ns") {
        (p, 1.0)
    } else if let Some(p) = s.strip_suffix('s') {
        (p, 1_000_000_000.0)
    } else {
        (s, 1.0)
    };
    let v = num.trim().parse::<f64>().ok()?;
    if v < 0.0 || !v.is_finite() {
        return None;
    }
    Some((v * mult) as u64)
}

/// Parse one field value according to its key's type class.
fn parse_value(key: &str, raw: &str, line: u32) -> Result<f64> {
    if DURATION_KEYS.contains(&key) {
        return parse_duration_ns(raw)
            .map(|ns| ns as f64)
            .ok_or_else(|| err(line, format!("bad duration for `{key}`: `{raw}` (try e.g. 250ms)")));
    }
    if FLOAT_KEYS.contains(&key) {
        return raw
            .parse::<f64>()
            .ok()
            .filter(|v| v.is_finite() && *v >= 0.0)
            .ok_or_else(|| err(line, format!("bad number for `{key}`: `{raw}`")));
    }
    parse_size(raw)
        .map(|n| n as f64)
        .ok_or_else(|| err(line, format!("bad size/count for `{key}`: `{raw}` (try e.g. 256K)")))
}

fn valid_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

enum State {
    Top,
    Workload(WorkloadBuilder),
    Chaos(ChaosStanza),
    Route(RouteSpec),
}

struct WorkloadBuilder {
    name: String,
    kind: Option<WorkloadKind>,
    class: Option<TransferClass>,
    after: Vec<String>,
    params: Vec<Param>,
    line: u32,
}

impl PlanSpec {
    /// Parse either format: canonical JSON (first non-space byte `{`) or
    /// the line-oriented DSL.
    pub fn parse_any(src: &str) -> Result<PlanSpec> {
        if src.trim_start().starts_with('{') {
            PlanSpec::from_json(src)
        } else {
            PlanSpec::parse(src)
        }
    }

    /// Parse the line-oriented DSL. Errors carry `line N:` spans.
    pub fn parse(src: &str) -> Result<PlanSpec> {
        let mut spec = PlanSpec::default();
        let mut named = false;
        let mut state = State::Top;
        let mut seen_plan_keys: Vec<String> = Vec::new();

        for (i, raw) in src.lines().enumerate() {
            let line = (i + 1) as u32;
            let text = raw.split('#').next().unwrap_or("").trim();
            if text.is_empty() {
                continue;
            }
            match &mut state {
                State::Top => {
                    let (head, rest) = split_first(text);
                    match head {
                        "plan" => {
                            if named {
                                return Err(err(line, "duplicate `plan` declaration"));
                            }
                            if !valid_ident(rest) {
                                return Err(err(line, format!("bad plan name `{rest}`")));
                            }
                            spec.name = rest.to_string();
                            named = true;
                        }
                        "workload" => {
                            let (name, brace) = split_last(rest);
                            if brace != "{" || !valid_ident(name) {
                                return Err(err(line, "expected `workload <name> {`"));
                            }
                            state = State::Workload(WorkloadBuilder {
                                name: name.to_string(),
                                kind: None,
                                class: None,
                                after: Vec::new(),
                                params: Vec::new(),
                                line,
                            });
                        }
                        "chaos" => {
                            if rest != "{" {
                                return Err(err(line, "expected `chaos {`"));
                            }
                            if spec.chaos.is_some() {
                                return Err(err(line, "duplicate `chaos` stanza"));
                            }
                            state = State::Chaos(ChaosStanza {
                                params: Vec::new(),
                                line,
                            });
                        }
                        "route" => {
                            let (name, brace) = split_last(rest);
                            if brace != "{" || !valid_ident(name) {
                                return Err(err(line, "expected `route <workload> {`"));
                            }
                            if spec.routes.iter().any(|r| r.name == name) {
                                return Err(err(
                                    line,
                                    format!("duplicate `route` stanza for `{name}`"),
                                ));
                            }
                            state = State::Route(RouteSpec {
                                name: name.to_string(),
                                max_legs: None,
                                via: Vec::new(),
                                line,
                            });
                        }
                        key if PLAN_KEYS.contains(&key) => {
                            if seen_plan_keys.iter().any(|k| k == key) {
                                return Err(err(line, format!("duplicate plan field `{key}`")));
                            }
                            seen_plan_keys.push(key.to_string());
                            apply_plan_key(&mut spec, key, rest, line)?;
                        }
                        other => {
                            return Err(err(
                                line,
                                format!(
                                    "unknown plan field `{other}` (known: {})",
                                    PLAN_KEYS.join(", ")
                                ),
                            ));
                        }
                    }
                }
                State::Workload(b) => {
                    if text == "}" {
                        let b = match std::mem::replace(&mut state, State::Top) {
                            State::Workload(b) => b,
                            _ => unreachable!(),
                        };
                        let kind = b
                            .kind
                            .ok_or_else(|| err(b.line, format!("workload `{}` missing `kind`", b.name)))?;
                        spec.workloads.push(WorkloadSpec {
                            name: b.name,
                            kind,
                            class: b.class,
                            after: b.after,
                            params: b.params,
                            line: b.line,
                        });
                        continue;
                    }
                    let (key, rest) = split_first(text);
                    match key {
                        "kind" => {
                            if b.kind.is_some() {
                                return Err(err(line, "duplicate `kind`"));
                            }
                            let k = WorkloadKind::parse(rest).ok_or_else(|| {
                                err(
                                    line,
                                    format!(
                                        "unknown kind `{rest}` (known: {})",
                                        WORKLOAD_KINDS.join(", ")
                                    ),
                                )
                            })?;
                            b.kind = Some(k);
                        }
                        "class" => {
                            if b.class.is_some() {
                                return Err(err(line, "duplicate `class`"));
                            }
                            b.class = Some(parse_class(rest, line)?);
                        }
                        "after" => {
                            if !b.after.is_empty() {
                                return Err(err(line, "duplicate `after`"));
                            }
                            for dep in rest.split(',') {
                                let dep = dep.trim();
                                if !valid_ident(dep) {
                                    return Err(err(line, format!("bad dependency name `{dep}`")));
                                }
                                b.after.push(dep.to_string());
                            }
                        }
                        key if WORKLOAD_KEYS.contains(&key) => {
                            if b.params.iter().any(|p| p.key == key) {
                                return Err(err(line, format!("duplicate field `{key}`")));
                            }
                            let value = parse_value(key, rest, line)?;
                            b.params.push(Param {
                                key: key.to_string(),
                                value,
                                line,
                            });
                        }
                        other => {
                            return Err(err(
                                line,
                                format!(
                                    "unknown workload field `{other}` (known: {})",
                                    WORKLOAD_KEYS.join(", ")
                                ),
                            ));
                        }
                    }
                }
                State::Route(r) => {
                    if text == "}" {
                        let r = match std::mem::replace(&mut state, State::Top) {
                            State::Route(r) => r,
                            _ => unreachable!(),
                        };
                        spec.routes.push(r);
                        continue;
                    }
                    let (key, rest) = split_first(text);
                    match key {
                        "max_legs" => {
                            if r.max_legs.is_some() {
                                return Err(err(line, "duplicate `max_legs`"));
                            }
                            let n = parse_u64_any(rest).filter(|&n| n > 0).ok_or_else(|| {
                                err(line, format!("bad number for `max_legs`: `{rest}`"))
                            })?;
                            r.max_legs = Some(n as u32);
                        }
                        "via" => {
                            if !r.via.is_empty() {
                                return Err(err(line, "duplicate `via`"));
                            }
                            for tok in rest.split(',') {
                                let tok = tok.trim();
                                let n = parse_u64_any(tok)
                                    .filter(|&n| n <= u16::MAX as u64)
                                    .ok_or_else(|| {
                                        err(line, format!("bad relay node id `{tok}` in `via`"))
                                    })?;
                                r.via.push(n as u16);
                            }
                        }
                        other => {
                            return Err(err(
                                line,
                                format!(
                                    "unknown route field `{other}` (known: {})",
                                    ROUTE_KEYS.join(", ")
                                ),
                            ));
                        }
                    }
                }
                State::Chaos(c) => {
                    if text == "}" {
                        let c = match std::mem::replace(&mut state, State::Top) {
                            State::Chaos(c) => c,
                            _ => unreachable!(),
                        };
                        spec.chaos = Some(c);
                        continue;
                    }
                    let (key, rest) = split_first(text);
                    if !CHAOS_KEYS.contains(&key) {
                        return Err(err(
                            line,
                            format!(
                                "unknown chaos field `{key}` (known: {})",
                                CHAOS_KEYS.join(", ")
                            ),
                        ));
                    }
                    if c.params.iter().any(|p| p.key == key) {
                        return Err(err(line, format!("duplicate field `{key}`")));
                    }
                    let value = parse_value(key, rest, line)?;
                    c.params.push(Param {
                        key: key.to_string(),
                        value,
                        line,
                    });
                }
            }
        }
        match state {
            State::Top => {}
            State::Workload(b) => {
                return Err(err(b.line, format!("unclosed workload `{}` (missing `}}`)", b.name)))
            }
            State::Chaos(c) => return Err(err(c.line, "unclosed chaos stanza (missing `}`)")),
            State::Route(r) => {
                return Err(err(r.line, format!("unclosed route `{}` (missing `}}`)", r.name)))
            }
        }
        if !named {
            return Err(Error::Config("line 1: missing `plan <name>` declaration".into()));
        }
        if spec.workloads.is_empty() {
            return Err(Error::Config(format!(
                "plan `{}` declares no workloads",
                spec.name
            )));
        }
        Ok(spec)
    }

    /// Canonical JSON form: one object, BTreeMap-sorted keys, stanza params
    /// flattened. Equal specs serialize byte-equal, so
    /// `canon::fnv1a64(to_json())` is the plan identity.
    pub fn to_json(&self) -> String {
        let workloads = self
            .workloads
            .iter()
            .map(|w| {
                let mut pairs: Vec<(&str, Json)> = vec![
                    ("name", Json::str(&w.name)),
                    ("kind", Json::str(w.kind.name())),
                ];
                if let Some(c) = w.class {
                    pairs.push(("class", Json::str(c.name())));
                }
                if !w.after.is_empty() {
                    pairs.push(("after", Json::arr(w.after.iter().map(|a| Json::str(a)))));
                }
                for p in &w.params {
                    pairs.push((p.key.as_str(), Json::num(p.value)));
                }
                Json::obj(pairs)
            })
            .collect::<Vec<_>>();
        let mut pairs: Vec<(&str, Json)> = vec![
            ("version", Json::num(1.0)),
            ("plan", Json::str(&self.name)),
            ("profile", Json::str(&self.profile)),
            ("nodes", Json::num(self.nodes as f64)),
            // Full-width u64 seeds survive the f64 JSON number type as text
            // (same convention as ChaosSchedule::to_json).
            ("seed", Json::str(&self.seed.to_string())),
            ("time_compression", Json::num(self.time_compression)),
            ("window", Json::num(self.window as f64)),
            ("workloads", Json::arr(workloads)),
        ];
        if let Some(c) = &self.chaos {
            pairs.push((
                "chaos",
                Json::obj(c.params.iter().map(|p| (p.key.as_str(), Json::num(p.value))).collect()),
            ));
        }
        // `routes` only when present, so pre-existing plans keep their
        // digests.
        if !self.routes.is_empty() {
            pairs.push((
                "routes",
                Json::arr(self.routes.iter().map(|r| {
                    let mut rp: Vec<(&str, Json)> = vec![("name", Json::str(&r.name))];
                    if let Some(m) = r.max_legs {
                        rp.push(("max_legs", Json::num(m as f64)));
                    }
                    if !r.via.is_empty() {
                        rp.push(("via", Json::arr(r.via.iter().map(|&n| Json::num(n as f64)))));
                    }
                    Json::obj(rp)
                })),
            ));
        }
        Json::obj(pairs).to_string()
    }

    /// Parse the canonical JSON form. Field vocabulary is validated against
    /// the same key tables as the DSL; spans degrade to `line 0`.
    pub fn from_json(src: &str) -> Result<PlanSpec> {
        let j = Json::parse(src).map_err(|e| Error::Config(format!("plan json: {e}")))?;
        let mut spec = PlanSpec {
            name: j
                .get("plan")
                .as_str()
                .ok_or_else(|| Error::Config("plan json: missing `plan` name".into()))?
                .to_string(),
            ..PlanSpec::default()
        };
        if let Some(p) = j.get("profile").as_str() {
            spec.profile = p.to_string();
        }
        if let Some(n) = j.get("nodes").as_u64() {
            spec.nodes = clamp_nodes(n, 0)?;
        }
        if let Some(s) = j.get("seed").as_str() {
            spec.seed = parse_u64_any(s)
                .ok_or_else(|| Error::Config(format!("plan json: bad seed `{s}`")))?;
        } else if let Some(s) = j.get("seed").as_u64() {
            spec.seed = s;
        }
        if let Some(t) = j.get("time_compression").as_f64() {
            spec.time_compression = t;
        }
        if let Some(w) = j.get("window").as_u64() {
            spec.window = w as usize;
        }
        let workloads = j
            .get("workloads")
            .as_arr()
            .ok_or_else(|| Error::Config("plan json: missing `workloads` array".into()))?;
        for (i, wj) in workloads.iter().enumerate() {
            let obj = wj
                .as_obj()
                .ok_or_else(|| Error::Config(format!("plan json: workload {i} is not an object")))?;
            let name = wj
                .get("name")
                .as_str()
                .ok_or_else(|| Error::Config(format!("plan json: workload {i} missing `name`")))?
                .to_string();
            let kind = wj
                .get("kind")
                .as_str()
                .and_then(WorkloadKind::parse)
                .ok_or_else(|| {
                    Error::Config(format!("plan json: workload `{name}` has a bad `kind`"))
                })?;
            let class = match wj.get("class").as_str() {
                Some(c) => Some(parse_class(c, 0)?),
                None => None,
            };
            let mut after = Vec::new();
            if let Some(deps) = wj.get("after").as_arr() {
                for d in deps {
                    after.push(
                        d.as_str()
                            .ok_or_else(|| {
                                Error::Config(format!(
                                    "plan json: workload `{name}` has a non-string `after` entry"
                                ))
                            })?
                            .to_string(),
                    );
                }
            }
            let mut params = Vec::new();
            for (key, val) in obj {
                if matches!(key.as_str(), "name" | "kind" | "class" | "after") {
                    continue;
                }
                if !WORKLOAD_KEYS.contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "plan json: workload `{name}`: unknown field `{key}` (known: {})",
                        WORKLOAD_KEYS.join(", ")
                    )));
                }
                let value = val.as_f64().ok_or_else(|| {
                    Error::Config(format!("plan json: workload `{name}`: `{key}` is not a number"))
                })?;
                params.push(Param {
                    key: key.clone(),
                    value,
                    line: 0,
                });
            }
            spec.workloads.push(WorkloadSpec {
                name,
                kind,
                class,
                after,
                params,
                line: 0,
            });
        }
        if spec.workloads.is_empty() {
            return Err(Error::Config(format!(
                "plan `{}` declares no workloads",
                spec.name
            )));
        }
        if let Some(cj) = j.get("chaos").as_obj() {
            let mut params = Vec::new();
            for (key, val) in cj {
                if !CHAOS_KEYS.contains(&key.as_str()) {
                    return Err(Error::Config(format!(
                        "plan json: chaos: unknown field `{key}` (known: {})",
                        CHAOS_KEYS.join(", ")
                    )));
                }
                let value = val.as_f64().ok_or_else(|| {
                    Error::Config(format!("plan json: chaos: `{key}` is not a number"))
                })?;
                params.push(Param {
                    key: key.clone(),
                    value,
                    line: 0,
                });
            }
            spec.chaos = Some(ChaosStanza { params, line: 0 });
        }
        if let Some(routes) = j.get("routes").as_arr() {
            for (i, rj) in routes.iter().enumerate() {
                let obj = rj.as_obj().ok_or_else(|| {
                    Error::Config(format!("plan json: route {i} is not an object"))
                })?;
                let name = rj
                    .get("name")
                    .as_str()
                    .ok_or_else(|| Error::Config(format!("plan json: route {i} missing `name`")))?
                    .to_string();
                if spec.routes.iter().any(|r| r.name == name) {
                    return Err(Error::Config(format!(
                        "plan json: duplicate route for `{name}`"
                    )));
                }
                for (key, _) in obj {
                    if key != "name" && !ROUTE_KEYS.contains(&key.as_str()) {
                        return Err(Error::Config(format!(
                            "plan json: route `{name}`: unknown field `{key}` (known: {})",
                            ROUTE_KEYS.join(", ")
                        )));
                    }
                }
                let max_legs = match rj.get("max_legs").as_u64() {
                    Some(0) => {
                        return Err(Error::Config(format!(
                            "plan json: route `{name}`: `max_legs` must be > 0"
                        )))
                    }
                    Some(m) => Some(m as u32),
                    None => None,
                };
                let mut via = Vec::new();
                if let Some(hops) = rj.get("via").as_arr() {
                    for h in hops {
                        let n = h.as_u64().filter(|&n| n <= u16::MAX as u64).ok_or_else(|| {
                            Error::Config(format!(
                                "plan json: route `{name}`: bad `via` node id"
                            ))
                        })?;
                        via.push(n as u16);
                    }
                }
                spec.routes.push(RouteSpec {
                    name,
                    max_legs,
                    via,
                    line: 0,
                });
            }
        }
        Ok(spec)
    }
}

fn clamp_nodes(n: u64, line: u32) -> Result<u16> {
    if n == 0 || n > u16::MAX as u64 {
        return Err(err(line, format!("`nodes` out of range: {n}")));
    }
    Ok(n as u16)
}

fn parse_class(s: &str, line: u32) -> Result<TransferClass> {
    match s {
        "latency" => Ok(TransferClass::Latency),
        "bulk" => Ok(TransferClass::Bulk),
        other => Err(err(
            line,
            format!("unknown class `{other}` (expected `latency` or `bulk`)"),
        )),
    }
}

fn apply_plan_key(spec: &mut PlanSpec, key: &str, rest: &str, line: u32) -> Result<()> {
    match key {
        "profile" => {
            if !valid_ident(rest) {
                return Err(err(line, format!("bad profile name `{rest}`")));
            }
            spec.profile = rest.to_string();
        }
        "nodes" => {
            let n = parse_u64_any(rest)
                .ok_or_else(|| err(line, format!("bad number for `nodes`: `{rest}`")))?;
            spec.nodes = clamp_nodes(n, line)?;
        }
        "seed" => {
            spec.seed = parse_u64_any(rest)
                .ok_or_else(|| err(line, format!("bad number for `seed`: `{rest}`")))?;
        }
        "time_compression" => {
            spec.time_compression = parse_value(key, rest, line)?;
            if spec.time_compression <= 0.0 {
                return Err(err(line, "`time_compression` must be > 0"));
            }
        }
        "window" => {
            let w = parse_u64_any(rest)
                .ok_or_else(|| err(line, format!("bad number for `window`: `{rest}`")))?;
            if w == 0 || w > 1024 {
                return Err(err(line, format!("`window` out of range: {w}")));
            }
            spec.window = w as usize;
        }
        _ => unreachable!("caller checks PLAN_KEYS"),
    }
    Ok(())
}

impl PlanSpec {
    /// Cap the embedded chaos horizon (the CLI's and bench's `--smoke`
    /// mode), so the injector thread never dominates CI wall clock. No-op
    /// without a `chaos` stanza. Mutating the spec changes the plan digest
    /// — smoke journals are not comparable to full-run journals.
    pub fn cap_chaos_horizon(&mut self, max_ns: f64) {
        if let Some(c) = self.chaos.as_mut() {
            match c.params.iter_mut().find(|p| p.key == "horizon") {
                Some(p) => p.value = p.value.min(max_ns),
                None if max_ns < 250_000_000.0 => c.params.push(Param {
                    key: "horizon".into(),
                    value: max_ns,
                    line: 0,
                }),
                None => {}
            }
        }
    }
}

/// Split off the first whitespace-delimited token; the rest is trimmed.
fn split_first(s: &str) -> (&str, &str) {
    match s.split_once(char::is_whitespace) {
        Some((a, b)) => (a, b.trim()),
        None => (s, ""),
    }
}

/// Split off the last whitespace-delimited token; the head is trimmed.
fn split_last(s: &str) -> (&str, &str) {
    match s.rsplit_once(char::is_whitespace) {
        Some((a, b)) => (a.trim(), b),
        None => ("", s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
# smallest useful plan
plan mini
nodes 2
seed 11

workload fetch {
  kind hicache_fetch
  clients 2
  ops 4
  block 64K
}
"#;

    #[test]
    fn parses_the_minimal_plan() {
        let p = PlanSpec::parse(MINI).unwrap();
        assert_eq!(p.name, "mini");
        assert_eq!(p.nodes, 2);
        assert_eq!(p.seed, 11);
        assert_eq!(p.profile, "h800_hgx"); // default
        assert_eq!(p.workloads.len(), 1);
        let w = &p.workloads[0];
        assert_eq!(w.kind, WorkloadKind::HicacheFetch);
        assert_eq!(w.param("block"), Some(65536.0));
        assert_eq!(w.param("clients"), Some(2.0));
        assert_eq!(w.line, 7);
    }

    #[test]
    fn json_roundtrip_is_byte_identical() {
        let p = PlanSpec::parse(MINI).unwrap();
        let j = p.to_json();
        let q = PlanSpec::from_json(&j).unwrap();
        assert_eq!(j, q.to_json());
        // parse_any auto-detects both forms.
        assert_eq!(PlanSpec::parse_any(&j).unwrap().to_json(), j);
        assert_eq!(PlanSpec::parse_any(MINI).unwrap().to_json(), j);
    }

    #[test]
    fn errors_carry_line_spans() {
        let bad = "plan p\nworkload w {\n  kind hicache_fetch\n  blocc 4\n}\n";
        let e = PlanSpec::parse(bad).unwrap_err().to_string();
        assert!(e.contains("line 4"), "{e}");
        assert!(e.contains("blocc"), "{e}");

        let typo = "plan p\nworkload w {\n  kind hicache_fetch\n  class latnecy\n}\n";
        let e = PlanSpec::parse(typo).unwrap_err().to_string();
        assert!(e.contains("line 4") && e.contains("latnecy"), "{e}");

        let unclosed = "plan p\nworkload w {\n  kind flood\n";
        let e = PlanSpec::parse(unclosed).unwrap_err().to_string();
        assert!(e.contains("line 2") && e.contains("unclosed"), "{e}");
    }

    #[test]
    fn durations_and_sizes_parse() {
        assert_eq!(parse_duration_ns("250ms"), Some(250_000_000));
        assert_eq!(parse_duration_ns("2s"), Some(2_000_000_000));
        assert_eq!(parse_duration_ns("500us"), Some(500_000));
        assert_eq!(parse_duration_ns("42"), Some(42));
        assert_eq!(parse_duration_ns("1.5ms"), Some(1_500_000));
        assert_eq!(parse_duration_ns("-1ms"), None);
        assert_eq!(parse_duration_ns("x"), None);
    }

    #[test]
    fn chaos_stanza_and_after_deps() {
        let src = "plan p\nnodes 4\nworkload a {\n kind broadcast\n payload 1M\n}\n\
                   workload b {\n kind flood\n after a\n ops 8\n}\nchaos {\n eps 2\n horizon 100ms\n}\n";
        let p = PlanSpec::parse(src).unwrap();
        assert_eq!(p.workloads[1].after, vec!["a"]);
        let c = p.chaos.as_ref().unwrap();
        assert_eq!(c.param("eps"), Some(2.0));
        assert_eq!(c.param("horizon"), Some(100_000_000.0));
        // Round-trip keeps the chaos stanza.
        let q = PlanSpec::from_json(&p.to_json()).unwrap();
        assert_eq!(q.to_json(), p.to_json());
        assert!(q.chaos.is_some());
    }

    #[test]
    fn staged_workload_and_route_stanza_parse_and_round_trip() {
        let src = "plan relay\nprofile silo_fleet\nnodes 3\nworkload push {\n kind staged\n \
                   src 0\n dst 1\n src_gpu 0\n payload 1M\n chunk 128K\n}\n\
                   route push {\n max_legs 2\n via 2\n}\n";
        let p = PlanSpec::parse(src).unwrap();
        let w = &p.workloads[0];
        assert_eq!(w.kind, WorkloadKind::Staged);
        assert_eq!(w.param("src"), Some(0.0));
        assert_eq!(w.param("dst"), Some(1.0));
        assert_eq!(p.routes.len(), 1);
        let r = &p.routes[0];
        assert_eq!(r.name, "push");
        assert_eq!(r.max_legs, Some(2));
        assert_eq!(r.via, vec![2]);
        // JSON round-trip carries the route stanza byte-identically.
        let j = p.to_json();
        assert!(j.contains("\"routes\""), "{j}");
        let q = PlanSpec::from_json(&j).unwrap();
        assert_eq!(q.to_json(), j);
        assert_eq!(q.routes, p.routes.iter().map(|r| RouteSpec { line: 0, ..r.clone() }).collect::<Vec<_>>());
        // Plans without routes keep their old serialization (digest
        // stability for the shipped corpus).
        assert!(!PlanSpec::parse(MINI).unwrap().to_json().contains("routes"));
    }

    #[test]
    fn route_stanza_rejects_mistakes() {
        let dup = "plan p\nworkload w {\n kind staged\n src 0\n dst 1\n}\n\
                   route w {\n via 2\n}\nroute w {\n via 3\n}\n";
        let e = PlanSpec::parse(dup).unwrap_err().to_string();
        assert!(e.contains("line 9") && e.contains("duplicate"), "{e}");

        let badkey = "plan p\nworkload w {\n kind staged\n}\nroute w {\n hops 2\n}\n";
        let e = PlanSpec::parse(badkey).unwrap_err().to_string();
        assert!(e.contains("line 6") && e.contains("max_legs"), "{e}");

        let unclosed = "plan p\nworkload w {\n kind staged\n}\nroute w {\n via 2\n";
        let e = PlanSpec::parse(unclosed).unwrap_err().to_string();
        assert!(e.contains("line 5") && e.contains("unclosed route"), "{e}");
    }

    #[test]
    fn rejects_structural_mistakes() {
        assert!(PlanSpec::parse("workload w {\n kind flood\n}\n").is_err(), "no plan name");
        assert!(PlanSpec::parse("plan p\n").is_err(), "no workloads");
        let dup = "plan p\nnodes 2\nnodes 4\nworkload w {\n kind flood\n}\n";
        assert!(PlanSpec::parse(dup).unwrap_err().to_string().contains("line 3"));
        let badkind = "plan p\nworkload w {\n kind warp\n}\n";
        assert!(PlanSpec::parse(badkind).unwrap_err().to_string().contains("warp"));
    }
}
