//! Minimal logging facade mirroring the `log` crate's macro surface.
//!
//! The offline vendor set has no `log` crate, so this module provides the
//! same call shape — `log::info!("...")` after a `use crate::log;` — backed
//! by a single atomic max-level and a stderr sink (installed by
//! [`crate::util::logging::init`]). Until `init` runs, the level is `Off`
//! and every macro call is a cheap atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first (mirrors `log::Level`).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Level {
    type Err = ();
    fn from_str(s: &str) -> Result<Level, ()> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            "trace" => Ok(Level::Trace),
            _ => Err(()),
        }
    }
}

/// 0 = off (the default until `util::logging::init` is called).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Set the maximum level that will be emitted.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Pure gating rule: emit iff the record's level is at most `max` (0 = off).
#[inline]
fn gate(level: Level, max: u8) -> bool {
    level as u8 <= max
}

/// Would a record at `level` be emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    gate(level, MAX_LEVEL.load(Ordering::Relaxed))
}

/// Macro backend: format and write one record to stderr. Not intended to be
/// called directly — use the `log::error!` … `log::trace!` macros.
pub fn __log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = crate::util::clock::now_ns() as f64 / 1e9;
    eprintln!(
        "[{t:10.4}s {:5} {}] {}",
        level,
        target.split("::").last().unwrap_or(""),
        args
    );
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::log::__log($crate::log::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::log::__log($crate::log::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::log::__log($crate::log::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::log::__log($crate::log::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::log::__log($crate::log::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

// Make the macros addressable as `log::info!` etc. after `use crate::log;`
// (or `use tent::log;` from the bin/examples), matching the real crate.
pub use crate::{debug, error, info, trace, warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_order() {
        assert_eq!("info".parse::<Level>(), Ok(Level::Info));
        assert_eq!("WARN".parse::<Level>(), Ok(Level::Warn));
        assert!("verbose".parse::<Level>().is_err());
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn gate_is_monotone_in_level() {
        // Pure rule — safe against other tests mutating the global level.
        assert!(gate(Level::Error, Level::Warn as u8));
        assert!(gate(Level::Warn, Level::Warn as u8));
        assert!(!gate(Level::Debug, Level::Warn as u8));
        assert!(gate(Level::Trace, Level::Trace as u8));
        assert!(!gate(Level::Error, 0)); // off until init
    }

    #[test]
    fn macros_expand_and_run() {
        use crate::log;
        // No assertions on the (test-shared) global level — just prove the
        // macros expand, format, and route through __log without panicking.
        set_max_level(Level::Error);
        log::debug!("usually invisible {}", 1 + 1);
        log::error!("visible smoke record: {}", "ok");
    }
}
