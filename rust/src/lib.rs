//! # TENT — a declarative slice-spraying data-movement engine
//!
//! Reproduction of *"TENT: A Declarative Slice Spraying Engine for Performant
//! and Resilient Data Movement in Disaggregated LLM Serving"* (CS.DC 2026).
//!
//! TENT decouples transfer *intent* from physical *execution*: applications
//! declare batched transfers between [`segment::Segment`]s, and the engine
//! decides — per request, at runtime — how to realize each transfer across a
//! pool of heterogeneous interconnects. Elephant flows are decomposed into
//! fine-grained slices that are "sprayed" across rails according to a
//! telemetry-driven cost model (Algorithm 1 of the paper), with dual-layer
//! resilience (per-slice rerouting + whole-backend substitution) embedded in
//! the data plane.
//!
//! ## Layering
//!
//! * [`engine`] — the paper's contribution: batch API, Phase-1 dynamic
//!   orchestration, Phase-2 telemetry-driven slice spraying, Phase-3
//!   dual-layer resilience, and the low-overhead lock-free datapath (§4.4).
//! * [`topology`], [`segment`], [`fabric`], [`transport`] — the substrates:
//!   device/tier model, unified segment abstraction, the simulated multi-rail
//!   fabric (real byte movement, paced to scaled hardware profiles), and thin
//!   pluggable transport backends.
//! * [`policy`] — scheduling policies, including faithful re-implementations
//!   of the paper's baselines (Mooncake TE, NIXL, UCCL-P2P, round-robin).
//! * [`chaos`] — the trace-driven chaos harness: deterministic fault
//!   schedules (Table 1 trace + correlated scenarios) replayed against a
//!   live fleet, with end-to-end healing-latency instrumentation and the
//!   sub-50 ms self-healing acceptance gate (§6.3).
//! * [`serving`], [`runtime`] — the disaggregated-LLM-serving consumer: a
//!   HiCache-style multi-tier KV cache, request router, checkpoint-engine
//!   analog, all generic over a `ModelExecutor` — the deterministic
//!   synthetic model (artifact-free, tier-1) or the PJRT runner for the
//!   AOT-compiled JAX/Pallas artifacts.
//! * [`bench`] — TEBench, the microbenchmark harness of §5.1.3.
//! * [`util`] — dependency-free building blocks (PRNG, histograms, EWMA,
//!   JSON, lock-free MPSC ring, CLI).
//!
//! ## Quickstart
//!
//! This example runs as a doctest — `cargo test --doc` actually moves the
//! megabyte across the simulated 8-rail fabric:
//!
//! ```
//! use tent::cluster::Cluster;
//! use tent::engine::{TentEngine, EngineConfig, TransferReq};
//! use tent::segment::Location;
//!
//! let cluster = Cluster::from_profile("h800_hgx").unwrap();
//! let engine = TentEngine::new(&cluster, EngineConfig::default()).unwrap();
//! let src = engine.register_segment(Location::host(0, 0), 1 << 20).unwrap();
//! let dst = engine.register_segment(Location::host(1, 0), 1 << 20).unwrap();
//! let batch = engine.allocate_batch();
//! engine.submit(batch, &[TransferReq::write(src, 0, dst, 0, 1 << 20)]).unwrap();
//! // `wait` errors if any transfer in the batch failed — no status-checking
//! // needed after a successful return.
//! engine.wait(batch, std::time::Duration::from_secs(30)).unwrap();
//! ```

pub mod log;
pub mod util;
pub mod topology;
pub mod segment;
pub mod fabric;
pub mod transport;
pub mod engine;
pub mod policy;
pub mod cluster;
pub mod chaos;
pub mod plan;
pub mod runtime;
pub mod serving;
pub mod bench;

pub use cluster::Cluster;
pub use engine::{EngineConfig, TentEngine};

/// Library-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
///
/// Display/From are hand-implemented (`thiserror` is not in the offline
/// vendor set); the messages match the originals one-for-one.
#[derive(Debug)]
pub enum Error {
    /// No device is eligible to carry a slice (Algorithm 1, line 2).
    NoEligibleDevice(String),
    /// A segment id was not found in the segment manager.
    UnknownSegment(u64),
    /// Out-of-bounds access into a segment.
    OutOfBounds(String),
    /// A batch id was not found or already reaped.
    UnknownBatch(u64),
    /// The transfer failed on all candidate paths after retries.
    TransferFailed(String),
    /// Waiting for a batch exceeded the caller's deadline.
    Timeout(u64),
    /// Engine is shutting down.
    Shutdown,
    /// Configuration / profile errors.
    Config(String),
    /// I/O error (file backend, TCP backend, artifact loading).
    Io(std::io::Error),
    /// PJRT runtime error.
    Runtime(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::NoEligibleDevice(s) => write!(f, "no eligible device for transfer: {s}"),
            Error::UnknownSegment(id) => write!(f, "unknown segment {id}"),
            Error::OutOfBounds(s) => write!(f, "segment range out of bounds: {s}"),
            Error::UnknownBatch(id) => write!(f, "unknown batch {id}"),
            Error::TransferFailed(s) => write!(f, "transfer failed permanently: {s}"),
            Error::Timeout(id) => write!(f, "timed out waiting for batch {id}"),
            Error::Shutdown => write!(f, "engine shut down"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}
