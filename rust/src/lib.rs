//! # TENT — a declarative slice-spraying data-movement engine
//!
//! Reproduction of *"TENT: A Declarative Slice Spraying Engine for Performant
//! and Resilient Data Movement in Disaggregated LLM Serving"* (CS.DC 2026).
//!
//! TENT decouples transfer *intent* from physical *execution*: applications
//! declare batched transfers between [`segment::Segment`]s, and the engine
//! decides — per request, at runtime — how to realize each transfer across a
//! pool of heterogeneous interconnects. Elephant flows are decomposed into
//! fine-grained slices that are "sprayed" across rails according to a
//! telemetry-driven cost model (Algorithm 1 of the paper), with dual-layer
//! resilience (per-slice rerouting + whole-backend substitution) embedded in
//! the data plane.
//!
//! ## Layering
//!
//! * [`engine`] — the paper's contribution: batch API, Phase-1 dynamic
//!   orchestration, Phase-2 telemetry-driven slice spraying, Phase-3
//!   dual-layer resilience, and the low-overhead lock-free datapath (§4.4).
//! * [`topology`], [`segment`], [`fabric`], [`transport`] — the substrates:
//!   device/tier model, unified segment abstraction, the simulated multi-rail
//!   fabric (real byte movement, paced to scaled hardware profiles), and thin
//!   pluggable transport backends.
//! * [`policy`] — scheduling policies, including faithful re-implementations
//!   of the paper's baselines (Mooncake TE, NIXL, UCCL-P2P, round-robin).
//! * [`serving`], [`runtime`] — the disaggregated-LLM-serving consumer: a
//!   HiCache-style multi-tier KV cache, request router, PJRT model runner
//!   (AOT-compiled JAX/Pallas artifacts), and a checkpoint-engine analog.
//! * [`bench`] — TEBench, the microbenchmark harness of §5.1.3.
//! * [`util`] — dependency-free building blocks (PRNG, histograms, EWMA,
//!   JSON, lock-free MPSC ring, CLI).
//!
//! ## Quickstart
//!
//! ```no_run
//! use tent::cluster::Cluster;
//! use tent::engine::{TentEngine, EngineConfig, TransferOp, TransferReq};
//! use tent::segment::Location;
//!
//! let cluster = Cluster::from_profile("h800_hgx").unwrap();
//! let engine = TentEngine::new(&cluster, EngineConfig::default()).unwrap();
//! let src = engine.register_segment(Location::host(0, 0), 1 << 20).unwrap();
//! let dst = engine.register_segment(Location::host(1, 0), 1 << 20).unwrap();
//! let batch = engine.allocate_batch();
//! engine.submit(batch, &[TransferReq::write(src, 0, dst, 0, 1 << 20)]).unwrap();
//! engine.wait(batch, std::time::Duration::from_secs(5)).unwrap();
//! ```

pub mod util;
pub mod topology;
pub mod segment;
pub mod fabric;
pub mod transport;
pub mod engine;
pub mod policy;
pub mod cluster;
pub mod runtime;
pub mod serving;
pub mod bench;

pub use cluster::Cluster;
pub use engine::{EngineConfig, TentEngine};

/// Library-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// No device is eligible to carry a slice (Algorithm 1, line 2).
    #[error("no eligible device for transfer: {0}")]
    NoEligibleDevice(String),
    /// A segment id was not found in the segment manager.
    #[error("unknown segment {0}")]
    UnknownSegment(u64),
    /// Out-of-bounds access into a segment.
    #[error("segment range out of bounds: {0}")]
    OutOfBounds(String),
    /// A batch id was not found or already reaped.
    #[error("unknown batch {0}")]
    UnknownBatch(u64),
    /// The transfer failed on all candidate paths after retries.
    #[error("transfer failed permanently: {0}")]
    TransferFailed(String),
    /// Waiting for a batch exceeded the caller's deadline.
    #[error("timed out waiting for batch {0}")]
    Timeout(u64),
    /// Engine is shutting down.
    #[error("engine shut down")]
    Shutdown,
    /// Configuration / profile errors.
    #[error("config error: {0}")]
    Config(String),
    /// I/O error (file backend, TCP backend, artifact loading).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// PJRT runtime error.
    #[error("runtime error: {0}")]
    Runtime(String),
}
