//! `tentd` — the TENT coordinator CLI.
//!
//! Subcommands:
//!   topo        dump the discovered topology of a cluster profile
//!   bench       run a TEBench microbenchmark
//!   plan        compile + execute a declarative transfer plan (.tent or
//!               canonical JSON; see docs/DSL.md) with a replay journal
//!   serve       run the multi-turn serving workload (synthetic model by
//!               default; --model pjrt for the AOT-artifact path)
//!   checkpoint  run a checkpoint-engine weight update + model install
//!   failover    run a live failure-injection demo
//!
//! Common flags: --profile <name> --policy <tent|mooncake|nixl|uccl|rr>
//!               --nodes <n> --seed <n>
//! See `tentd help` for per-command flags.

use std::sync::Arc;
use std::time::Duration;

use tent::bench::{self, TeBenchConfig, ThreadPair};
use tent::cluster::Cluster;
use tent::log;
use tent::engine::{EngineConfig, TentEngine};
use tent::policy::PolicyKind;
use tent::runtime::{make_executor, ModelSelect};
use tent::segment::Location;
use tent::serving::{CheckpointConfig, CheckpointEngine, ServeConfig, ServeMode};
use tent::util::cli::Args;
use tent::util::{fmt_bw, fmt_bytes};

const HELP: &str = r#"tentd — TENT: declarative slice-spraying transfer engine

USAGE: tentd <command> [flags]

COMMANDS:
  topo        Dump topology: tentd topo --profile h800_hgx --nodes 2
  bench       TEBench: tentd bench --profile h800_hgx --policy tent \
                --block 1M --batch 4 --threads 4 --iters 16 \
                --src host --dst host
  plan        Declarative transfer plan (docs/DSL.md, plans/*.tent):
                tentd plan plans/hicache_storm.tent [--seed N] [--check]
                  [--journal out.jsonl] [--verify <digest>] [--json] [--smoke]
              --check compiles and prints the stage DAG without running;
              --verify exits 1 unless the journal digest matches;
              --smoke caps the embedded chaos horizon for CI
  serve       Multi-turn serving (no artifacts needed — synthetic model):
                tentd serve --mode hicache --policy tent --clients 4 --turns 3 \
                  [--model synthetic|pjrt|auto]
  checkpoint  Weight update + in-place model install:
                tentd checkpoint --ranks 8 [--payload 16M]
  failover    Failure injection demo: tentd failover --fail-at 500 --recover-at 1500

COMMON FLAGS:
  --profile <name>      h800_hgx | h800_no_nvlink | no_gpudirect | mnnvl_rack |
                        ascend_ub | legacy_tcp | mixed_fleet   [h800_hgx]
  --profile-file <path> custom fleet description (JSON; see
                        rust/src/topology/json_profile.rs for the schema)
  --policy <name>    tent | mooncake | nixl | uccl | rr        [tent]
  --nodes <n>        node count                                 [2]
  --verbose          info-level logging
"#;

fn main() {
    let args = Args::from_env();
    tent::util::logging::init(if args.flag("verbose") {
        log::Level::Info
    } else {
        log::Level::Warn
    });
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "topo" => cmd_topo(&args),
        "bench" => cmd_bench(&args),
        "plan" => cmd_plan(&args),
        "serve" => cmd_serve(&args),
        "checkpoint" => cmd_checkpoint(&args),
        "failover" => cmd_failover(&args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn make_engine(args: &Args) -> tent::Result<(Cluster, Arc<TentEngine>)> {
    let policy = PolicyKind::parse(&args.get_str("policy", "tent"))
        .ok_or_else(|| tent::Error::Config("unknown --policy".into()))?;
    // --profile-file <path.json> loads a custom fleet description;
    // otherwise --profile names a built-in.
    let cluster = match args.get("profile-file") {
        Some(path) => Cluster::from_profile_file(path, tent::fabric::FabricConfig::default())?,
        None => Cluster::from_profile_nodes(
            &args.get_str("profile", "h800_hgx"),
            args.get_u64("nodes", 2) as u16,
            tent::fabric::FabricConfig::default(),
        )?,
    };
    let engine = Arc::new(TentEngine::new(&cluster, EngineConfig::with_policy(policy))?);
    Ok((cluster, engine))
}

fn cmd_topo(args: &Args) -> tent::Result<()> {
    let profile = args.get_str("profile", "h800_hgx");
    let nodes = args.get_u64("nodes", 2) as u16;
    let topo = tent::topology::profile::build_profile(&profile, nodes)?;
    print!("{}", topo.describe());
    Ok(())
}

fn parse_loc(kind: &str, node: u16, idx: u8) -> Location {
    match kind {
        "gpu" | "device" => Location::device(node, idx),
        _ => Location::host(node, idx % 2),
    }
}

fn cmd_bench(args: &Args) -> tent::Result<()> {
    let (_cluster, engine) = make_engine(args)?;
    let block = args.get_u64("block", 1 << 20);
    let batch = args.get_usize("batch", 1);
    let threads = args.get_usize("threads", 4);
    let iters = args.get_usize("iters", 16);
    let src_kind = args.get_str("src", "host");
    let dst_kind = args.get_str("dst", "host");
    let seg_len = (block * batch as u64 * 4).max(8 << 20);
    let pairs: Vec<ThreadPair> = (0..threads)
        .map(|i| {
            let src = engine.register_segment(parse_loc(&src_kind, 0, (i % 8) as u8), seg_len)?;
            let dst = engine.register_segment(parse_loc(&dst_kind, 1, (i % 8) as u8), seg_len)?;
            Ok(ThreadPair { src, dst, seg_len })
        })
        .collect::<tent::Result<_>>()?;
    let cfg = TeBenchConfig {
        block_size: block,
        batch_size: batch,
        iters,
        ..Default::default()
    };
    println!("{}", bench::header());
    let r = bench::run(&engine, &pairs, &cfg)?;
    println!(
        "{}",
        bench::fmt_row(&format!("{}x{}", fmt_bytes(block), batch), &r)
    );
    println!("\nper-rail state:");
    println!(
        "  {:<14} {:<8} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "rail", "fabric", "bytes", "slices", "p50", "p99", "b1"
    );
    for snap in engine.rail_snapshots() {
        if snap.bytes_carried > 0 {
            println!(
                "  {:<14} {:<8} {:>12} {:>8} {:>12} {:>12} {:>8.2}",
                snap.name,
                snap.fabric,
                fmt_bytes(snap.bytes_carried),
                snap.slices_ok,
                tent::util::fmt_ns(snap.p50_ns),
                tent::util::fmt_ns(snap.p99_ns),
                snap.beta1,
            );
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> tent::Result<()> {
    let path = args.positional.get(1).cloned().ok_or_else(|| {
        tent::Error::Config(
            "usage: tentd plan <file.tent|file.json> [--seed N] [--check] \
             [--journal out.jsonl] [--verify <digest>] [--json] [--smoke]"
                .into(),
        )
    })?;
    let src = std::fs::read_to_string(&path).map_err(tent::Error::Io)?;
    let mut spec = tent::plan::PlanSpec::parse_any(&src)?;
    spec.seed = args.get_u64("seed", spec.seed);
    if args.flag("smoke") {
        spec.cap_chaos_horizon(100_000_000.0);
    }
    let dag = tent::plan::compile(&spec)?;
    if args.flag("check") {
        print!("{}", dag.describe());
        return Ok(());
    }
    let fleet = tent::plan::fleet_for(&spec)?;
    let report = fleet.run_plan(&dag)?;
    println!("{}", report.header());
    print!("{}", report.table());
    if args.flag("json") {
        println!("{}", report.to_json());
    }
    if let Some(out) = args.get("journal") {
        report.journal.save(std::path::Path::new(out))?;
        println!("journal: {out} ({} events)", report.journal.len());
    }
    if let Some(want) = args.get("verify") {
        let got = report.journal.digest_hex();
        if got != *want {
            eprintln!("verify FAILED: journal digest {got} != expected {want}");
            std::process::exit(1);
        }
        println!("verify OK: {got}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> tent::Result<()> {
    let mode = match args.get_str("mode", "hicache").as_str() {
        "baseline" => ServeMode::Baseline,
        _ => ServeMode::HiCache,
    };
    // Keep the disk pool out of /tmp once the run ends.
    let pool = tent::util::TempPool::new("serve");
    let mut cfg = ServeConfig {
        mode,
        clients: args.get_usize("clients", 4),
        turns: args.get_usize("turns", 3),
        decode_tokens: args.get_usize("decode", 2),
        seed: args.get_u64("seed", 7),
        model: ModelSelect::parse(&args.get_str("model", "auto"))
            .ok_or_else(|| tent::Error::Config("unknown --model (synthetic|pjrt|auto)".into()))?,
        ..Default::default()
    };
    cfg.cache.disk_path = pool.path();
    let model = make_executor(cfg.model)?;
    let (_cluster, engine) = make_engine(args)?;
    let convs = tent::serving::build_for(model.meta(), &cfg);
    let report = tent::serving::run_serving(&engine, model.as_ref(), &convs, &cfg)?;
    println!("{} clients={} turns={}", report.header(), cfg.clients, cfg.turns);
    println!(
        "input throughput: {:.0} tok/s   avg TTFT {:.3}s   P90 TTFT {:.3}s",
        report.input_throughput_tok_s(),
        report.avg_ttft_s(),
        report.p90_ttft_s()
    );
    for r in 1..=cfg.turns {
        println!("  round {r} avg TTFT: {:.3}s", report.round_avg_ttft_s(r));
    }
    Ok(())
}

fn cmd_checkpoint(args: &Args) -> tent::Result<()> {
    let sel = ModelSelect::parse(&args.get_str("model", "auto"))
        .ok_or_else(|| tent::Error::Config("unknown --model (synthetic|pjrt|auto)".into()))?;
    let mut model = make_executor(sel)?;
    let (_cluster, engine) = make_engine(args)?;
    // Default the payload to the executor's flat param vector so the
    // broadcast can be installed and exercised end to end.
    let param_bytes = model.meta().param_count as u64 * 4;
    let cfg = CheckpointConfig {
        payload_bytes: args.get_u64("payload", param_bytes),
        ranks: args.get_u64("ranks", 8) as u8,
        chunk_bytes: args.get_u64("chunk", 2 << 20),
        node: 0,
    };
    let ce = CheckpointEngine::new(Arc::clone(&engine), cfg.clone())?;
    let payload: Vec<u8> = (0..cfg.payload_bytes).map(|i| (i % 253) as u8).collect();
    ce.stage_weights(&payload)?;
    let rep = ce.update()?;
    println!(
        "updated {} ranks with {} in {:.3}s ({} effective)",
        rep.ranks,
        fmt_bytes(rep.payload_bytes),
        rep.seconds(),
        fmt_bw(rep.bytes_moved as f64 / rep.seconds())
    );
    println!("verify: {}", ce.verify()?);
    if cfg.payload_bytes == param_bytes {
        // Close the RL-pipeline loop: install rank-0's weights into the
        // model and prove inference still works.
        ce.install_into(0, model.as_mut())?;
        let t_pre = model.meta().t_pre;
        let tokens: Vec<i32> = (0..t_pre as i32).collect();
        let (tok, _) = model.prefill(&tokens, model.empty_kv()?, 0)?;
        println!(
            "rank-0 inference after in-place update ({}): next token = {tok} — OK",
            model.name()
        );
    } else {
        println!("(payload size != model params; skipping the install step)");
    }
    Ok(())
}

fn cmd_failover(args: &Args) -> tent::Result<()> {
    let (cluster, engine) = make_engine(args)?;
    let fail_at = Duration::from_millis(args.get_u64("fail-at", 500));
    let recover_at = Duration::from_millis(args.get_u64("recover-at", 1500));
    let total = Duration::from_millis(args.get_u64("duration", 2500));
    let len = 32u64 << 20;
    let src = engine.register_segment(Location::host(0, 0), len)?;
    let dst = engine.register_segment(Location::host(1, 0), len)?;
    let rail = cluster
        .topo
        .rails_of(tent::topology::NodeId(0), tent::topology::FabricKind::Rdma)[0];

    let fabric = Arc::clone(&cluster.fabric);
    let injector = std::thread::spawn(move || {
        std::thread::sleep(fail_at);
        fabric.inject_failure(rail);
        std::thread::sleep(recover_at - fail_at);
        fabric.recover(rail);
    });

    let start = std::time::Instant::now();
    let mut windows: Vec<(u64, u64)> = Vec::new(); // (ms, bytes/s)
    while start.elapsed() < total {
        let t0 = std::time::Instant::now();
        engine.transfer_sync(
            tent::engine::TransferReq::write(src, 0, dst, 0, 8 << 20),
            Duration::from_secs(30),
        )?;
        windows.push((
            start.elapsed().as_millis() as u64,
            (8u64 << 20) * 1000 / t0.elapsed().as_millis().max(1) as u64,
        ));
    }
    injector.join().unwrap();
    println!("t(ms)  throughput");
    for (t, bps) in windows {
        println!("{t:>6} {}", fmt_bw(bps as f64));
    }
    let s = engine.stats();
    println!(
        "retries={} exclusions={} readmissions={} permanent_failures={}",
        s.retries, s.exclusions, s.readmissions, s.permanent_failures
    );
    Ok(())
}
