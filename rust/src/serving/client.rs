//! Synthetic multi-turn conversation workload (the SGLang multi-turn
//! benchmark analogue used for Table 2).
//!
//! Every client issues `turns` requests; each turn appends one fresh
//! `t_pre`-token chunk to the conversation history, so turn `t` carries
//! `t` chunks of reusable prefix. Clients share a common system-prompt
//! chunk (cross-client prefix reuse, as in production serving).

use crate::util::prng::Pcg64;

/// One client's scripted conversation.
#[derive(Clone, Debug)]
pub struct Conversation {
    pub client: usize,
    /// The GPU this client's requests are served on (TP-group analogue).
    pub gpu: u8,
    /// `turns` chunks of exactly `t_pre` tokens each.
    pub chunks: Vec<Vec<i32>>,
}

/// Build the conversation scripts a [`super::ServeConfig`] describes, shaped
/// to a model's chunk size and vocab — the one-liner every serving driver
/// (CLI, benches, examples, tests) shares.
pub fn build_for(
    meta: &crate::runtime::ModelMeta,
    cfg: &super::ServeConfig,
) -> Vec<Conversation> {
    build_conversations(
        cfg.clients,
        cfg.turns,
        meta.t_pre,
        meta.vocab as i32,
        cfg.cache.gpus,
        cfg.seed,
        cfg.shared_system_prompt,
    )
}

/// Build deterministic conversation scripts.
pub fn build_conversations(
    clients: usize,
    turns: usize,
    t_pre: usize,
    vocab: i32,
    gpus: u8,
    seed: u64,
    shared_system_prompt: bool,
) -> Vec<Conversation> {
    let mut rng = Pcg64::new(seed, 0xC11E);
    let system: Vec<i32> = (0..t_pre).map(|_| rng.gen_range(vocab as u64) as i32).collect();
    (0..clients)
        .map(|c| {
            let mut chunks = Vec::with_capacity(turns);
            for t in 0..turns {
                if t == 0 && shared_system_prompt {
                    chunks.push(system.clone());
                } else {
                    let mut rng_c = Pcg64::new(seed ^ 0xBEEF, (c * 1000 + t) as u64);
                    chunks.push(
                        (0..t_pre)
                            .map(|_| rng_c.gen_range(vocab as u64) as i32)
                            .collect(),
                    );
                }
            }
            Conversation {
                client: c,
                gpu: (c % gpus as usize) as u8,
                chunks,
            }
        })
        .collect()
}

/// Request-level SLO class — `TransferClass` semantics lifted to the
/// serving layer: `Interactive` rides ahead of `Batch` at admission the
/// way Latency-class slices ride ahead of Bulk on a rail.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum RequestClass {
    Interactive,
    Batch,
}

impl RequestClass {
    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Interactive => "interactive",
            RequestClass::Batch => "batch",
        }
    }
}

/// One scripted session for the continuous-batching scheduler
/// (`serving::batching::serve_fleet`): an arrival-driven multi-turn
/// conversation with an SLO class and a target model shape.
#[derive(Clone, Debug)]
pub struct SessionScript {
    pub session: usize,
    pub class: RequestClass,
    /// Index into the fleet's model list (multi-model serving).
    pub model: usize,
    /// `turns` chunks of exactly the target model's `t_pre` tokens.
    pub chunks: Vec<Vec<i32>>,
    /// Virtual arrival time of turn 0 (ns since run start).
    pub arrival_ns: u64,
    /// Virtual think time between a turn finishing and the next arriving.
    pub think_ns: u64,
}

/// Knobs for [`build_sessions`].
#[derive(Clone, Debug)]
pub struct SessionWorkload {
    pub sessions: usize,
    pub turns: usize,
    /// Fraction of sessions in the `Interactive` class.
    pub interactive_share: f64,
    /// Mean virtual inter-arrival between session starts (Poisson process).
    pub mean_interarrival_ns: u64,
    pub think_ns: u64,
    pub shared_system_prompt: bool,
    pub seed: u64,
}

impl Default for SessionWorkload {
    fn default() -> Self {
        SessionWorkload {
            sessions: 64,
            turns: 3,
            interactive_share: 0.5,
            mean_interarrival_ns: 200_000,
            think_ns: 1_000_000,
            shared_system_prompt: true,
            seed: 7,
        }
    }
}

/// Build the deterministic session scripts for a fleet serving `metas`
/// model shapes (session `s` targets model `s % metas.len()`). Arrivals
/// are a Poisson process over the virtual clock; every draw comes from
/// the seeded PRNG, so equal seeds give byte-identical workloads.
pub fn build_sessions(
    metas: &[&crate::runtime::ModelMeta],
    w: &SessionWorkload,
) -> Vec<SessionScript> {
    assert!(!metas.is_empty(), "at least one model shape");
    let mut rng = Pcg64::new(w.seed, 0x5E55);
    // One shared system-prompt chunk per model shape.
    let systems: Vec<Vec<i32>> = metas
        .iter()
        .map(|m| (0..m.t_pre).map(|_| rng.gen_range(m.vocab as u64) as i32).collect())
        .collect();
    let mut arrival = 0u64;
    (0..w.sessions)
        .map(|s| {
            let model = s % metas.len();
            let meta = metas[model];
            arrival += rng.gen_exp(w.mean_interarrival_ns as f64).max(0.0) as u64;
            let class = if rng.gen_bool(w.interactive_share) {
                RequestClass::Interactive
            } else {
                RequestClass::Batch
            };
            let mut chunks = Vec::with_capacity(w.turns);
            for t in 0..w.turns {
                if t == 0 && w.shared_system_prompt {
                    chunks.push(systems[model].clone());
                } else {
                    let mut rng_s = Pcg64::new(w.seed ^ 0xBEEF5, (s as u64) * 4096 + t as u64);
                    chunks.push(
                        (0..meta.t_pre)
                            .map(|_| rng_s.gen_range(meta.vocab as u64) as i32)
                            .collect(),
                    );
                }
            }
            SessionScript {
                session: s,
                class,
                model,
                chunks,
                arrival_ns: arrival,
                think_ns: w.think_ns,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sessions_deterministic_and_well_shaped() {
        let meta = crate::runtime::ModelMeta::custom(2, 2, 8, 32, 4, 512, 10_000);
        let w = SessionWorkload {
            sessions: 32,
            turns: 2,
            ..Default::default()
        };
        let a = build_sessions(&[&meta], &w);
        let b = build_sessions(&[&meta], &w);
        assert_eq!(a.len(), 32);
        let mut last_arrival = 0;
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.chunks, y.chunks);
            assert_eq!(x.class, y.class);
            assert_eq!(x.arrival_ns, y.arrival_ns);
            assert_eq!(x.chunks.len(), 2);
            assert!(x.chunks.iter().all(|c| c.len() == 4));
            assert!(x.arrival_ns >= last_arrival, "arrivals monotone");
            last_arrival = x.arrival_ns;
            // Shared system prompt across sessions of the same model.
            assert_eq!(x.chunks[0], a[0].chunks[0]);
        }
        assert!(a.iter().any(|s| s.class == RequestClass::Interactive));
        assert!(a.iter().any(|s| s.class == RequestClass::Batch));
    }

    #[test]
    fn sessions_round_robin_models() {
        let m0 = crate::runtime::ModelMeta::custom(2, 2, 8, 32, 4, 512, 10_000);
        let m1 = crate::runtime::ModelMeta::custom(1, 2, 8, 16, 8, 256, 5_000);
        let w = SessionWorkload {
            sessions: 6,
            turns: 1,
            ..Default::default()
        };
        let sess = build_sessions(&[&m0, &m1], &w);
        for s in &sess {
            assert_eq!(s.model, s.session % 2);
            let t_pre = if s.model == 0 { 4 } else { 8 };
            assert!(s.chunks.iter().all(|c| c.len() == t_pre));
        }
    }

    #[test]
    fn deterministic_and_well_shaped() {
        let a = build_conversations(4, 3, 128, 4096, 8, 7, true);
        let b = build_conversations(4, 3, 128, 4096, 8, 7, true);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.chunks, y.chunks);
            assert_eq!(x.chunks.len(), 3);
            assert!(x.chunks.iter().all(|c| c.len() == 128));
            assert!(x
                .chunks
                .iter()
                .flatten()
                .all(|&t| (0..4096).contains(&t)));
        }
    }

    #[test]
    fn shared_system_prompt_is_shared() {
        let convs = build_conversations(3, 2, 64, 4096, 8, 1, true);
        assert_eq!(convs[0].chunks[0], convs[1].chunks[0]);
        assert_eq!(convs[1].chunks[0], convs[2].chunks[0]);
        assert_ne!(convs[0].chunks[1], convs[1].chunks[1]);
    }

    #[test]
    fn unshared_prompts_differ() {
        let convs = build_conversations(2, 1, 64, 4096, 8, 1, false);
        assert_ne!(convs[0].chunks[0], convs[1].chunks[0]);
    }

    #[test]
    fn gpu_assignment_round_robins() {
        let convs = build_conversations(10, 1, 16, 100, 4, 1, true);
        assert_eq!(convs[0].gpu, 0);
        assert_eq!(convs[5].gpu, 1);
        assert_eq!(convs[9].gpu, 1);
    }
}
