//! Synthetic multi-turn conversation workload (the SGLang multi-turn
//! benchmark analogue used for Table 2).
//!
//! Every client issues `turns` requests; each turn appends one fresh
//! `t_pre`-token chunk to the conversation history, so turn `t` carries
//! `t` chunks of reusable prefix. Clients share a common system-prompt
//! chunk (cross-client prefix reuse, as in production serving).

use crate::util::prng::Pcg64;

/// One client's scripted conversation.
#[derive(Clone, Debug)]
pub struct Conversation {
    pub client: usize,
    /// The GPU this client's requests are served on (TP-group analogue).
    pub gpu: u8,
    /// `turns` chunks of exactly `t_pre` tokens each.
    pub chunks: Vec<Vec<i32>>,
}

/// Build the conversation scripts a [`super::ServeConfig`] describes, shaped
/// to a model's chunk size and vocab — the one-liner every serving driver
/// (CLI, benches, examples, tests) shares.
pub fn build_for(
    meta: &crate::runtime::ModelMeta,
    cfg: &super::ServeConfig,
) -> Vec<Conversation> {
    build_conversations(
        cfg.clients,
        cfg.turns,
        meta.t_pre,
        meta.vocab as i32,
        cfg.cache.gpus,
        cfg.seed,
        cfg.shared_system_prompt,
    )
}

/// Build deterministic conversation scripts.
pub fn build_conversations(
    clients: usize,
    turns: usize,
    t_pre: usize,
    vocab: i32,
    gpus: u8,
    seed: u64,
    shared_system_prompt: bool,
) -> Vec<Conversation> {
    let mut rng = Pcg64::new(seed, 0xC11E);
    let system: Vec<i32> = (0..t_pre).map(|_| rng.gen_range(vocab as u64) as i32).collect();
    (0..clients)
        .map(|c| {
            let mut chunks = Vec::with_capacity(turns);
            for t in 0..turns {
                if t == 0 && shared_system_prompt {
                    chunks.push(system.clone());
                } else {
                    let mut rng_c = Pcg64::new(seed ^ 0xBEEF, (c * 1000 + t) as u64);
                    chunks.push(
                        (0..t_pre)
                            .map(|_| rng_c.gen_range(vocab as u64) as i32)
                            .collect(),
                    );
                }
            }
            Conversation {
                client: c,
                gpu: (c % gpus as usize) as u8,
                chunks,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_well_shaped() {
        let a = build_conversations(4, 3, 128, 4096, 8, 7, true);
        let b = build_conversations(4, 3, 128, 4096, 8, 7, true);
        assert_eq!(a.len(), 4);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.chunks, y.chunks);
            assert_eq!(x.chunks.len(), 3);
            assert!(x.chunks.iter().all(|c| c.len() == 128));
            assert!(x
                .chunks
                .iter()
                .flatten()
                .all(|&t| (0..4096).contains(&t)));
        }
    }

    #[test]
    fn shared_system_prompt_is_shared() {
        let convs = build_conversations(3, 2, 64, 4096, 8, 1, true);
        assert_eq!(convs[0].chunks[0], convs[1].chunks[0]);
        assert_eq!(convs[1].chunks[0], convs[2].chunks[0]);
        assert_ne!(convs[0].chunks[1], convs[1].chunks[1]);
    }

    #[test]
    fn unshared_prompts_differ() {
        let convs = build_conversations(2, 1, 64, 4096, 8, 1, false);
        assert_ne!(convs[0].chunks[0], convs[1].chunks[0]);
    }

    #[test]
    fn gpu_assignment_round_robins() {
        let convs = build_conversations(10, 1, 16, 100, 4, 1, true);
        assert_eq!(convs[0].gpu, 0);
        assert_eq!(convs[5].gpu, 1);
        assert_eq!(convs[9].gpu, 1);
    }
}
