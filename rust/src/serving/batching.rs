//! Continuous-batching serving scheduler over a [`Fleet`] of engines.
//!
//! This is the millions-of-users path the ROADMAP names: instead of the
//! turn-major FIFO loop in [`super::router`] (kept as the Table-2
//! baseline), each engine runs an **iteration-level** scheduling lane:
//!
//! * **Arrival-driven queue with SLO admission.** Sessions arrive on a
//!   virtual clock (Poisson workload from `client::build_sessions`); each
//!   turn is a request in one of two classes — `Interactive` rides ahead
//!   of `Batch` at admission (the request-level analogue of the engine's
//!   Latency/Bulk `TransferClass` split), with a batch-slot reserve and
//!   age-based promotion so bulk work cannot starve.
//! * **Iteration-level batch formation.** Every iteration forms one
//!   chunked-prefill batch (up to `prefill_chunks_per_iter` chunks, one
//!   per running request) and one decode batch (every decoding request)
//!   through the [`ModelExecutor::prefill_batch`]/[`decode_batch`] API.
//!   The decode batch shares the weight pass — the continuous-batching
//!   throughput win the synthetic FLOPs model prices in.
//! * **Deterministic virtual time.** The lane's clock advances only by
//!   the executor's *modeled* batch latency, a modeled fetch cost
//!   (`fetch_ns_per_byte`), and jumps to the next arrival — so without
//!   failure injection the admitted schedule ([`BatchReport::schedule_table`])
//!   is a pure function of (sessions, models, config), while the KV bytes
//!   still move through the real engine data plane.
//! * **Prefix-cache-aware placement + session affinity.** Sessions are
//!   placed by rendezvous (highest-random-weight) hashing of their prefix
//!   chain hash over the engines serving their model, so sessions that
//!   share a true prefix colocate on the same engine's `TieredKvCache`
//!   and every later turn returns to it. On an engine failure only that
//!   engine's sessions re-hash to survivors; everyone else keeps their
//!   cache affinity.
//! * **Multi-model fleets.** Engine `j` serves `models[j % models.len()]`;
//!   several `ModelMeta` shapes share one fabric and one datapath.
//!
//! [`ModelExecutor::prefill_batch`]: crate::runtime::ModelExecutor::prefill_batch
//! [`decode_batch`]: crate::runtime::ModelExecutor::decode_batch

use super::client::{RequestClass, SessionScript};
use super::kvcache::{hash_chunks, KvCacheConfig, TieredKvCache};
use crate::cluster::Fleet;
use crate::engine::TentEngine;
use crate::runtime::{DecodeStep, KvCache, ModelExecutor, PrefillStep};
use crate::segment::{Location, SegmentId};
use crate::util::clock;
use crate::util::hist::Histogram;
use crate::util::TempPool;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Which scheduler shape a run uses.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SchedulePolicy {
    /// Turn-major baseline: strict arrival order, one request in flight
    /// per engine, no class priority — the old router's serving shape
    /// expressed in the same machinery (apples-to-apples comparison).
    Fifo,
    /// Iteration-level continuous batching with SLO admission.
    Continuous,
}

/// Per-class TTFT service-level objectives (virtual ns).
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    pub interactive_ttft_ns: u64,
    pub batch_ttft_ns: u64,
}

/// Kill one engine mid-run (resilience axis): engine `node` stops after
/// completing `after_turns` requests and hands its queue, in-flight
/// requests, and future turns to the surviving engines by re-running the
/// rendezvous placement over the live set.
#[derive(Clone, Copy, Debug)]
pub struct FailurePlan {
    pub node: u16,
    pub after_turns: usize,
}

/// Continuous-batching scheduler knobs.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    pub schedule: SchedulePolicy,
    /// Concurrent requests per engine (working KV slots). `Fifo` ignores
    /// this and runs one.
    pub max_running: usize,
    /// Prefill chunks formed per iteration (chunked-prefill budget; one
    /// chunk per running request per iteration).
    pub prefill_chunks_per_iter: usize,
    /// Slots an un-aged `Batch` request may never take (kept free for
    /// interactive arrivals).
    pub interactive_reserve: usize,
    /// Queue age (virtual ns) after which a `Batch` request is promoted
    /// past the reserve — the anti-starvation valve.
    pub batch_admit_age_ns: u64,
    /// Decode steps per turn (>= 1; the first defines TTFT). Clipped at
    /// the model's context bound.
    pub decode_tokens: usize,
    /// Modeled virtual cost of moving one fetched KV byte into the
    /// working segment (default 0.04 ns/B ≈ 25 GB/s effective).
    pub fetch_ns_per_byte: f64,
    /// Per-engine tiered-cache template; `node`/`disk_path` are
    /// overridden per engine.
    pub cache: KvCacheConfig,
    pub slo: SloConfig,
    pub fail: Option<FailurePlan>,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            schedule: SchedulePolicy::Continuous,
            max_running: 16,
            prefill_chunks_per_iter: 4,
            interactive_reserve: 4,
            batch_admit_age_ns: 50_000_000,
            decode_tokens: 4,
            fetch_ns_per_byte: 0.04,
            cache: KvCacheConfig::default(),
            slo: SloConfig {
                interactive_ttft_ns: 50_000_000,
                batch_ttft_ns: 500_000_000,
            },
            fail: None,
        }
    }
}

/// One completed turn's measurements (virtual-clock latencies).
#[derive(Clone, Copy, Debug)]
pub struct ReqMetrics {
    pub session: usize,
    pub turn: usize,
    pub class: RequestClass,
    pub model: usize,
    pub engine: u16,
    /// Admission order on the serving engine (per-engine counter) — the
    /// SLO-overtaking evidence.
    pub admit_seq: u64,
    pub arrival_ns: u64,
    pub admit_ns: u64,
    pub input_tokens: usize,
    pub cached_blocks: usize,
    pub fetched_bytes: u64,
    pub ttft_ns: u64,
    pub tpot_ns: u64,
    pub decode_steps: usize,
}

/// Fleet-wide serving report.
pub struct BatchReport {
    pub rows: Vec<ReqMetrics>,
    /// Sessions that could not be placed (no live engine serves their
    /// model).
    pub dropped_sessions: usize,
    /// Largest per-engine virtual clock at drain (virtual makespan).
    pub makespan_ns: u64,
    /// Real wall time of the run.
    pub wall_ns: u64,
}

impl BatchReport {
    /// The semantic admitted schedule: `(session, turn, engine,
    /// admit_seq, cached_blocks, fetched_bytes)`, sorted. Two runs with
    /// the same sessions/models/config and no failure injection must
    /// produce identical tables — the determinism contract.
    pub fn schedule_table(&self) -> Vec<(usize, usize, u16, u64, usize, u64)> {
        let mut v: Vec<_> = self
            .rows
            .iter()
            .map(|r| (r.session, r.turn, r.engine, r.admit_seq, r.cached_blocks, r.fetched_bytes))
            .collect();
        v.sort_unstable();
        v
    }

    pub fn input_tokens_total(&self) -> usize {
        self.rows.iter().map(|r| r.input_tokens).sum()
    }

    /// Input tokens per *virtual* second of makespan — the throughput the
    /// FIFO-vs-continuous gate compares.
    pub fn input_throughput_tok_s(&self) -> f64 {
        self.input_tokens_total() as f64 / (self.makespan_ns.max(1) as f64 / 1e9)
    }

    /// TTFT distribution, optionally restricted to one class, in the
    /// shared log-bucketed histogram (same quantile definition as every
    /// other bench gate).
    pub fn ttft_hist(&self, class: Option<RequestClass>) -> Histogram {
        let h = Histogram::new();
        for r in &self.rows {
            let keep = match class {
                None => true,
                Some(c) => c == r.class,
            };
            if keep {
                h.record(r.ttft_ns);
            }
        }
        h
    }

    pub fn p90_ttft_s(&self) -> f64 {
        self.ttft_hist(None).p90() as f64 / 1e9
    }

    pub fn p99_ttft_s(&self, class: RequestClass) -> f64 {
        self.ttft_hist(Some(class)).p99() as f64 / 1e9
    }

    /// Fraction of completed `class` turns whose TTFT met its SLO bound
    /// (1.0 when the class is absent).
    pub fn slo_attainment(&self, class: RequestClass, slo: &SloConfig) -> f64 {
        let bound = match class {
            RequestClass::Interactive => slo.interactive_ttft_ns,
            RequestClass::Batch => slo.batch_ttft_ns,
        };
        let (mut total, mut ok) = (0u64, 0u64);
        for r in self.rows.iter().filter(|r| r.class == class) {
            total += 1;
            if r.ttft_ns <= bound {
                ok += 1;
            }
        }
        if total == 0 {
            1.0
        } else {
            ok as f64 / total as f64
        }
    }

    /// Sorted, deduplicated engines that served `session`'s turns — the
    /// affinity evidence (one engine absent failures; at most two when a
    /// single engine dies mid-run).
    pub fn engines_of(&self, session: usize) -> Vec<u16> {
        let mut v: Vec<u16> = self
            .rows
            .iter()
            .filter(|r| r.session == session)
            .map(|r| r.engine)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// A turn waiting to be admitted on some engine.
#[derive(Clone, Copy, Debug)]
struct Req {
    session: usize,
    turn: usize,
    arrival_vns: u64,
}

/// A turn admitted into an engine's running set.
struct Running {
    session: usize,
    turn: usize,
    arrival_vns: u64,
    admit_seq: u64,
    admit_vns: u64,
    slot: usize,
    kv: Option<KvCache>,
    hashes: Vec<u64>,
    next_chunk: usize,
    chunks_total: usize,
    next_token: i32,
    decode_done: usize,
    decode_target: usize,
    cached_blocks: usize,
    fetched_bytes: u64,
    ttft_vns: u64,
    tpot_total: u64,
}

/// splitmix64 finalizer — the rendezvous score mixer.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Highest-random-weight placement of a session (by its prefix key) over
/// the live engines serving its model. `None` when no such engine is
/// alive.
fn place(key: u64, live: &[AtomicBool], models_len: usize, model: usize) -> Option<u16> {
    let mut best: Option<(u64, u16)> = None;
    for (j, alive) in live.iter().enumerate() {
        if j % models_len != model || !alive.load(Ordering::Acquire) {
            continue;
        }
        let score = mix(key ^ (j as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let better = match best {
            None => true,
            Some((b, _)) => score > b,
        };
        if better {
            best = Some((score, j as u16));
        }
    }
    best.map(|(_, j)| j)
}

/// Cross-lane coordination state.
struct Shared {
    live: Vec<AtomicBool>,
    injected: Vec<Mutex<Vec<Req>>>,
    remaining: AtomicUsize,
}

/// Serve `sessions` across the fleet with one scheduling lane per engine.
/// Engine `j` serves `models[j % models.len()]`; each lane owns a
/// [`TieredKvCache`] on its node plus `max_running` working KV segments.
pub fn serve_fleet(
    fleet: &Fleet,
    models: &[Arc<dyn ModelExecutor>],
    sessions: &[SessionScript],
    cfg: &BatchConfig,
) -> Result<BatchReport> {
    if models.is_empty() {
        return Err(Error::Config("serve_fleet needs at least one model".into()));
    }
    if cfg.decode_tokens == 0 || cfg.max_running == 0 {
        return Err(Error::Config("decode_tokens and max_running must be >= 1".into()));
    }
    if cfg.interactive_reserve >= cfg.max_running && cfg.schedule == SchedulePolicy::Continuous {
        return Err(Error::Config(format!(
            "interactive_reserve {} leaves no slot for batch admission (max_running {})",
            cfg.interactive_reserve, cfg.max_running
        )));
    }
    let n = fleet.nodes();
    // Placement keys: the chain hash of the first non-system chunk (when
    // one exists), so sessions sharing only the system prompt spread while
    // true prefix-sharers colocate. Validate shapes up front.
    let mut keys = Vec::with_capacity(sessions.len());
    for (i, s) in sessions.iter().enumerate() {
        if s.session != i {
            return Err(Error::Config(format!(
                "session ids must be dense: index {i} holds session {}",
                s.session
            )));
        }
        if s.model >= models.len() {
            return Err(Error::Config(format!(
                "session {i} targets model {} of {}",
                s.model,
                models.len()
            )));
        }
        let meta = models[s.model].meta();
        let max_turns = (meta.t_max / meta.t_pre).saturating_sub(1);
        if s.chunks.is_empty() || s.chunks.len() > max_turns {
            return Err(Error::Config(format!(
                "session {i} has {} turns; model {} allows 1..={max_turns}",
                s.chunks.len(),
                s.model
            )));
        }
        if s.chunks.iter().any(|c| c.len() != meta.t_pre) {
            return Err(Error::Config(format!(
                "session {i} chunk size mismatch (model {} t_pre {})",
                s.model, meta.t_pre
            )));
        }
        let hashes = hash_chunks(&s.chunks);
        keys.push(hashes[hashes.len().min(2) - 1]);
    }

    let shared = Shared {
        live: (0..n).map(|_| AtomicBool::new(true)).collect(),
        injected: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        remaining: AtomicUsize::new(0),
    };
    let mut initial: Vec<Vec<Req>> = (0..n).map(|_| Vec::new()).collect();
    let mut dropped = 0usize;
    let mut total_turns = 0usize;
    for s in sessions {
        match place(keys[s.session], &shared.live, models.len(), s.model) {
            Some(j) => {
                initial[j as usize].push(Req {
                    session: s.session,
                    turn: 0,
                    arrival_vns: s.arrival_ns,
                });
                total_turns += s.chunks.len();
            }
            None => dropped += 1,
        }
    }
    shared.remaining.store(total_turns, Ordering::Release);

    // Per-engine cache + working slots, built up front so config errors
    // surface before any lane spawns.
    let pools: Vec<TempPool> = (0..n).map(|_| TempPool::new("cb_kv")).collect();
    let mut caches: Vec<TieredKvCache> = Vec::with_capacity(n);
    let mut working_all: Vec<Vec<SegmentId>> = Vec::with_capacity(n);
    let slots_per_engine = match cfg.schedule {
        SchedulePolicy::Fifo => 1,
        SchedulePolicy::Continuous => cfg.max_running,
    };
    for (j, pool) in pools.iter().enumerate() {
        let model = &models[j % models.len()];
        let meta = model.meta();
        let mut ccfg = cfg.cache.clone();
        ccfg.node = j as u16;
        ccfg.disk_path = pool.path();
        let engine = fleet.engine(j as u16);
        caches.push(TieredKvCache::new(engine, meta, ccfg.clone())?);
        working_all.push(
            (0..slots_per_engine)
                .map(|s| {
                    engine.register_segment(
                        Location::device(j as u16, (s % ccfg.gpus as usize) as u8),
                        meta.kv_bytes,
                    )
                })
                .collect::<Result<Vec<_>>>()?,
        );
    }

    let first_err: Mutex<Option<Error>> = Mutex::new(None);
    let start = clock::now_ns();
    let mut lane_out: Vec<(Vec<ReqMetrics>, u64)> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|j| {
                let queue = std::mem::take(&mut initial[j]);
                let model = &models[j % models.len()];
                let cache = &caches[j];
                let working = &working_all[j];
                let keys = &keys;
                let shared = &shared;
                let first_err = &first_err;
                let engine = fleet.engine(j as u16);
                scope.spawn(move || {
                    match run_lane(
                        j as u16,
                        engine,
                        model.as_ref(),
                        cache,
                        working,
                        sessions,
                        keys,
                        queue,
                        cfg,
                        models.len(),
                        shared,
                    ) {
                        Ok(out) => out,
                        Err(e) => {
                            first_err.lock().unwrap().get_or_insert(e);
                            // Unblock every other lane.
                            shared.remaining.store(0, Ordering::Release);
                            (Vec::new(), 0)
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            lane_out.push(h.join().expect("serving lane panicked"));
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }

    let mut rows = Vec::new();
    let mut makespan = 0u64;
    for (r, vend) in lane_out {
        rows.extend(r);
        makespan = makespan.max(vend);
    }
    // Sessions orphaned by a failure with no surviving engine for their
    // model also count as dropped.
    let completed_sessions: std::collections::HashSet<usize> =
        rows.iter().map(|r| r.session).collect();
    let placed = sessions.len() - dropped;
    dropped += placed.saturating_sub(completed_sessions.len());
    Ok(BatchReport {
        rows,
        dropped_sessions: dropped,
        makespan_ns: makespan,
        wall_ns: clock::now_ns().saturating_sub(start),
    })
}

/// One engine's scheduling lane. Returns its completed-turn rows and its
/// final virtual clock.
#[allow(clippy::too_many_arguments)]
fn run_lane(
    j: u16,
    engine: &Arc<TentEngine>,
    model: &dyn ModelExecutor,
    cache: &TieredKvCache,
    working: &[SegmentId],
    sessions: &[SessionScript],
    keys: &[u64],
    mut queue: Vec<Req>,
    cfg: &BatchConfig,
    models_len: usize,
    shared: &Shared,
) -> Result<(Vec<ReqMetrics>, u64)> {
    let meta = model.meta();
    let t_pre = meta.t_pre;
    let mut running: Vec<Running> = Vec::new();
    let mut free_slots: Vec<usize> = (0..working.len()).rev().collect();
    let mut rows: Vec<ReqMetrics> = Vec::new();
    let mut vnow: u64 = 0;
    let mut admit_seq: u64 = 0;
    let mut completed_turns: usize = 0;
    queue.sort_by_key(|r| (r.arrival_vns, r.session, r.turn));

    while shared.remaining.load(Ordering::Acquire) > 0 {
        // Failure handoffs from a dying peer.
        {
            let mut inj = shared.injected[j as usize].lock().unwrap();
            if !inj.is_empty() {
                queue.extend(inj.drain(..));
                drop(inj);
                queue.sort_by_key(|r| (r.arrival_vns, r.session, r.turn));
            }
        }
        if running.is_empty() {
            match queue.iter().map(|r| r.arrival_vns).min() {
                // Idle gap: jump the virtual clock to the next arrival.
                Some(a) if a > vnow => vnow = a,
                Some(_) => {}
                None => {
                    // Nothing owned — park until the fleet drains or a
                    // failure hands work over.
                    clock::sleep_ns(100_000);
                    continue;
                }
            }
        }

        // ---- admission ----
        let mut order: Vec<usize> =
            (0..queue.len()).filter(|&i| queue[i].arrival_vns <= vnow).collect();
        match cfg.schedule {
            SchedulePolicy::Fifo => {
                order.sort_by_key(|&i| (queue[i].arrival_vns, queue[i].session, queue[i].turn));
            }
            SchedulePolicy::Continuous => {
                order.sort_by_key(|&i| {
                    let r = &queue[i];
                    let class = sessions[r.session].class;
                    let aged = class == RequestClass::Batch
                        && vnow.saturating_sub(r.arrival_vns) >= cfg.batch_admit_age_ns;
                    let rank = if class == RequestClass::Interactive || aged {
                        0u8
                    } else {
                        1
                    };
                    (rank, r.arrival_vns, r.session, r.turn)
                });
            }
        }
        let mut batch_running = running
            .iter()
            .filter(|r| sessions[r.session].class == RequestClass::Batch)
            .count();
        let mut take: Vec<usize> = Vec::new();
        for &i in &order {
            if free_slots.len() <= take.len() {
                break;
            }
            let r = &queue[i];
            let class = sessions[r.session].class;
            if cfg.schedule == SchedulePolicy::Continuous && class == RequestClass::Batch {
                let aged = vnow.saturating_sub(r.arrival_vns) >= cfg.batch_admit_age_ns;
                let cap = cfg.max_running.saturating_sub(cfg.interactive_reserve);
                if !aged && batch_running >= cap {
                    continue;
                }
                batch_running += 1;
            }
            take.push(i);
        }
        take.sort_unstable();
        for &i in take.iter().rev() {
            let r = queue.swap_remove(i);
            let slot = free_slots.pop().expect("slot reserved above");
            let s = &sessions[r.session];
            let chunks_total = r.turn + 1;
            let hashes = hash_chunks(&s.chunks[..chunks_total]);
            let reusable = &hashes[..r.turn];
            let hit = cache.lookup_prefix(reusable);
            let fetched = cache.fetch_prefix(engine, reusable, hit, working[slot])?;
            let kv = if hit > 0 {
                model.kv_from_bytes(&cache.materialize_prefix_bytes(engine, working[slot], hit)?)?
            } else {
                model.empty_kv()?
            };
            // The fetch rides the lane's iteration timeline at a modeled
            // rate (the real transfer already moved the bytes).
            vnow += (fetched as f64 * cfg.fetch_ns_per_byte) as u64;
            let pos_after = chunks_total * t_pre;
            running.push(Running {
                session: r.session,
                turn: r.turn,
                arrival_vns: r.arrival_vns,
                admit_seq,
                admit_vns: vnow,
                slot,
                kv: Some(kv),
                hashes,
                next_chunk: hit,
                chunks_total,
                next_token: 0,
                decode_done: 0,
                decode_target: cfg.decode_tokens.min(meta.t_max - pos_after),
                cached_blocks: hit,
                fetched_bytes: fetched,
                ttft_vns: 0,
                tpot_total: 0,
            });
            admit_seq += 1;
        }

        // ---- prefill batch (chunked, one chunk per request per iteration) ----
        let budget = cfg.prefill_chunks_per_iter.max(1);
        let mut pwho: Vec<usize> = Vec::new();
        let mut psteps: Vec<PrefillStep<'_>> = Vec::new();
        for (i, r) in running.iter_mut().enumerate() {
            if r.next_chunk < r.chunks_total && psteps.len() < budget {
                psteps.push(PrefillStep {
                    tokens: &sessions[r.session].chunks[r.next_chunk],
                    kv: r.kv.take().expect("kv held between iterations"),
                    offset: (r.next_chunk * t_pre) as i32,
                });
                pwho.push(i);
            }
        }
        if !psteps.is_empty() {
            let (res, ns) = model.prefill_batch(psteps)?;
            vnow += ns;
            for (&i, (tok, kv)) in pwho.iter().zip(res) {
                let r = &mut running[i];
                r.next_token = tok;
                r.kv = Some(kv);
                r.next_chunk += 1;
            }
        }

        // ---- decode batch (every decoding request) ----
        let mut dwho: Vec<usize> = Vec::new();
        let mut dsteps: Vec<DecodeStep> = Vec::new();
        for (i, r) in running.iter_mut().enumerate() {
            if r.next_chunk == r.chunks_total && r.decode_done < r.decode_target {
                dsteps.push(DecodeStep {
                    token: r.next_token,
                    kv: r.kv.take().expect("kv held between iterations"),
                    pos: (r.chunks_total * t_pre + r.decode_done) as i32,
                });
                dwho.push(i);
            }
        }
        if !dsteps.is_empty() {
            let (res, ns) = model.decode_batch(dsteps)?;
            vnow += ns;
            for (&i, (tok, kv)) in dwho.iter().zip(res) {
                let r = &mut running[i];
                r.next_token = tok;
                r.kv = Some(kv);
                r.decode_done += 1;
                if r.decode_done == 1 {
                    r.ttft_vns = vnow.saturating_sub(r.arrival_vns);
                } else {
                    // Every request in the batch waited for the whole
                    // iteration — the batch latency is its step latency.
                    r.tpot_total += ns;
                }
            }
        }

        // ---- completions: write back, record, schedule the next turn ----
        let done: Vec<usize> = (0..running.len())
            .filter(|&i| {
                running[i].next_chunk == running[i].chunks_total
                    && running[i].decode_done >= running[i].decode_target
            })
            .collect();
        for &i in done.iter().rev() {
            let r = running.swap_remove(i);
            let kv = r.kv.expect("kv held between iterations");
            let seg = engine.segment(working[r.slot])?;
            match kv.as_host_bytes() {
                Some(raw) => seg.write_at(0, raw)?,
                None => seg.write_at(0, &kv.to_bytes()?)?,
            }
            for (k, h) in r.hashes.iter().enumerate().skip(r.cached_blocks) {
                let home = (*h % cache.config().gpus as u64) as u8;
                cache.store_block(engine, *h, home, working[r.slot], k)?;
            }
            free_slots.push(r.slot);
            rows.push(ReqMetrics {
                session: r.session,
                turn: r.turn,
                class: sessions[r.session].class,
                model: sessions[r.session].model,
                engine: j,
                admit_seq: r.admit_seq,
                arrival_ns: r.arrival_vns,
                admit_ns: r.admit_vns,
                input_tokens: t_pre,
                cached_blocks: r.cached_blocks,
                fetched_bytes: r.fetched_bytes,
                ttft_ns: r.ttft_vns,
                tpot_ns: if r.decode_done > 1 {
                    r.tpot_total / (r.decode_done as u64 - 1)
                } else {
                    0
                },
                decode_steps: r.decode_done,
            });
            completed_turns += 1;
            shared.remaining.fetch_sub(1, Ordering::AcqRel);
            if r.turn + 1 < sessions[r.session].chunks.len() {
                // Session affinity: the next turn returns to this lane.
                queue.push(Req {
                    session: r.session,
                    turn: r.turn + 1,
                    arrival_vns: vnow + sessions[r.session].think_ns,
                });
            }
        }

        // ---- scheduled failure: hand everything to the survivors ----
        if let Some(f) = cfg.fail {
            if f.node == j
                && completed_turns >= f.after_turns
                && shared.live[j as usize].load(Ordering::Acquire)
            {
                shared.live[j as usize].store(false, Ordering::Release);
                let mut orphans: Vec<Req> = queue.drain(..).collect();
                for r in running.drain(..) {
                    // In-flight turns restart from the target's cache.
                    orphans.push(Req {
                        session: r.session,
                        turn: r.turn,
                        arrival_vns: r.arrival_vns,
                    });
                }
                for o in orphans {
                    let m = sessions[o.session].model;
                    match place(keys[o.session], &shared.live, models_len, m) {
                        Some(t) => shared.injected[t as usize].lock().unwrap().push(o),
                        None => {
                            // No surviving engine serves this model: the
                            // session's outstanding turns leave the run.
                            let rest = sessions[o.session].chunks.len() - o.turn;
                            shared.remaining.fetch_sub(rest, Ordering::AcqRel);
                        }
                    }
                }
                break;
            }
        }
    }
    Ok((rows, vnow))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendezvous_is_stable_under_failure() {
        let live: Vec<AtomicBool> = (0..4).map(|_| AtomicBool::new(true)).collect();
        let keys: Vec<u64> = (0..64).map(|i| mix(i * 0x9E37)).collect();
        let before: Vec<u16> = keys.iter().map(|&k| place(k, &live, 1, 0).unwrap()).collect();
        // Every engine gets some share.
        for j in 0..4u16 {
            assert!(before.iter().any(|&p| p == j), "engine {j} got nothing");
        }
        // Kill engine 2: only its sessions move.
        live[2].store(false, Ordering::Release);
        for (i, &k) in keys.iter().enumerate() {
            let after = place(k, &live, 1, 0).unwrap();
            if before[i] != 2 {
                assert_eq!(after, before[i], "session {i} moved without losing its engine");
            } else {
                assert_ne!(after, 2);
            }
        }
    }

    #[test]
    fn rendezvous_respects_model_assignment() {
        let live: Vec<AtomicBool> = (0..4).map(|_| AtomicBool::new(true)).collect();
        for model in 0..2 {
            for k in 0..32u64 {
                let j = place(mix(k), &live, 2, model).unwrap();
                assert_eq!(j as usize % 2, model);
            }
        }
        // No live engine for the model → None.
        live[1].store(false, Ordering::Release);
        live[3].store(false, Ordering::Release);
        assert_eq!(place(1, &live, 2, 1), None);
        assert!(place(1, &live, 2, 0).is_some());
    }
}
