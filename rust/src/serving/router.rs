//! The serving router: turn-level request loop combining the tiered KV
//! cache, the TENT data plane, and a pluggable model executor.
//!
//! This is the Table-2 workload: multi-turn conversations where each turn's
//! TTFT is cache-lookup + KV fetch (over the transfer engine) + prefill of
//! the uncached suffix + first decode step. Three configurations:
//!
//! * `Baseline`  — no HiCache: every turn recomputes the full history.
//! * `HiCache` + Mooncake TE engine — cache hits, state-blind RDMA fetches.
//! * `HiCache` + TENT engine — cache hits, NVLink/PCIe-aware slice spraying.
//!
//! The model side is any [`ModelExecutor`] — the PJRT `Runtime` when AOT
//! artifacts + a real backend exist, otherwise the deterministic
//! `SyntheticModel` (`ServeConfig::model`, default `Auto`), so the whole
//! loop runs in tier-1 with no artifacts on disk.

use super::client::Conversation;
use super::kvcache::{hash_chunks, KvCacheConfig, TieredKvCache};
use crate::engine::TentEngine;
use crate::log;
use crate::runtime::{ModelExecutor, ModelSelect};
use crate::segment::Location;
use crate::util::clock;
use crate::Result;
use std::sync::Arc;

/// Serving mode for a run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ServeMode {
    /// KV restricted to (working) GPU memory; full recompute per turn.
    Baseline,
    /// Multi-tier KV cache with engine-mediated block movement.
    HiCache,
}

/// Serving run configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub mode: ServeMode,
    pub clients: usize,
    pub turns: usize,
    /// Decode steps per turn (>= 1; the first defines TTFT).
    pub decode_tokens: usize,
    pub cache: KvCacheConfig,
    pub seed: u64,
    pub shared_system_prompt: bool,
    /// Which model executor to serve with (`Auto` = PJRT when artifacts are
    /// available, synthetic otherwise). Consumed by
    /// `runtime::make_executor`; `run_serving` itself takes the executor.
    pub model: ModelSelect,
}

impl ServeConfig {
    /// FNV digest of the run-shaping knobs (canonical JSON via
    /// `util::canon`) — printed in [`ServeReport::header`] so two result
    /// tables are comparable at a glance.
    pub fn digest(&self) -> u64 {
        use crate::util::json::Json;
        crate::util::canon::digest_json(&Json::obj(vec![
            (
                "mode",
                Json::str(match self.mode {
                    ServeMode::Baseline => "baseline",
                    ServeMode::HiCache => "hicache",
                }),
            ),
            ("clients", Json::num(self.clients as f64)),
            ("turns", Json::num(self.turns as f64)),
            ("decode_tokens", Json::num(self.decode_tokens as f64)),
            ("seed", Json::str(&self.seed.to_string())),
            ("shared_system_prompt", Json::Bool(self.shared_system_prompt)),
            ("gpus", Json::num(self.cache.gpus as f64)),
        ]))
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            mode: ServeMode::HiCache,
            clients: 8,
            turns: 5,
            decode_tokens: 4,
            cache: KvCacheConfig::default(),
            seed: 7,
            shared_system_prompt: true,
            model: ModelSelect::Auto,
        }
    }
}

/// Per-turn measurements.
#[derive(Clone, Copy, Debug)]
pub struct TurnMetrics {
    pub client: usize,
    pub turn: usize,
    pub input_tokens: usize,
    pub cached_blocks: usize,
    pub fetched_bytes: u64,
    pub ttft_ns: u64,
    /// Mean per-output-token latency over decode steps 2..n (0 if n == 1).
    pub tpot_ns: u64,
    /// Decode steps actually executed this turn (>= 1; fewer than
    /// `ServeConfig::decode_tokens` when the context hits `t_max`).
    pub decode_steps: usize,
    pub total_ns: u64,
}

/// Run-level report (the Table 2 row).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub mode: ServeMode,
    pub policy: &'static str,
    /// Executor that served the run ("pjrt" / "synthetic").
    pub model: &'static str,
    pub turns: Vec<TurnMetrics>,
    pub wall_ns: u64,
    pub input_tokens_total: usize,
    /// Seed the run was driven with (reproducibility handle).
    pub seed: u64,
    /// [`ServeConfig::digest`] of the config that produced this report.
    pub config_digest: u64,
}

impl ServeReport {
    /// One-line run identity: mode, policy, model, plus the seed and
    /// config digest that make the numbers below reproducible.
    pub fn header(&self) -> String {
        format!(
            "mode={:?} policy={} model={} seed={:#x} config={}",
            self.mode,
            self.policy,
            self.model,
            self.seed,
            crate::util::canon::digest_hex(self.config_digest)
        )
    }

    /// The semantic (timing-free) turn table: `(client, turn, input_tokens,
    /// cached_blocks, fetched_bytes)` per served turn. Two runs with the
    /// same `ServeConfig::seed` and executor must produce identical tables
    /// — the determinism contract the property tests assert.
    pub fn turn_table(&self) -> Vec<(usize, usize, usize, usize, u64)> {
        self.turns
            .iter()
            .map(|t| (t.client, t.turn, t.input_tokens, t.cached_blocks, t.fetched_bytes))
            .collect()
    }

    pub fn input_throughput_tok_s(&self) -> f64 {
        self.input_tokens_total as f64 / (self.wall_ns as f64 / 1e9)
    }
    pub fn avg_ttft_s(&self) -> f64 {
        if self.turns.is_empty() {
            return 0.0;
        }
        self.turns.iter().map(|t| t.ttft_ns as f64).sum::<f64>() / self.turns.len() as f64 / 1e9
    }
    /// TTFT distribution over all served turns in the shared log-bucketed
    /// histogram — the *same* quantile definition the bench PASS/FAIL gates
    /// use, so report percentiles and gate thresholds are comparable.
    pub fn ttft_hist(&self) -> crate::util::hist::Histogram {
        let h = crate::util::hist::Histogram::new();
        for t in &self.turns {
            h.record(t.ttft_ns);
        }
        h
    }
    pub fn p90_ttft_s(&self) -> f64 {
        if self.turns.is_empty() {
            return 0.0;
        }
        self.ttft_hist().p90() as f64 / 1e9
    }
    pub fn p99_ttft_s(&self) -> f64 {
        if self.turns.is_empty() {
            return 0.0;
        }
        self.ttft_hist().p99() as f64 / 1e9
    }
    /// Average TTFT of a specific round (1-based, like the paper's R1/R5/R10).
    pub fn round_avg_ttft_s(&self, round: usize) -> f64 {
        let xs: Vec<f64> = self
            .turns
            .iter()
            .filter(|t| t.turn + 1 == round)
            .map(|t| t.ttft_ns as f64 / 1e9)
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }
}

/// Serve scripted conversations and measure.
pub fn run_serving(
    engine: &Arc<TentEngine>,
    model: &dyn ModelExecutor,
    conversations: &[Conversation],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let meta = model.meta();
    // The last turn's first decode lands at turns·t_pre, so the history
    // must leave at least one position of context headroom. Fail up front
    // with a clear message instead of erroring deep inside the turn loop.
    let max_turns = (meta.t_max / meta.t_pre).saturating_sub(1);
    if cfg.turns > max_turns {
        return Err(crate::Error::Config(format!(
            "turns {} exceeds the model's context budget (t_max {} / t_pre {} chunks, minus \
             decode headroom): max {max_turns}",
            cfg.turns, meta.t_max, meta.t_pre
        )));
    }
    let cache = match cfg.mode {
        ServeMode::HiCache => Some(TieredKvCache::new(engine, meta, cfg.cache.clone())?),
        ServeMode::Baseline => None,
    };
    // One working KV segment per GPU ("HBM scratch" for the active request).
    // Clients share the slot of their assigned GPU, so a turn never finds
    // its previous KV resident — it must come back through the cache tiers,
    // as in a memory-constrained production node.
    let working: Vec<_> = (0..cfg.cache.gpus)
        .map(|g| engine.register_segment(Location::device(cfg.cache.node, g), meta.kv_bytes))
        .collect::<Result<Vec<_>>>()?;

    let mut metrics = Vec::new();
    let wall_start = clock::now_ns();
    let mut input_tokens_total = 0usize;

    // Turn-major order: all clients' turn t arrive together (concurrency =
    // clients), served FIFO by the single model executor — queueing is part
    // of TTFT, as user-visible.
    for t in 0..cfg.turns {
        let arrivals = clock::now_ns();
        for conv in conversations {
            let m = serve_turn(engine, model, cache.as_ref(), &working, conv, t, cfg, arrivals)?;
            input_tokens_total += m.input_tokens;
            metrics.push(m);
        }
    }

    Ok(ServeReport {
        mode: cfg.mode,
        policy: match engine.policy_kind() {
            crate::policy::PolicyKind::Tent => "TENT",
            k => k.name(),
        },
        model: model.name(),
        turns: metrics,
        wall_ns: clock::now_ns() - wall_start,
        input_tokens_total,
        seed: cfg.seed,
        config_digest: cfg.digest(),
    })
}

#[allow(clippy::too_many_arguments)]
fn serve_turn(
    engine: &Arc<TentEngine>,
    model: &dyn ModelExecutor,
    cache: Option<&TieredKvCache>,
    working: &[crate::segment::SegmentId],
    conv: &Conversation,
    turn: usize,
    cfg: &ServeConfig,
    arrival_ns: u64,
) -> Result<TurnMetrics> {
    let meta = model.meta();
    let t_pre = meta.t_pre;
    let history = &conv.chunks[..=turn]; // chunks 0..=turn
    let input_tokens = t_pre; // new tokens this turn
    let wseg = working[conv.gpu as usize];

    let mut cached_blocks = 0usize;
    let mut fetched_bytes = 0u64;

    // 1. Assemble the KV state up to `turn` chunks.
    let (mut kv, mut next_token, start_chunk) = match cache {
        Some(cache) => {
            let hashes = hash_chunks(history);
            // Reuse covers prior turns' chunks; the new chunk is computed.
            let reusable = &hashes[..turn];
            let hit = cache.lookup_prefix(reusable);
            cached_blocks = hit;
            // Fetch hit blocks into the working segment via the engine.
            fetched_bytes = cache.fetch_prefix(engine, reusable, hit, wseg)?;
            let kv = if hit > 0 {
                // Materialize only the fetched prefix into the executor's
                // KV; the tail beyond `hit` blocks is zeroed. The working
                // segment is shared across clients on this GPU slot, so a
                // whole-segment read would carry stale KV bytes from
                // whichever request used the slot last.
                model.kv_from_bytes(&cache.materialize_prefix_bytes(engine, wseg, hit)?)?
            } else {
                model.empty_kv()?
            };
            (kv, 0i32, hit)
        }
        None => (model.empty_kv()?, 0i32, 0),
    };

    // 2. Prefill uncached chunks (all of them for Baseline).
    for (k, chunk) in history.iter().enumerate().skip(start_chunk) {
        let (tok, kv2) = model.prefill(chunk, kv, (k * t_pre) as i32)?;
        kv = kv2;
        next_token = tok;
    }

    // 3. First decode step → TTFT.
    let seq_len = (history.len() * t_pre) as i32;
    let (mut tok, mut kv_cur) = model.decode(next_token, kv, seq_len)?;
    let ttft_ns = clock::now_ns() - arrival_ns;

    // 4. Remaining decode steps → TPOT. (Generated tokens are not appended
    // to the scripted history; see DESIGN.md.) The loop breaks early when
    // the context fills, so the mean divides by the steps actually run —
    // dividing by the *requested* count understates TPOT near `t_max`.
    let mut tpot_total = 0u64;
    let mut extra_steps = 0u64;
    for i in 1..cfg.decode_tokens {
        let t0 = clock::now_ns();
        let pos = seq_len + i as i32;
        if (pos as usize) >= meta.t_max {
            break;
        }
        let (t2, kv2) = model.decode(tok, kv_cur, pos)?;
        tok = t2;
        kv_cur = kv2;
        tpot_total += clock::now_ns() - t0;
        extra_steps += 1;
    }
    let tpot_ns = if extra_steps > 0 { tpot_total / extra_steps } else { 0 };

    // 5. Write back: store this turn's new blocks (write-through via the
    // engine). The working segment must hold the final KV bytes first.
    let store_start = clock::now_ns();
    if let Some(cache) = cache {
        let seg = engine.segment(wseg)?;
        // Borrow host-resident KV bytes directly (synthetic executor);
        // only the PJRT literal path pays a conversion copy.
        match kv_cur.as_host_bytes() {
            Some(raw) => seg.write_at(0, raw)?,
            None => seg.write_at(0, &kv_cur.to_bytes()?)?,
        }
        let hashes = hash_chunks(history);
        for (k, h) in hashes.iter().enumerate().skip(start_chunk) {
            // Home blocks by content hash — spreads the pool across GPUs,
            // creating the peer-GPU (NVLink vs RDMA) fetch traffic.
            let home = (*h % cache.config().gpus as u64) as u8;
            cache.store_block(engine, *h, home, wseg, k)?;
        }
    }
    log::debug!(
        "turn client={} turn={} ttft={} store={} total={}",
        conv.client,
        turn,
        crate::util::fmt_ns(ttft_ns),
        crate::util::fmt_ns(clock::now_ns() - store_start),
        crate::util::fmt_ns(clock::now_ns() - arrival_ns)
    );

    Ok(TurnMetrics {
        client: conv.client,
        turn,
        input_tokens,
        cached_blocks,
        fetched_bytes,
        ttft_ns,
        tpot_ns,
        decode_steps: 1 + extra_steps as usize,
        total_ns: clock::now_ns() - arrival_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_percentiles() {
        let r = report((1..=10u64).map(|i| i * 1_000_000_000).collect());
        assert!((r.avg_ttft_s() - 5.5).abs() < 1e-9);
        // The histogram's log buckets report quantiles within ~3% (high) of
        // the exact nearest-rank value.
        let p90 = r.p90_ttft_s();
        assert!((9.0..9.3).contains(&p90), "p90 {p90} outside histogram tolerance of 9.0");
        assert!((r.round_avg_ttft_s(1) - 1.0).abs() < 1e-9);
        assert!((r.input_throughput_tok_s() - 128.0).abs() < 1e-9);
        assert_eq!(r.round_avg_ttft_s(99), 0.0);
        assert_eq!(r.turn_table().len(), 10);
        assert_eq!(r.turn_table()[0], (0, 0, 128, 0, 0));
        // The header names the reproducibility handle.
        let h = r.header();
        assert!(h.contains("seed=0x7") && h.contains("config="), "{h}");
        assert_eq!(r.config_digest, ServeConfig::default().digest());
    }

    #[test]
    fn p90_uses_shared_quantile_definition() {
        // Two samples, 1 s and 10 s. The old ad-hoc nearest-rank index
        // `v[(len-1)*9/10]` = v[0] reported the *minimum* (1.0 s) as P90;
        // `Histogram::quantile(0.9)` ranks ceil(0.9·2) = 2 → the 10 s
        // sample (within log-bucket tolerance).
        let r = report(vec![1_000_000_000, 10_000_000_000]);
        let p90 = r.p90_ttft_s();
        assert!(p90 >= 9.5, "p90 {p90} still reporting the low sample");
        assert!(r.p99_ttft_s() >= 9.5);
        // Empty report stays well-defined.
        assert_eq!(report(Vec::new()).p90_ttft_s(), 0.0);
    }

    fn report(ttfts: Vec<u64>) -> ServeReport {
        let total = ttfts.len();
        let mk = |ttft: u64, turn: usize| TurnMetrics {
            client: 0,
            turn,
            input_tokens: 128,
            cached_blocks: 0,
            fetched_bytes: 0,
            ttft_ns: ttft,
            tpot_ns: 0,
            decode_steps: 1,
            total_ns: ttft,
        };
        ServeReport {
            mode: ServeMode::HiCache,
            policy: "TENT",
            model: "synthetic",
            turns: ttfts.into_iter().enumerate().map(|(i, t)| mk(t, i)).collect(),
            wall_ns: 10_000_000_000,
            input_tokens_total: total * 128,
            seed: 7,
            config_digest: ServeConfig::default().digest(),
        }
    }
}
