//! HiCache-style multi-tier KV cache.
//!
//! KV blocks (one prefill chunk = 128 tokens ≈ 1 MiB of cache) live in a
//! three-tier hierarchy — per-GPU HBM pools, a host-DRAM pool, and an
//! SSD-backed file pool — indexed by a prefix chain hash (the block-granular
//! equivalent of RadixAttention's prefix tree). Every promotion / demotion /
//! fetch moves *real bytes* through the TENT engine as batched transfers, so
//! the transfer policy (TENT vs Mooncake TE) is the only variable in the
//! Table 2 comparison:
//!
//! * peer-GPU block fetch → D2D (TENT: NVLink first; TE: always RDMA),
//! * host-tier fetch → H2D (TENT: PCIe rail; TE: GPUDirect-RDMA loopback),
//! * disk-tier fetch → file I/O.
//!
//! A block in the working KV layout `[L, 2, H, T, D]` is **strided**: 2·L·H
//! planes of `128·D` floats. Fetch/store therefore issue one batched
//! transfer of 2·L·H sub-requests per block — exactly the gather/scatter
//! shape of production KV movement.
//!
//! The store is model-free: it only needs a [`ModelMeta`] for block
//! geometry (`ModelMeta::tiny_gpt()` works with no artifacts on disk), so
//! every tier-movement property is testable in tier-1.

use crate::engine::{TentEngine, TransferClass, TransferReq};
use crate::runtime::ModelMeta;
use crate::segment::{Location, SegmentId};
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// FNV-1a chain hash over a token chunk: `h_k = fnv(h_{k-1} ‖ chunk_k)`.
/// Equal prefixes → equal chains, so a chunk's hash identifies the whole
/// prefix up to and including it (radix-tree equivalence at block
/// granularity).
pub fn chain_hash(parent: u64, chunk: &[i32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ parent.rotate_left(17);
    for t in chunk {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Hash chain for a full history of chunks.
pub fn hash_chunks(chunks: &[Vec<i32>]) -> Vec<u64> {
    let mut out = Vec::with_capacity(chunks.len());
    let mut parent = 0;
    for c in chunks {
        parent = chain_hash(parent, c);
        out.push(parent);
    }
    out
}

/// Which tier a block's *primary* copy lives in.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TierId {
    Gpu(u8),
    Cpu,
    Disk,
}

#[derive(Clone, Debug)]
struct Entry {
    tier: TierId,
    /// Block index within the tier's pool segment.
    slot: usize,
    /// CPU write-through shadow slot (present while primary is on a GPU).
    cpu_shadow: Option<usize>,
    last_use: u64,
}

struct Pool {
    seg: SegmentId,
    free: Vec<usize>,
}

struct CacheState {
    gpu_pools: Vec<Pool>,
    cpu_pool: Pool,
    disk_pool: Pool,
    index: HashMap<u64, Entry>,
}

/// Cache configuration (block counts per tier).
#[derive(Clone, Debug)]
pub struct KvCacheConfig {
    pub gpus: u8,
    pub gpu_blocks_per_gpu: usize,
    pub cpu_blocks: usize,
    pub disk_blocks: usize,
    pub node: u16,
    pub disk_path: std::path::PathBuf,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            gpus: 8,
            gpu_blocks_per_gpu: 3,
            cpu_blocks: 200,
            disk_blocks: 1024,
            node: 0,
            disk_path: std::env::temp_dir().join(format!("tent_kv_{}.pool", std::process::id())),
        }
    }
}

/// Counters for the serving report.
#[derive(Default)]
pub struct CacheStats {
    pub lookups: AtomicU64,
    pub hit_blocks: AtomicU64,
    pub miss_blocks: AtomicU64,
    pub fetched_blocks: AtomicU64,
    pub fetched_bytes: AtomicU64,
    pub stored_blocks: AtomicU64,
    pub gpu_evictions: AtomicU64,
    pub cpu_demotions: AtomicU64,
    pub fetch_gpu_tier: AtomicU64,
    pub fetch_cpu_tier: AtomicU64,
    pub fetch_disk_tier: AtomicU64,
}

/// The tiered store.
pub struct TieredKvCache {
    cfg: KvCacheConfig,
    /// Base byte offset of each (l, s, h) plane in the working KV layout.
    stride_bases: Vec<u64>,
    /// Bytes of one block within one plane (= T_pre · D · 4).
    plane_chunk_bytes: u64,
    /// Total bytes of one block (= planes · plane_chunk_bytes).
    block_bytes: u64,
    /// Full working-KV byte size (`ModelMeta::kv_bytes`).
    kv_bytes: u64,
    tokens_per_block: usize,
    state: Mutex<CacheState>,
    clock: AtomicU64,
    pub stats: CacheStats,
}

impl TieredKvCache {
    /// Build pools + index; registers one pool segment per GPU, one host
    /// pool, one file pool.
    pub fn new(engine: &TentEngine, meta: &ModelMeta, cfg: KvCacheConfig) -> Result<TieredKvCache> {
        let tokens_per_block = meta.t_pre;
        let d = meta.head_dim;
        let plane_chunk_bytes = (tokens_per_block * d * 4) as u64;
        let planes = meta.layers * 2 * meta.heads;
        let block_bytes = plane_chunk_bytes * planes as u64;
        let mut stride_bases = Vec::with_capacity(planes);
        for l in 0..meta.layers {
            for s in 0..2 {
                for h in 0..meta.heads {
                    let plane = ((l * 2 + s) * meta.heads + h) as u64;
                    stride_bases.push(plane * (meta.t_max * d * 4) as u64);
                }
            }
        }
        let gpu_pools = (0..cfg.gpus)
            .map(|g| {
                let len = block_bytes * cfg.gpu_blocks_per_gpu as u64;
                let seg = engine.register_segment(Location::device(cfg.node, g), len)?;
                Ok(Pool {
                    seg,
                    free: (0..cfg.gpu_blocks_per_gpu).rev().collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let cpu_pool = Pool {
            seg: engine
                .register_segment(Location::host(cfg.node, 0), block_bytes * cfg.cpu_blocks as u64)?,
            free: (0..cfg.cpu_blocks).rev().collect(),
        };
        let disk_pool = Pool {
            seg: engine.register_file_segment(
                Location::storage(cfg.node, cfg.disk_path.clone()),
                block_bytes * cfg.disk_blocks as u64,
            )?,
            free: (0..cfg.disk_blocks).rev().collect(),
        };
        Ok(TieredKvCache {
            stride_bases,
            plane_chunk_bytes,
            block_bytes,
            kv_bytes: meta.kv_bytes,
            tokens_per_block,
            state: Mutex::new(CacheState {
                gpu_pools,
                cpu_pool,
                disk_pool,
                index: HashMap::new(),
            }),
            clock: AtomicU64::new(1),
            cfg,
            stats: CacheStats::default(),
        })
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }
    pub fn tokens_per_block(&self) -> usize {
        self.tokens_per_block
    }
    /// Number of strided planes per block (2·L·H).
    pub fn plane_count(&self) -> usize {
        self.stride_bases.len()
    }
    /// Bytes of one block within one plane (= T_pre · D · 4).
    pub fn plane_chunk_bytes(&self) -> u64 {
        self.plane_chunk_bytes
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// How many leading blocks of `hashes` are cached (any tier).
    pub fn lookup_prefix(&self, hashes: &[u64]) -> usize {
        self.stats.lookups.fetch_add(1, Ordering::Relaxed);
        let t = self.tick();
        let mut st = self.state.lock().unwrap();
        let mut n = 0;
        for h in hashes {
            match st.index.get_mut(h) {
                Some(e) => {
                    e.last_use = t;
                    n += 1;
                }
                None => break,
            }
        }
        self.stats.hit_blocks.fetch_add(n as u64, Ordering::Relaxed);
        self.stats
            .miss_blocks
            .fetch_add((hashes.len() - n) as u64, Ordering::Relaxed);
        n
    }

    /// Transfer requests moving pool block `slot` ↔ the strided planes of
    /// block position `k` in a working KV segment.
    fn block_reqs(
        &self,
        pool_seg: SegmentId,
        slot: usize,
        working: SegmentId,
        k: usize,
        to_working: bool,
        out: &mut Vec<TransferReq>,
    ) {
        let row = k as u64 * self.plane_chunk_bytes;
        let pool_base = slot as u64 * self.block_bytes;
        for (i, &base) in self.stride_bases.iter().enumerate() {
            let w_off = base + row;
            let p_off = pool_base + i as u64 * self.plane_chunk_bytes;
            // KV-block movement gates prefill/decode, so it rides the
            // latency lane — a concurrent checkpoint burst on the same
            // rails can no longer head-of-line block it.
            out.push(if to_working {
                TransferReq::read(pool_seg, p_off, working, w_off, self.plane_chunk_bytes)
                    .class(TransferClass::Latency)
            } else {
                TransferReq::write(working, w_off, pool_seg, p_off, self.plane_chunk_bytes)
                    .class(TransferClass::Latency)
            });
        }
    }

    /// Fetch the first `n` blocks of `hashes` into the working segment
    /// (block `i` lands at chunk position `i`); one engine batch for the
    /// whole gather. Returns bytes moved.
    pub fn fetch_prefix(
        &self,
        engine: &TentEngine,
        hashes: &[u64],
        n: usize,
        working: SegmentId,
    ) -> Result<u64> {
        if n == 0 {
            return Ok(0);
        }
        let mut reqs = Vec::with_capacity(n * self.stride_bases.len());
        {
            let t = self.tick();
            let mut st = self.state.lock().unwrap();
            for (k, h) in hashes.iter().take(n).enumerate() {
                let e = st
                    .index
                    .get_mut(h)
                    .ok_or_else(|| Error::TransferFailed(format!("block {h:#x} vanished")))?
                    .clone();
                st.index.get_mut(h).unwrap().last_use = t;
                let (seg, counter) = match e.tier {
                    TierId::Gpu(g) => (st.gpu_pools[g as usize].seg, &self.stats.fetch_gpu_tier),
                    TierId::Cpu => (st.cpu_pool.seg, &self.stats.fetch_cpu_tier),
                    TierId::Disk => (st.disk_pool.seg, &self.stats.fetch_disk_tier),
                };
                counter.fetch_add(1, Ordering::Relaxed);
                self.block_reqs(seg, e.slot, working, k, true, &mut reqs);
            }
        }
        let batch = engine.allocate_batch();
        engine.submit(batch, &reqs)?;
        engine.wait(batch, Duration::from_secs(120))?;
        engine.release_batch(batch)?;
        let bytes = n as u64 * self.block_bytes;
        self.stats.fetched_blocks.fetch_add(n as u64, Ordering::Relaxed);
        self.stats.fetched_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(bytes)
    }

    /// Materialize the first `hit` cached blocks of the shared working
    /// segment into a full-size KV byte buffer whose tail (every position
    /// `>= hit` blocks, in every plane) is **zero**. The working segment is
    /// shared between clients on a GPU slot, so reading it whole would copy
    /// whichever bytes the previous request left beyond the fetched prefix
    /// — stale KV that the subsequent prefill of *this* request's suffix
    /// never overwrites row-for-row. Only the `hit · t_pre` leading rows of
    /// each strided plane are read.
    pub fn materialize_prefix_bytes(
        &self,
        engine: &TentEngine,
        working: SegmentId,
        hit: usize,
    ) -> Result<Vec<u8>> {
        let mut raw = vec![0u8; self.kv_bytes as usize];
        if hit == 0 {
            return Ok(raw);
        }
        let span = (hit as u64 * self.plane_chunk_bytes) as usize;
        let seg = engine.segment(working)?;
        for &base in &self.stride_bases {
            let start = base as usize;
            seg.read_at(base, &mut raw[start..start + span])?;
        }
        Ok(raw)
    }

    /// Store block `k` of the working segment under `hash`, homed on
    /// `home_gpu` with write-through to the CPU tier. No-op if cached.
    pub fn store_block(
        &self,
        engine: &TentEngine,
        hash: u64,
        home_gpu: u8,
        working: SegmentId,
        k: usize,
    ) -> Result<()> {
        let (gpu_seg, gpu_slot, cpu_seg, cpu_slot) = {
            let mut st = self.state.lock().unwrap();
            if st.index.contains_key(&hash) {
                return Ok(());
            }
            let gpu_slot = self.alloc_gpu_slot(&mut st, home_gpu)?;
            let cpu_slot = self.alloc_cpu_slot(engine, &mut st)?;
            let gpu_seg = st.gpu_pools[home_gpu as usize].seg;
            let cpu_seg = st.cpu_pool.seg;
            st.index.insert(
                hash,
                Entry {
                    tier: TierId::Gpu(home_gpu),
                    slot: gpu_slot,
                    cpu_shadow: Some(cpu_slot),
                    last_use: self.tick(),
                },
            );
            (gpu_seg, gpu_slot, cpu_seg, cpu_slot)
        };
        let mut reqs = Vec::with_capacity(2 * self.stride_bases.len());
        self.block_reqs(gpu_seg, gpu_slot, working, k, false, &mut reqs);
        self.block_reqs(cpu_seg, cpu_slot, working, k, false, &mut reqs);
        let batch = engine.allocate_batch();
        engine.submit(batch, &reqs)?;
        engine.wait(batch, Duration::from_secs(120))?;
        engine.release_batch(batch)?;
        self.stats.stored_blocks.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Allocate a slot in `gpu`'s pool, evicting the pool's LRU block to its
    /// CPU shadow (metadata-only flip; write-through already put the bytes
    /// there) when full.
    fn alloc_gpu_slot(&self, st: &mut CacheState, gpu: u8) -> Result<usize> {
        if let Some(s) = st.gpu_pools[gpu as usize].free.pop() {
            return Ok(s);
        }
        let victim = st
            .index
            .iter()
            .filter(|(_, e)| e.tier == TierId::Gpu(gpu))
            .min_by_key(|(_, e)| e.last_use)
            .map(|(h, e)| (*h, e.slot, e.cpu_shadow));
        let (vh, vslot, shadow) = victim.ok_or_else(|| {
            Error::Config(format!("gpu{gpu} pool exhausted with no evictable blocks"))
        })?;
        let shadow = shadow.ok_or_else(|| Error::Config("evicted block lost its shadow".into()))?;
        let e = st.index.get_mut(&vh).unwrap();
        e.tier = TierId::Cpu;
        e.slot = shadow;
        e.cpu_shadow = None;
        st.gpu_pools[gpu as usize].free.push(vslot);
        self.stats.gpu_evictions.fetch_add(1, Ordering::Relaxed);
        Ok(st.gpu_pools[gpu as usize].free.pop().unwrap())
    }

    /// Allocate a CPU slot, demoting the LRU CPU-primary block to disk
    /// (real copy) when full.
    fn alloc_cpu_slot(&self, engine: &TentEngine, st: &mut CacheState) -> Result<usize> {
        if let Some(s) = st.cpu_pool.free.pop() {
            return Ok(s);
        }
        let victim = st
            .index
            .iter()
            .filter(|(_, e)| e.tier == TierId::Cpu)
            .min_by_key(|(_, e)| e.last_use)
            .map(|(h, e)| (*h, e.slot));
        let (vh, vslot) = victim.ok_or_else(|| {
            // All CPU slots are shadows of GPU blocks; reclaim the LRU
            // GPU block's shadow instead (it keeps its GPU primary).
            Error::Config("cpu pool exhausted (all slots are live shadows)".into())
        })?;
        let disk_slot = st
            .disk_pool
            .free
            .pop()
            .ok_or_else(|| Error::Config("disk pool exhausted".into()))?;
        // Tier demotion is background housekeeping: it rides the bulk lane
        // so it cannot delay concurrent latency-class KV fetches.
        engine.transfer_sync(
            TransferReq::write(
                st.cpu_pool.seg,
                vslot as u64 * self.block_bytes,
                st.disk_pool.seg,
                disk_slot as u64 * self.block_bytes,
                self.block_bytes,
            )
            .class(TransferClass::Bulk),
            Duration::from_secs(120),
        )?;
        let e = st.index.get_mut(&vh).unwrap();
        e.tier = TierId::Disk;
        e.slot = disk_slot;
        st.cpu_pool.free.push(vslot);
        self.stats.cpu_demotions.fetch_add(1, Ordering::Relaxed);
        Ok(st.cpu_pool.free.pop().unwrap())
    }

    /// Tier occupancy for reports: (gpu, cpu, disk) primary-block counts.
    pub fn occupancy(&self) -> (usize, usize, usize) {
        let st = self.state.lock().unwrap();
        let (mut g, mut c, mut d) = (0, 0, 0);
        for e in st.index.values() {
            match e.tier {
                TierId::Gpu(_) => g += 1,
                TierId::Cpu => c += 1,
                TierId::Disk => d += 1,
            }
        }
        (g, c, d)
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_prefix_property() {
        let a = vec![1i32, 2, 3];
        let b = vec![4i32, 5, 6];
        let c = vec![7i32, 8, 9];
        let h1 = hash_chunks(&[a.clone(), b.clone()]);
        let h2 = hash_chunks(&[a.clone(), b.clone(), c.clone()]);
        assert_eq!(h1[0], h2[0]);
        assert_eq!(h1[1], h2[1]);
        let h3 = hash_chunks(&[c, b]);
        assert_ne!(h1[0], h3[0]);
        assert_ne!(h1[1], h3[1]);
    }

    #[test]
    fn chain_hash_sensitive_to_order() {
        assert_ne!(chain_hash(0, &[1, 2]), chain_hash(0, &[2, 1]));
    }

    #[test]
    fn chain_hash_sensitive_to_parent() {
        assert_ne!(chain_hash(1, &[1, 2]), chain_hash(2, &[1, 2]));
    }
}
