//! Checkpoint-engine analogue (Moonshot Checkpoint Engine, Table 3):
//! in-place model weight updates pushed from a trainer's host memory to
//! every inference rank's GPU memory through the transfer engine.
//!
//! The update is a **pipelined ring broadcast** with all ranks
//! participating: the payload is cut into chunks; chunk `i` flows
//! host → GPU₀ → GPU₁ → … → GPU₇, with each hop running in its own thread
//! so hops overlap across chunks. Per-hop transport choice is exactly the
//! engine-policy variable the paper measures: TENT rides PCIe for H2D and
//! NVLink for the D2D hops; Mooncake TE pins everything to RDMA.

use crate::engine::{TentEngine, TransferClass, TransferReq};
use crate::segment::{Location, SegmentId};
use crate::util::clock;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Update configuration.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Total weight payload in bytes.
    pub payload_bytes: u64,
    /// Number of inference ranks (GPUs) to update.
    pub ranks: u8,
    /// Pipeline chunk size.
    pub chunk_bytes: u64,
    pub node: u16,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        CheckpointConfig {
            payload_bytes: 17_441_792, // TinyGPT params.bin
            ranks: 8,
            chunk_bytes: 2 << 20,
            node: 0,
        }
    }
}

/// Outcome of one update.
#[derive(Clone, Debug)]
pub struct UpdateReport {
    pub total_ns: u64,
    pub payload_bytes: u64,
    pub ranks: u8,
    pub chunks: usize,
    /// Bytes moved across all hops: the ring has exactly `ranks` hops
    /// (host→rank₀ plus rank_{k-1}→rank_k for k = 1..ranks), each carrying
    /// the full payload, so this equals `payload_bytes × ranks` and must
    /// match the sum of the fabric's per-rail byte counters.
    pub bytes_moved: u64,
}

impl UpdateReport {
    pub fn seconds(&self) -> f64 {
        self.total_ns as f64 / 1e9
    }
}

/// The checkpoint engine: source host segment + per-rank device segments.
pub struct CheckpointEngine {
    engine: Arc<TentEngine>,
    cfg: CheckpointConfig,
    pub src: SegmentId,
    pub rank_segs: Vec<SegmentId>,
}

impl CheckpointEngine {
    pub fn new(engine: Arc<TentEngine>, cfg: CheckpointConfig) -> Result<CheckpointEngine> {
        let src = engine.register_segment(Location::host(cfg.node, 0), cfg.payload_bytes)?;
        let rank_segs = (0..cfg.ranks)
            .map(|g| engine.register_segment(Location::device(cfg.node, g), cfg.payload_bytes))
            .collect::<Result<Vec<_>>>()?;
        Ok(CheckpointEngine {
            engine,
            cfg,
            src,
            rank_segs,
        })
    }

    /// Load the new weights into the trainer-side host segment.
    pub fn stage_weights(&self, raw: &[u8]) -> Result<()> {
        assert_eq!(raw.len() as u64, self.cfg.payload_bytes);
        self.engine.segment(self.src)?.write_at(0, raw)
    }

    /// Run one in-place update: pipelined ring broadcast to all ranks.
    /// Returns once every rank holds the full payload.
    pub fn update(&self) -> Result<UpdateReport> {
        let cfg = &self.cfg;
        let n_chunks = cfg.payload_bytes.div_ceil(cfg.chunk_bytes) as usize;
        // The ring has exactly `ranks` hops: host→rank₀, then
        // rank_{k-1}→rank_k for k = 1..ranks. (An off-by-one here used to
        // allocate `ranks + 1` rows with a dead, never-written last row.)
        let hops = cfg.ranks as usize;
        let start = clock::now_ns();

        // done[h][c] = hop h has delivered chunk c. Hop 0 = host→rank0,
        // hop k (k≥1) = rank_{k-1} → rank_k.
        let done: Arc<Vec<Vec<AtomicU64>>> = Arc::new(
            (0..hops)
                .map(|_| (0..n_chunks).map(|_| AtomicU64::new(0)).collect())
                .collect(),
        );

        let mut handles = Vec::new();
        for hop in 0..hops {
            let engine = Arc::clone(&self.engine);
            let done = Arc::clone(&done);
            let (src_seg, dst_seg) = if hop == 0 {
                (self.src, self.rank_segs[0])
            } else {
                (self.rank_segs[hop - 1], self.rank_segs[hop])
            };
            let payload = cfg.payload_bytes;
            let chunk = cfg.chunk_bytes;
            handles.push(std::thread::spawn(move || -> Result<()> {
                for c in 0..n_chunks {
                    // Wait for upstream hop to deliver chunk c.
                    if hop > 0 {
                        while done[hop - 1][c].load(Ordering::Acquire) == 0 {
                            std::thread::yield_now();
                        }
                    }
                    let off = c as u64 * chunk;
                    let len = chunk.min(payload - off);
                    // Weight broadcast is the canonical bulk flow: explicit
                    // `Bulk` class keeps it out of the latency lane shared
                    // with KV-cache fetches.
                    engine.transfer_sync(
                        TransferReq::write(src_seg, off, dst_seg, off, len)
                            .class(TransferClass::Bulk),
                        Duration::from_secs(300),
                    )?;
                    done[hop][c].store(1, Ordering::Release);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("hop thread panicked")?;
        }
        Ok(UpdateReport {
            total_ns: clock::now_ns() - start,
            payload_bytes: cfg.payload_bytes,
            ranks: cfg.ranks,
            chunks: n_chunks,
            bytes_moved: cfg.payload_bytes * cfg.ranks as u64,
        })
    }

    /// Verify every rank holds exactly the staged payload.
    pub fn verify(&self) -> Result<bool> {
        let src = self.engine.segment(self.src)?;
        let mut want = vec![0u8; self.cfg.payload_bytes as usize];
        src.read_at(0, &mut want)?;
        let mut got = vec![0u8; self.cfg.payload_bytes as usize];
        for seg in &self.rank_segs {
            self.engine.segment(*seg)?.read_at(0, &mut got)?;
            if got != want {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Install rank `rank`'s broadcast weights into a model executor — the
    /// RL-pipeline handoff: weights arrive over TENT, then serve traffic.
    pub fn install_into(
        &self,
        rank: usize,
        model: &mut dyn crate::runtime::ModelExecutor,
    ) -> Result<()> {
        let params = self.rank_params_f32(rank)?;
        model.install_params(&params)
    }

    /// Read back one rank's weights as f32 (for `install_params`).
    pub fn rank_params_f32(&self, rank: usize) -> Result<Vec<f32>> {
        let seg = self.engine.segment(self.rank_segs[rank])?;
        let mut raw = vec![0u8; self.cfg.payload_bytes as usize];
        seg.read_at(0, &mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn config(&self) -> &CheckpointConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;
    use crate::engine::EngineConfig;

    #[test]
    fn broadcast_delivers_to_all_ranks() {
        let c = Cluster::from_profile_nodes("h800_hgx", 1, crate::fabric::FabricConfig::default())
            .unwrap();
        let e = Arc::new(crate::engine::TentEngine::new(&c, EngineConfig::default()).unwrap());
        let cfg = CheckpointConfig {
            payload_bytes: 4 << 20,
            ranks: 4,
            chunk_bytes: 1 << 20,
            node: 0,
        };
        let ce = CheckpointEngine::new(Arc::clone(&e), cfg).unwrap();
        let payload: Vec<u8> = (0..4 << 20).map(|i| (i % 251) as u8).collect();
        ce.stage_weights(&payload).unwrap();
        let rep = ce.update().unwrap();
        assert_eq!(rep.chunks, 4);
        assert!(ce.verify().unwrap());
        assert!(rep.total_ns > 0);
        // Conservation: the ring's byte ledger must equal what the fabric
        // actually carried — `ranks` hops × payload, no phantom hop row.
        // (Poll briefly: batched completion accounting lands at the next
        // worker flush, at most one drain pass behind the final wake-up.)
        assert_eq!(rep.bytes_moved, rep.payload_bytes * rep.ranks as u64);
        let carried_now = || -> u64 { c.fabric.byte_counters().iter().map(|&(_, b)| b).sum() };
        for _ in 0..500 {
            if carried_now() == rep.bytes_moved {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(carried_now(), rep.bytes_moved, "fabric byte ledger drifted");
        // Checkpoint traffic must be accounted entirely under the bulk class.
        let s = e.stats();
        assert!(s.slices_completed_bulk > 0);
        assert_eq!(s.slices_completed_latency, 0);
    }

    #[test]
    fn second_update_with_new_weights() {
        let c = Cluster::from_profile_nodes("h800_hgx", 1, crate::fabric::FabricConfig::default())
            .unwrap();
        let e = Arc::new(crate::engine::TentEngine::new(&c, EngineConfig::default()).unwrap());
        let cfg = CheckpointConfig {
            payload_bytes: 1 << 20,
            ranks: 2,
            chunk_bytes: 256 << 10,
            node: 0,
        };
        let ce = CheckpointEngine::new(Arc::clone(&e), cfg).unwrap();
        for round in 0..2u8 {
            let payload: Vec<u8> = (0..1 << 20).map(|i| (i % 89) as u8 ^ round).collect();
            ce.stage_weights(&payload).unwrap();
            ce.update().unwrap();
            assert!(ce.verify().unwrap(), "round {round}");
        }
    }
}
