//! Disaggregated-LLM-serving consumers of the TENT data plane.
//!
//! * [`kvcache`] — HiCache-style multi-tier KV block store (GPU pools, host
//!   pool, SSD pool) whose tier movement rides the engine.
//! * [`router`] — the turn-major serving loop producing Table 2's metrics
//!   (kept as the FIFO baseline).
//! * [`batching`] — continuous-batching scheduler with SLO admission,
//!   prefix-aware placement, and session affinity over a fleet of engines.
//! * [`client`] — deterministic conversation + session workload generators.
//! * [`checkpoint`] — Moonshot-Checkpoint-Engine analogue: pipelined
//!   weight-update broadcast (Table 3).
//!
//! Everything here is generic over [`crate::runtime::ModelExecutor`], so
//! the whole stack runs in tier-1 on the synthetic executor and switches to
//! PJRT (`--model pjrt`) with no caller changes.

pub mod batching;
pub mod checkpoint;
pub mod client;
pub mod kvcache;
pub mod router;

pub use batching::{
    serve_fleet, BatchConfig, BatchReport, FailurePlan, ReqMetrics, SchedulePolicy, SloConfig,
};
pub use checkpoint::{CheckpointConfig, CheckpointEngine, UpdateReport};
pub use client::{
    build_conversations, build_for, build_sessions, Conversation, RequestClass, SessionScript,
    SessionWorkload,
};
pub use kvcache::{KvCacheConfig, TieredKvCache};
pub use router::{run_serving, ServeConfig, ServeMode, ServeReport};
