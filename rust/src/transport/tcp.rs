//! TCP backend — the universal fallback fabric, implemented over **real
//! loopback sockets** (each sim node gets a receiver listening on
//! 127.0.0.1). Slowest path, always reachable; paced to the profile's
//! nominal TCP bandwidth since loopback outruns a real 10 GbE link.
//!
//! Wire format per slice: `[seg: u64][off: u64][len: u64]` + payload,
//! answered by a 1-byte ack. One-sided-write semantics are preserved: the
//! receiver writes straight into the destination segment at the absolute
//! offset, so retries stay idempotent.

use super::*;
use crate::fabric::Fabric;
use crate::segment::{Segment, SegmentId, SegmentManager};
use crate::topology::{FabricKind, NodeId, RailId, Topology};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::Result;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

pub struct TcpBackend {
    segments: Arc<SegmentManager>,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    /// Receiver port per destination node (lazily started).
    ports: HashMap<NodeId, u16>,
    /// Outbound connection per (src, dst) node pair.
    conns: HashMap<(NodeId, NodeId), Arc<Mutex<TcpStream>>>,
}

impl TcpBackend {
    pub fn new(segments: Arc<SegmentManager>) -> Self {
        TcpBackend {
            segments,
            inner: Mutex::new(Inner::default()),
        }
    }

    fn ensure_receiver(&self, node: NodeId) -> Result<u16> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&p) = inner.ports.get(&node) {
            return Ok(p);
        }
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let port = listener.local_addr()?.port();
        let segs = Arc::clone(&self.segments);
        std::thread::Builder::new()
            .name(format!("tent-tcp-rx-{}", node.0))
            .spawn(move || {
                for stream in listener.incoming() {
                    let Ok(stream) = stream else { continue };
                    let segs = Arc::clone(&segs);
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, &segs);
                    });
                }
            })
            .expect("spawn tcp receiver");
        inner.ports.insert(node, port);
        Ok(port)
    }

    fn connection(&self, src: NodeId, dst: NodeId) -> Result<Arc<Mutex<TcpStream>>> {
        let port = self.ensure_receiver(dst)?;
        let mut inner = self.inner.lock().unwrap();
        if let Some(c) = inner.conns.get(&(src, dst)) {
            return Ok(Arc::clone(c));
        }
        let stream = TcpStream::connect(("127.0.0.1", port))?;
        stream.set_nodelay(true)?;
        let c = Arc::new(Mutex::new(stream));
        inner.conns.insert((src, dst), Arc::clone(&c));
        Ok(c)
    }
}

fn serve_conn(mut stream: TcpStream, segs: &SegmentManager) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    let mut hdr = [0u8; 24];
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stream.read_exact(&mut hdr).is_err() {
            return Ok(()); // peer closed
        }
        let seg = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let off = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(hdr[16..24].try_into().unwrap()) as usize;
        buf.resize(len, 0);
        stream.read_exact(&mut buf)?;
        let status: u8 = match segs.get(SegmentId(seg)) {
            Ok(segment) => match segment.write_at(off, &buf) {
                Ok(()) => 0,
                Err(_) => 1,
            },
            Err(_) => 1,
        };
        stream.write_all(&[status])?;
    }
}

impl TransportBackend for TcpBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::Tcp
    }
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // Host memory only (device memory would need a staged hop first).
        if src.loc.is_device() || dst.loc.is_device() || src.loc.is_storage() || dst.loc.is_storage()
        {
            return Vec::new();
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if !topo.node_in_fabric(sn, FabricKind::Tcp) || !topo.node_in_fabric(dn, FabricKind::Tcp) {
            return Vec::new();
        }
        topo.rails_of(sn, FabricKind::Tcp)
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        let service = fabric
            .service_ns(topo, io.rail, io.len, io.affinity, rng)
            .ok_or_else(|| crate::Error::TransferFailed(format!("{} down", io.rail)))?;
        let start = clock::now_ns();

        // Real socket round-trip.
        let conn = self.connection(io.src.loc.node(), io.dst.loc.node())?;
        let mut payload = vec![0u8; io.len as usize];
        io.src.read_at(io.src_off, &mut payload)?;
        {
            let mut s = conn.lock().unwrap();
            let mut hdr = [0u8; 24];
            hdr[0..8].copy_from_slice(&io.dst.id.0.to_le_bytes());
            hdr[8..16].copy_from_slice(&io.dst_off.to_le_bytes());
            hdr[16..24].copy_from_slice(&io.len.to_le_bytes());
            s.write_all(&hdr)?;
            s.write_all(&payload)?;
            let mut ack = [0u8; 1];
            s.read_exact(&mut ack)?;
            if ack[0] != 0 {
                return Err(crate::Error::TransferFailed("tcp remote write failed".into()));
            }
        }
        fabric.pace(io.rail, start, service);
        Ok(ExecOutcome { service_ns: service })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::Location;
    use crate::topology::profile::build_profile;

    #[test]
    fn loopback_roundtrip_moves_real_bytes() {
        let t = build_profile("legacy_tcp", 2).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let segs = Arc::new(SegmentManager::new());
        let be = TcpBackend::new(Arc::clone(&segs));
        let a = segs.register_memory(Location::host(0, 0), 1 << 16).unwrap();
        let b = segs.register_memory(Location::host(1, 0), 1 << 16).unwrap();
        a.write_at(0, &[0xC3; 1 << 14]).unwrap();
        let rails = be.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 1);
        let mut rng = Pcg64::new(1, 0);
        be.execute(
            &SliceIo {
                src: &a,
                src_off: 0,
                dst: &b,
                dst_off: 4096,
                len: 1 << 14,
                rail: rails[0],
                affinity: PathAffinity::default(),
            },
            &t,
            &f,
            &mut rng,
        )
        .unwrap();
        let mut buf = [0u8; 1 << 14];
        b.read_at(4096, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 0xC3));
    }

    #[test]
    fn device_endpoints_rejected() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let segs = Arc::new(SegmentManager::new());
        let be = TcpBackend::new(Arc::clone(&segs));
        let g = segs.register_memory(Location::device(0, 0), 64).unwrap();
        let h = segs.register_memory(Location::host(0, 0), 64).unwrap();
        assert!(be.plan_rails(&g, &h, &t).is_empty());
    }
}
