//! NVLink backend: intra-node GPU↔GPU direct fabric (tier-1).
//!
//! The paper's key Table-2 behaviour difference: TENT treats NVLink as a
//! first-class transport and prefers it whenever a direct GPU-to-GPU path
//! exists; Mooncake TE always routes GPU↔GPU over RDMA.

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct NvLinkBackend;

impl TransportBackend for NvLinkBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::NvLink
    }
    fn name(&self) -> &'static str {
        "nvlink_sim"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // GPU↔GPU, same node, node has NVLink, both P2P-mappable.
        if !src.loc.is_device() || !dst.loc.is_device() {
            return Vec::new();
        }
        if src.meta.gpu_handle.is_none() || dst.meta.gpu_handle.is_none() {
            return Vec::new();
        }
        let n = src.loc.node();
        if n != dst.loc.node() || !topo.node_in_fabric(n, FabricKind::NvLink) {
            return Vec::new();
        }
        // The source GPU's NVLink port carries the transfer.
        let src_gpu = src.loc.pcie_root();
        topo.rails_of(n, FabricKind::NvLink)
            .into_iter()
            .filter(|&r| topo.rail(r).gpu_idx == src_gpu)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn gpu_pair_same_node_reachable() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::device(0, 5), 1024).unwrap();
        let rails = NvLinkBackend.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 1);
        assert_eq!(t.rail(rails[0]).gpu_idx, Some(0));
    }

    #[test]
    fn cross_node_and_host_rejected() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::device(1, 0), 1024).unwrap();
        let h = m.register_memory(Location::host(0, 0), 1024).unwrap();
        assert!(NvLinkBackend.plan_rails(&a, &b, &t).is_empty());
        assert!(NvLinkBackend.plan_rails(&a, &h, &t).is_empty());
    }

    #[test]
    fn nvlink_is_much_faster_than_one_rdma_rail() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 4 << 20).unwrap();
        let b = m.register_memory(Location::device(0, 1), 4 << 20).unwrap();
        let nvl = NvLinkBackend.plan_rails(&a, &b, &t)[0];
        let rdma = crate::transport::rdma_sim::RdmaBackend.plan_rails(&a, &b, &t)[0];
        let mut rng = Pcg64::new(1, 0);
        let t_nvl = f.service_ns(&t, nvl, 4 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        let t_rdma = f.service_ns(&t, rdma, 4 << 20, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        // 2.045 GB/s vs 250 MB/s → ~8x.
        assert!(t_rdma > 5 * t_nvl, "nvl={t_nvl} rdma={t_rdma}");
    }
}
