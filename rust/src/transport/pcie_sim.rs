//! PCIe backend: host↔device staging hops within one node (cudaMemcpy
//! analogue). These rails carry the D2H / H2D legs of synthesized staged
//! routes (§4.1) and the KV-cache tier promotions/demotions in serving.

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct PcieBackend;

impl TransportBackend for PcieBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::Pcie
    }
    fn name(&self) -> &'static str {
        "pcie_sim"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // Exactly one endpoint is a device; same node.
        let gpu = match (src.loc.is_device(), dst.loc.is_device()) {
            (true, false) => src.loc.pcie_root(),
            (false, true) => dst.loc.pcie_root(),
            _ => return Vec::new(),
        };
        if src.loc.is_storage() || dst.loc.is_storage() {
            return Vec::new();
        }
        let n = src.loc.node();
        if n != dst.loc.node() || !topo.node_in_fabric(n, FabricKind::Pcie) {
            return Vec::new();
        }
        topo.rails_of(n, FabricKind::Pcie)
            .into_iter()
            .filter(|&r| topo.rail(r).gpu_idx == gpu)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn h2d_and_d2h_reachable() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let m = SegmentManager::new();
        let h = m.register_memory(Location::host(0, 0), 64).unwrap();
        let g = m.register_memory(Location::device(0, 3), 64).unwrap();
        let up = PcieBackend.plan_rails(&h, &g, &t);
        let down = PcieBackend.plan_rails(&g, &h, &t);
        assert_eq!(up.len(), 1);
        assert_eq!(up, down);
        assert_eq!(t.rail(up[0]).gpu_idx, Some(3));
    }

    #[test]
    fn d2d_and_h2h_rejected() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let m = SegmentManager::new();
        let g0 = m.register_memory(Location::device(0, 0), 64).unwrap();
        let g1 = m.register_memory(Location::device(0, 1), 64).unwrap();
        let h0 = m.register_memory(Location::host(0, 0), 64).unwrap();
        let h1 = m.register_memory(Location::host(0, 1), 64).unwrap();
        assert!(PcieBackend.plan_rails(&g0, &g1, &t).is_empty());
        assert!(PcieBackend.plan_rails(&h0, &h1, &t).is_empty());
    }
}
