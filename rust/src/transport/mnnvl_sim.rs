//! Multi-Node NVLink (MNNVL) backend: rack-scale GPU↔GPU fabric
//! (GB200-NVL72 shape). GPU-to-GPU **only** — it cannot carry host↔host
//! paths (§2.1), which is precisely the capability gap that forces
//! heterogeneous orchestration.

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct MnnvlBackend;

impl TransportBackend for MnnvlBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::Mnnvl
    }
    fn name(&self) -> &'static str {
        "mnnvl_sim"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        if !src.loc.is_device() || !dst.loc.is_device() {
            return Vec::new(); // GPU↔GPU only
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if !topo.node_in_fabric(sn, FabricKind::Mnnvl)
            || !topo.node_in_fabric(dn, FabricKind::Mnnvl)
        {
            return Vec::new();
        }
        let src_gpu = src.loc.pcie_root();
        topo.rails_of(sn, FabricKind::Mnnvl)
            .into_iter()
            .filter(|&r| topo.rail(r).gpu_idx == src_gpu)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn cross_node_gpu_pair_reachable_on_rack() {
        let t = build_profile("mnnvl_rack", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 2), 1024).unwrap();
        let b = m.register_memory(Location::device(1, 6), 1024).unwrap();
        assert_eq!(MnnvlBackend.plan_rails(&a, &b, &t).len(), 1);
    }

    #[test]
    fn host_paths_rejected() {
        let t = build_profile("mnnvl_rack", 2).unwrap();
        let m = SegmentManager::new();
        let h0 = m.register_memory(Location::host(0, 0), 1024).unwrap();
        let h1 = m.register_memory(Location::host(1, 0), 1024).unwrap();
        let g = m.register_memory(Location::device(0, 0), 1024).unwrap();
        assert!(MnnvlBackend.plan_rails(&h0, &h1, &t).is_empty());
        assert!(MnnvlBackend.plan_rails(&g, &h1, &t).is_empty());
    }

    #[test]
    fn not_available_off_rack() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::device(1, 0), 1024).unwrap();
        assert!(MnnvlBackend.plan_rails(&a, &b, &t).is_empty());
    }
}
