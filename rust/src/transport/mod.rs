//! Pluggable transport backends (§3.2).
//!
//! Each fabric is a *thin* backend conforming to [`TransportBackend`]:
//! it declares reachability/capabilities and executes single slices. All
//! scheduling, telemetry, and resilience live above this interface, so new
//! fabrics integrate without touching the engine — exactly the paper's
//! design (each production backend is < 800 LOC; ours are < 200).
//!
//! Backends *really move the bytes* (memcpy / TCP / file I/O); the
//! [`crate::fabric::Fabric`] decides how long the wire would have taken and
//! the backend paces completion to that deadline.

pub mod ascend_sim;
pub mod file_io;
pub mod mnnvl_sim;
pub mod nvlink_sim;
pub mod pcie_sim;
pub mod rdma_sim;
pub mod shm;
pub mod staged;
pub mod tcp;

use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::Result;
use std::sync::Arc;

/// Physical path asymmetries that affect wire time (but are invisible to
/// state-blind schedulers — they only surface through telemetry).
#[derive(Clone, Copy, Debug, Default)]
pub struct PathAffinity {
    /// Buffer lives on a different NUMA node than the rail.
    pub cross_numa: bool,
    /// Device buffer hangs off a different PCIe root complex than the rail
    /// (tier-2 paths traverse the PCIe switch — measurably more expensive).
    pub cross_root: bool,
}

/// Outcome of executing one slice.
#[derive(Clone, Copy, Debug)]
pub struct ExecOutcome {
    /// Wire service time (ns) charged by the fabric (excludes queueing).
    pub service_ns: u64,
}

/// One slice execution request as seen by a backend.
pub struct SliceIo<'a> {
    pub src: &'a Segment,
    pub src_off: u64,
    pub dst: &'a Segment,
    pub dst_off: u64,
    pub len: u64,
    pub rail: RailId,
    pub affinity: PathAffinity,
}

/// The uniform transport backend interface (§3.2).
pub trait TransportBackend: Send + Sync {
    /// Which fabric this backend drives.
    fn fabric(&self) -> FabricKind;

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Enumerate the local rails able to carry bytes from `src` to `dst`,
    /// or an empty vector if this backend cannot serve the pair at all.
    /// This is the capability intersection of §4.1.
    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId>;

    /// Execute one slice on the worker thread that owns `io.rail`.
    /// Blocking; returns after the bytes are delivered and paced.
    fn execute(&self, io: &SliceIo, topo: &Topology, fabric: &Fabric, rng: &mut Pcg64)
        -> Result<ExecOutcome>;
}

/// Paced memory→memory copy shared by the sim backends: compute wire time,
/// move the bytes, sleep out the remainder, maintain rail counters.
pub(crate) fn paced_mem_copy(
    io: &SliceIo,
    topo: &Topology,
    fabric: &Fabric,
    rng: &mut Pcg64,
) -> Result<ExecOutcome> {
    let service = fabric
        .service_ns(topo, io.rail, io.len, io.affinity, rng)
        .ok_or_else(|| {
            crate::Error::TransferFailed(format!("{} failed (rail down)", io.rail))
        })?;
    let start = clock::now_ns();
    Segment::copy_mem_to_mem(io.src, io.src_off, io.dst, io.dst_off, io.len)?;
    fabric.pace(io.rail, start, service);
    // A rail that died *while* we were on the wire aborts the slice —
    // models in-flight work-request failure (§2.3).
    if fabric.rail(io.rail).health() == crate::fabric::RailHealth::Failed {
        return Err(crate::Error::TransferFailed(format!(
            "{} died mid-flight",
            io.rail
        )));
    }
    Ok(ExecOutcome { service_ns: service })
}

/// Registry of loaded backends; the orchestrator iterates this to build
/// candidate plans. Order = static preference used only for tie-breaking
/// (fast GPU fabrics first).
pub struct TransportRegistry {
    backends: Vec<Arc<dyn TransportBackend>>,
    /// The synthesized compound route (§4.1); consulted only when no direct
    /// backend reaches the pair.
    staged: Arc<dyn TransportBackend>,
}

impl TransportRegistry {
    /// Load every backend whose fabric appears in the topology — the
    /// "dynamic backend loading" of §3.2.
    pub fn load_all(topo: &Topology, segments: Arc<crate::segment::SegmentManager>) -> Self {
        let present = |f: FabricKind| topo.fabrics.iter().any(|&(_, ff)| ff == f);
        let mut backends: Vec<Arc<dyn TransportBackend>> = Vec::new();
        if present(FabricKind::NvLink) {
            backends.push(Arc::new(nvlink_sim::NvLinkBackend));
        }
        if present(FabricKind::Mnnvl) {
            backends.push(Arc::new(mnnvl_sim::MnnvlBackend));
        }
        if present(FabricKind::AscendUb) {
            backends.push(Arc::new(ascend_sim::AscendBackend));
        }
        if present(FabricKind::Rdma) {
            backends.push(Arc::new(rdma_sim::RdmaBackend));
        }
        if present(FabricKind::Pcie) {
            backends.push(Arc::new(pcie_sim::PcieBackend));
        }
        if present(FabricKind::Shm) {
            backends.push(Arc::new(shm::ShmBackend));
        }
        if present(FabricKind::FileIo) {
            backends.push(Arc::new(file_io::FileIoBackend));
        }
        if present(FabricKind::Tcp) {
            backends.push(Arc::new(tcp::TcpBackend::new(segments)));
        }
        TransportRegistry {
            backends,
            staged: Arc::new(staged::StagedBackend::new()),
        }
    }

    pub fn all(&self) -> &[Arc<dyn TransportBackend>] {
        &self.backends
    }

    /// The staged-route synthesizer (always available).
    pub fn staged(&self) -> Arc<dyn TransportBackend> {
        Arc::clone(&self.staged)
    }

    pub fn by_fabric(&self, f: FabricKind) -> Option<Arc<dyn TransportBackend>> {
        self.backends.iter().find(|b| b.fabric() == f).cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segment::SegmentManager;
    use crate::topology::profile::build_profile;

    #[test]
    fn registry_loads_backends_for_profile() {
        let topo = build_profile("h800_hgx", 2).unwrap();
        let segs = Arc::new(SegmentManager::new());
        let reg = TransportRegistry::load_all(&topo, segs);
        let kinds: Vec<FabricKind> = reg.all().iter().map(|b| b.fabric()).collect();
        assert!(kinds.contains(&FabricKind::NvLink));
        assert!(kinds.contains(&FabricKind::Rdma));
        assert!(kinds.contains(&FabricKind::Tcp));
        assert!(!kinds.contains(&FabricKind::Mnnvl));
    }

    #[test]
    fn legacy_tcp_profile_loads_only_thin_set() {
        let topo = build_profile("legacy_tcp", 2).unwrap();
        let segs = Arc::new(SegmentManager::new());
        let reg = TransportRegistry::load_all(&topo, segs);
        let kinds: Vec<FabricKind> = reg.all().iter().map(|b| b.fabric()).collect();
        assert!(kinds.contains(&FabricKind::Tcp));
        assert!(kinds.contains(&FabricKind::Shm));
        assert!(!kinds.contains(&FabricKind::Rdma));
        assert!(!kinds.contains(&FabricKind::NvLink));
    }

    #[test]
    fn by_fabric_lookup() {
        let topo = build_profile("mnnvl_rack", 1).unwrap();
        let segs = Arc::new(SegmentManager::new());
        let reg = TransportRegistry::load_all(&topo, segs);
        assert!(reg.by_fabric(FabricKind::Mnnvl).is_some());
        assert!(reg.by_fabric(FabricKind::AscendUb).is_none());
    }
}
