//! Staged compound routes (§4.1): when no direct GPU-direct path spans the
//! endpoints (consumer GPUs without GPUDirect, cross-silo device pairs),
//! TENT transparently synthesizes D2H → H2H → H2D through host bounce
//! buffers.
//!
//! Each *slice* performs its three hops sequentially; because many slices of
//! an elephant flow are in flight concurrently on different rails, the D2H,
//! H2H, and H2D stages of successive chunks overlap — the pipelining the
//! paper describes emerges at the slice level.

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::Result;
use std::cell::RefCell;

pub struct StagedBackend;

thread_local! {
    /// Per-worker reusable bounce buffer (perf: no per-slice allocation).
    static BOUNCE: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

impl StagedBackend {
    /// Find the PCIe rail serving a device endpoint, if the hop is needed.
    fn pcie_hop(seg: &Segment, topo: &Topology) -> Option<RailId> {
        if !seg.loc.is_device() {
            return None;
        }
        let n = seg.loc.node();
        topo.rails_of(n, FabricKind::Pcie)
            .into_iter()
            .find(|&r| topo.rail(r).gpu_idx == seg.loc.pcie_root())
    }
}

impl TransportBackend for StagedBackend {
    fn fabric(&self) -> FabricKind {
        // Rides the RDMA fabric for its H2H leg; identity is the Arc itself.
        FabricKind::Rdma
    }
    fn name(&self) -> &'static str {
        "staged"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // At least one device endpoint; storage excluded.
        if src.loc.is_storage() || dst.loc.is_storage() {
            return Vec::new();
        }
        if !src.loc.is_device() && !dst.loc.is_device() {
            return Vec::new();
        }
        // Device endpoints must have a PCIe staging rail.
        if src.loc.is_device() && Self::pcie_hop(src, topo).is_none() {
            return Vec::new();
        }
        if dst.loc.is_device() && Self::pcie_hop(dst, topo).is_none() {
            return Vec::new();
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if sn == dn {
            // Same node: D2H + H2D only, no H2H leg; ride the source PCIe
            // rail as the schedulable unit.
            return Self::pcie_hop(src, topo)
                .or_else(|| Self::pcie_hop(dst, topo))
                .into_iter()
                .collect();
        }
        if !topo.node_in_fabric(sn, FabricKind::Rdma) || !topo.node_in_fabric(dn, FabricKind::Rdma)
        {
            return Vec::new();
        }
        // Host-capable NICs only (that's the point of staging).
        topo.rails_of(sn, FabricKind::Rdma)
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        let same_node = io.src.loc.node() == io.dst.loc.node();
        let d2h = Self::pcie_hop(io.src, topo);
        let h2d = Self::pcie_hop(io.dst, topo);

        let mut total: u64 = 0;
        let start = clock::now_ns();

        BOUNCE.with(|b| -> Result<()> {
            let mut buf = b.borrow_mut();
            buf.resize(io.len as usize, 0);

            // Hop 1: D2H into the bounce buffer.
            if let Some(rail) = d2h {
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                io.src.read_at(io.src_off, &mut buf)?;
                total += svc;
            } else {
                io.src.read_at(io.src_off, &mut buf)?;
            }

            // Hop 2: H2H over the scheduled rail (inter-node only).
            if !same_node {
                let svc = fabric
                    .service_ns(topo, io.rail, io.len, io.affinity, rng)
                    .ok_or_else(|| {
                        crate::Error::TransferFailed(format!("{} down", io.rail))
                    })?;
                total += svc;
            } else if d2h.is_none() || h2d.is_none() {
                // Same-node with a single device endpoint: the PCIe hop *is*
                // the scheduled rail; charge it once below.
            }

            // Hop 3: H2D from the bounce buffer.
            if let Some(rail) = h2d {
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                io.dst.write_at(io.dst_off, &buf)?;
                total += svc;
            } else {
                io.dst.write_at(io.dst_off, &buf)?;
            }
            Ok(())
        })?;

        fabric.pace(io.rail, start, total);
        Ok(ExecOutcome { service_ns: total })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn no_gpudirect_gpu_pair_gets_staged_route() {
        let t = build_profile("no_gpudirect", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::device(1, 3), 1 << 20).unwrap();
        // Direct RDMA refuses (no GPUDirect NICs)…
        assert!(
            crate::transport::rdma_sim::RdmaBackend
                .plan_rails(&a, &b, &t)
                .is_empty()
        );
        // …but the staged route is available over host-capable NICs.
        let rails = StagedBackend.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 8);
    }

    #[test]
    fn staged_moves_bytes_and_is_slower_than_direct_h2h() {
        let t = build_profile("no_gpudirect", 2).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::device(1, 0), 1 << 20).unwrap();
        a.write_at(0, &[0x77; 1 << 18]).unwrap();
        let rail = StagedBackend.plan_rails(&a, &b, &t)[0];
        let mut rng = Pcg64::new(1, 0);
        let out = StagedBackend
            .execute(
                &SliceIo {
                    src: &a,
                    src_off: 0,
                    dst: &b,
                    dst_off: 0,
                    len: 1 << 18,
                    rail,
                    affinity: PathAffinity::default(),
                },
                &t,
                &f,
                &mut rng,
            )
            .unwrap();
        let mut buf = [0u8; 1 << 18];
        b.read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 0x77));
        // Staged = D2H + H2H + H2D: strictly more than the bare H2H time.
        let h2h = f.service_ns(&t, rail, 1 << 18, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        assert!(out.service_ns > h2h, "staged {} h2h {}", out.service_ns, h2h);
    }

    #[test]
    fn same_node_staged_skips_network_leg() {
        let t = build_profile("no_gpudirect", 1).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 4096).unwrap();
        let b = m.register_memory(Location::device(0, 1), 4096).unwrap();
        let rails = StagedBackend.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 1); // the PCIe rail, not 8 NICs
        assert_eq!(t.rail(rails[0]).fabric, FabricKind::Pcie);
    }

    #[test]
    fn host_to_host_not_staged() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::host(0, 0), 64).unwrap();
        let b = m.register_memory(Location::host(1, 0), 64).unwrap();
        assert!(StagedBackend.plan_rails(&a, &b, &t).is_empty());
    }
}
