//! Staged compound routes (§4.1): when no direct GPU-direct path spans the
//! endpoints (consumer GPUs without GPUDirect, cross-silo device pairs),
//! TENT transparently synthesizes D2H → H2H → H2D through host bounce
//! buffers.
//!
//! Each *slice* performs its three hops sequentially; because many slices of
//! an elephant flow are in flight concurrently on different rails, the D2H,
//! H2H, and H2D stages of successive chunks overlap — the pipelining the
//! paper describes emerges at the slice level.
//!
//! A backend constructed with [`StagedBackend::over`] generalizes the single
//! bounce to a k-hop relay route ([`crate::topology::RelayRoute`]): each
//! network leg is dispatched on a healthy rail of that leg's fabric picked
//! at execution time, so spraying, pacing, and chaos masking apply per hop
//! — a dead rail on a relay node is sidestepped without failing the slice
//! as long as the node keeps one healthy rail in the leg's fabric.

use super::*;
use crate::fabric::{Fabric, RailHealth};
use crate::segment::Segment;
use crate::topology::{FabricKind, NodeId, RailId, RelayRoute, Topology};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::Result;
use std::cell::RefCell;
use std::sync::atomic::Ordering;

pub struct StagedBackend {
    /// Multi-hop relay route this instance executes; `None` is the classic
    /// synthesized single-bounce D2H→H2H→H2D.
    route: Option<Arc<RelayRoute>>,
}

thread_local! {
    /// Per-worker reusable bounce buffer (perf: no per-slice allocation).
    static BOUNCE: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

impl Default for StagedBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl StagedBackend {
    /// The classic single-bounce synthesizer.
    pub fn new() -> Self {
        StagedBackend { route: None }
    }

    /// A backend bound to one k-hop relay route: slices bounce through host
    /// memory on every intermediate node of `route`.
    pub fn over(route: Arc<RelayRoute>) -> Self {
        StagedBackend { route: Some(route) }
    }

    pub fn route(&self) -> Option<&Arc<RelayRoute>> {
        self.route.as_ref()
    }

    /// Find the PCIe rail serving a device endpoint, if the hop is needed.
    /// `pub(crate)` so the planner can price staged candidates by their
    /// bottleneck hop (D2H/H2D PCIe included), not the network rail alone.
    pub(crate) fn pcie_hop(seg: &Segment, topo: &Topology) -> Option<RailId> {
        if !seg.loc.is_device() {
            return None;
        }
        let n = seg.loc.node();
        topo.rails_of(n, FabricKind::Pcie)
            .into_iter()
            .find(|&r| topo.rail(r).gpu_idx == seg.loc.pcie_root())
    }

    /// Pick the rail carrying one network leg: `prefer` (the scheduled
    /// primary rail) if it serves this leg and is alive, else the healthy
    /// rail of `kind` on `node` with the least queued wire time. `None`
    /// only when every rail of the leg's fabric on the node is down.
    fn pick_leg_rail(
        topo: &Topology,
        fabric: &Fabric,
        node: NodeId,
        kind: FabricKind,
        prefer: Option<RailId>,
    ) -> Option<RailId> {
        let rails = topo.rails_of(node, kind);
        if let Some(p) = prefer {
            if rails.contains(&p) && fabric.rail(p).health() != RailHealth::Failed {
                return Some(p);
            }
        }
        rails
            .into_iter()
            .filter(|&r| fabric.rail(r).health() != RailHealth::Failed)
            .min_by(|&x, &y| {
                let load = |r: RailId| {
                    fabric.rail(r).queued_bytes() as f64
                        / topo.rail(r).bw_bytes_per_sec.max(1.0)
                };
                load(x).partial_cmp(&load(y)).unwrap_or(std::cmp::Ordering::Equal)
            })
    }

    /// Execute a slice along a k-hop relay route. One staged copy exists at
    /// a time (store-and-forward): the payload is read from `src` once,
    /// bounced through each relay's host memory — timed and paced on a rail
    /// of that leg's fabric, but carried in the shared thread-local buffer —
    /// and written to `dst` once. The fabric's relay ledger records bytes
    /// in/out of every relay node; an aborted leg drains the stranded
    /// staging copy (`relay_out`) so the conservation invariant survives
    /// retries.
    fn execute_route(
        &self,
        route: &RelayRoute,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        let d2h = Self::pcie_hop(io.src, topo);
        let h2d = Self::pcie_hop(io.dst, topo);
        let mut total: u64 = 0;

        BOUNCE.with(|b| -> Result<()> {
            let mut buf = b.borrow_mut();
            buf.resize(io.len as usize, 0);
            io.src.read_at(io.src_off, &mut buf)?;

            // Optional D2H into host staging memory on the source node.
            if let Some(rail) = d2h {
                let start = clock::now_ns();
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                fabric.pace(rail, start, svc);
                total += svc;
            }

            // Network legs, each dispatched at execution time.
            let mut staged_at: Option<NodeId> = None;
            let legs = (|| -> Result<()> {
                for leg in 0..route.legs() {
                    let egress = route.nodes[leg];
                    let kind = route.fabrics[leg];
                    let prefer = (leg == 0).then_some(io.rail);
                    let rail = Self::pick_leg_rail(topo, fabric, egress, kind, prefer)
                        .ok_or_else(|| {
                            crate::Error::TransferFailed(format!(
                                "no healthy {kind:?} rail on node {} (relay leg {leg})",
                                egress.0
                            ))
                        })?;
                    // Relay staging buffers are host-local: endpoint-buffer
                    // asymmetries only apply to the first leg.
                    let affinity = if leg == 0 {
                        io.affinity
                    } else {
                        PathAffinity::default()
                    };
                    let start = clock::now_ns();
                    let svc = fabric
                        .service_ns(topo, rail, io.len, affinity, rng)
                        .ok_or_else(|| {
                            crate::Error::TransferFailed(format!("{rail} down"))
                        })?;
                    fabric.pace(rail, start, svc);
                    if rail != io.rail {
                        // Non-primary legs bypass the datapath's completion
                        // accounting; credit their byte counters here.
                        fabric
                            .rail(rail)
                            .bytes_carried
                            .fetch_add(io.len, Ordering::Relaxed);
                    }
                    total += svc;
                    // Ledger: the staged copy drained from the previous
                    // relay and (unless this was the last leg) landed on
                    // the next one.
                    if let Some(n) = staged_at.take() {
                        fabric.relay_out(n, io.len);
                    }
                    if leg + 1 < route.legs() {
                        let relay = route.nodes[leg + 1];
                        fabric.relay_in(relay, io.len);
                        staged_at = Some(relay);
                    }
                }
                Ok(())
            })();
            if let Err(e) = legs {
                // Abandoned staging copy is freed, not forwarded — drain it
                // so in == out still holds once the retry lands elsewhere.
                if let Some(n) = staged_at.take() {
                    fabric.relay_out(n, io.len);
                }
                return Err(e);
            }

            // Optional H2D out of staging memory on the destination node.
            if let Some(rail) = h2d {
                let start = clock::now_ns();
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                fabric.pace(rail, start, svc);
                total += svc;
            }
            io.dst.write_at(io.dst_off, &buf)?;
            Ok(())
        })?;

        Ok(ExecOutcome { service_ns: total })
    }
}

impl TransportBackend for StagedBackend {
    fn fabric(&self) -> FabricKind {
        // A routed instance rides its first leg's fabric; the classic
        // synthesizer rides RDMA for its H2H leg. Identity is the Arc.
        self.route
            .as_ref()
            .map(|r| r.fabrics[0])
            .unwrap_or(FabricKind::Rdma)
    }
    fn name(&self) -> &'static str {
        "staged"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // Storage endpoints are refused in every mode (file I/O has its own
        // backend and no host staging path).
        if src.loc.is_storage() || dst.loc.is_storage() {
            return Vec::new();
        }
        // Device endpoints must have a PCIe staging rail.
        if src.loc.is_device() && Self::pcie_hop(src, topo).is_none() {
            return Vec::new();
        }
        if dst.loc.is_device() && Self::pcie_hop(dst, topo).is_none() {
            return Vec::new();
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if let Some(route) = &self.route {
            // Routed instance: the schedulable unit is a first-leg rail on
            // the route's source node. Host↔host pairs are fine here — a
            // relay route exists precisely because no direct fabric spans
            // the endpoints.
            if route.nodes.first() != Some(&sn) || route.nodes.last() != Some(&dn) {
                return Vec::new();
            }
            return topo.rails_of(sn, route.fabrics[0]);
        }
        // Classic single bounce: at least one device endpoint (a reachable
        // host↔host pair always has a direct backend).
        if !src.loc.is_device() && !dst.loc.is_device() {
            return Vec::new();
        }
        if sn == dn {
            // Same node: D2H + H2D only, no H2H leg; ride the source PCIe
            // rail as the schedulable unit.
            return Self::pcie_hop(src, topo)
                .or_else(|| Self::pcie_hop(dst, topo))
                .into_iter()
                .collect();
        }
        if !topo.node_in_fabric(sn, FabricKind::Rdma) || !topo.node_in_fabric(dn, FabricKind::Rdma)
        {
            return Vec::new();
        }
        // Host-capable NICs only (that's the point of staging).
        topo.rails_of(sn, FabricKind::Rdma)
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        if let Some(route) = &self.route {
            return self.execute_route(route, io, topo, fabric, rng);
        }
        let same_node = io.src.loc.node() == io.dst.loc.node();
        let d2h = Self::pcie_hop(io.src, topo);
        let h2d = Self::pcie_hop(io.dst, topo);

        let mut total: u64 = 0;
        let start = clock::now_ns();

        BOUNCE.with(|b| -> Result<()> {
            let mut buf = b.borrow_mut();
            buf.resize(io.len as usize, 0);

            // Hop 1: D2H into the bounce buffer.
            if let Some(rail) = d2h {
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                io.src.read_at(io.src_off, &mut buf)?;
                total += svc;
            } else {
                io.src.read_at(io.src_off, &mut buf)?;
            }

            // Hop 2: H2H over the scheduled rail (inter-node only).
            if !same_node {
                let svc = fabric
                    .service_ns(topo, io.rail, io.len, io.affinity, rng)
                    .ok_or_else(|| {
                        crate::Error::TransferFailed(format!("{} down", io.rail))
                    })?;
                total += svc;
            } else if d2h.is_none() || h2d.is_none() {
                // Same-node with a single device endpoint: the PCIe hop *is*
                // the scheduled rail; charge it once below.
            }

            // Hop 3: H2D from the bounce buffer.
            if let Some(rail) = h2d {
                let svc = fabric
                    .service_ns(topo, rail, io.len, io.affinity, rng)
                    .ok_or_else(|| crate::Error::TransferFailed(format!("{rail} down")))?;
                io.dst.write_at(io.dst_off, &buf)?;
                total += svc;
            } else {
                io.dst.write_at(io.dst_off, &buf)?;
            }
            Ok(())
        })?;

        fabric.pace(io.rail, start, total);
        Ok(ExecOutcome { service_ns: total })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn no_gpudirect_gpu_pair_gets_staged_route() {
        let t = build_profile("no_gpudirect", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::device(1, 3), 1 << 20).unwrap();
        // Direct RDMA refuses (no GPUDirect NICs)…
        assert!(
            crate::transport::rdma_sim::RdmaBackend
                .plan_rails(&a, &b, &t)
                .is_empty()
        );
        // …but the staged route is available over host-capable NICs.
        let rails = StagedBackend::new().plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 8);
    }

    #[test]
    fn staged_moves_bytes_and_is_slower_than_direct_h2h() {
        let t = build_profile("no_gpudirect", 2).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::device(1, 0), 1 << 20).unwrap();
        a.write_at(0, &[0x77; 1 << 18]).unwrap();
        let rail = StagedBackend::new().plan_rails(&a, &b, &t)[0];
        let mut rng = Pcg64::new(1, 0);
        let out = StagedBackend::new()
            .execute(
                &SliceIo {
                    src: &a,
                    src_off: 0,
                    dst: &b,
                    dst_off: 0,
                    len: 1 << 18,
                    rail,
                    affinity: PathAffinity::default(),
                },
                &t,
                &f,
                &mut rng,
            )
            .unwrap();
        let mut buf = [0u8; 1 << 18];
        b.read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 0x77));
        // Staged = D2H + H2H + H2D: strictly more than the bare H2H time.
        let h2h = f.service_ns(&t, rail, 1 << 18, crate::transport::PathAffinity::default(), &mut rng).unwrap();
        assert!(out.service_ns > h2h, "staged {} h2h {}", out.service_ns, h2h);
    }

    #[test]
    fn same_node_staged_skips_network_leg() {
        let t = build_profile("no_gpudirect", 1).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 4096).unwrap();
        let b = m.register_memory(Location::device(0, 1), 4096).unwrap();
        let rails = StagedBackend::new().plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 1); // the PCIe rail, not 8 NICs
        assert_eq!(t.rail(rails[0]).fabric, FabricKind::Pcie);
    }

    #[test]
    fn host_to_host_not_staged() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::host(0, 0), 64).unwrap();
        let b = m.register_memory(Location::host(1, 0), 64).unwrap();
        assert!(StagedBackend::new().plan_rails(&a, &b, &t).is_empty());
    }

    #[test]
    fn routed_instance_executes_relay_legs_and_keeps_the_ledger_balanced() {
        // silo_fleet: h800 prefill (node 0, RDMA-only) can only reach the
        // ascend decode silo (node 1, TCP-only) through the gateway (node 2).
        let t = build_profile("silo_fleet", 3).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let routes = t.relay_routes(crate::topology::NodeId(0), crate::topology::NodeId(1), 3);
        assert!(!routes.is_empty());
        let route = Arc::new(routes[0].clone());
        assert_eq!(route.relays(), &[crate::topology::NodeId(2)]);
        let backend = StagedBackend::over(Arc::clone(&route));

        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        a.write_at(0, &[0x5A; 1 << 18]).unwrap();
        let rails = backend.plan_rails(&a, &b, &t);
        assert!(!rails.is_empty(), "first-leg rails on the route's source");
        assert!(rails.iter().all(|&r| t.rail(r).fabric == route.fabrics[0]));

        let mut rng = Pcg64::new(7, 0);
        let out = backend
            .execute(
                &SliceIo {
                    src: &a,
                    src_off: 0,
                    dst: &b,
                    dst_off: 0,
                    len: 1 << 18,
                    rail: rails[0],
                    affinity: PathAffinity::default(),
                },
                &t,
                &f,
                &mut rng,
            )
            .unwrap();
        assert!(out.service_ns > 0);
        let mut buf = [0u8; 1 << 18];
        b.read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 0x5A));
        // Every byte entered and left the gateway's staging memory.
        assert_eq!(f.relay_bytes(crate::topology::NodeId(2)), (1 << 18, 1 << 18));
        // The second leg's rail was credited directly (not the primary).
        let leg2: u64 = t
            .rails_of(crate::topology::NodeId(2), route.fabrics[1])
            .iter()
            .map(|&r| f.rail(r).bytes_carried.load(Ordering::Relaxed))
            .sum();
        assert_eq!(leg2, 1 << 18);
    }

    #[test]
    fn routed_instance_masks_a_dead_relay_rail_per_hop() {
        let t = build_profile("silo_fleet", 3).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let route = Arc::new(
            t.relay_routes(crate::topology::NodeId(0), crate::topology::NodeId(1), 3)[0].clone(),
        );
        let backend = StagedBackend::over(Arc::clone(&route));
        let m = SegmentManager::new();
        let a = m.register_memory(Location::host(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        a.write_at(0, &[0x33; 4096]).unwrap();
        let rails = backend.plan_rails(&a, &b, &t);
        // Kill one of the gateway's two second-leg rails: the slice must
        // route around it at the hop, not fail.
        let gw_rails = t.rails_of(crate::topology::NodeId(2), route.fabrics[1]);
        assert!(gw_rails.len() >= 2);
        f.inject_failure(gw_rails[0]);
        let mut rng = Pcg64::new(9, 0);
        let out = backend.execute(
            &SliceIo {
                src: &a,
                src_off: 0,
                dst: &b,
                dst_off: 0,
                len: 4096,
                rail: rails[0],
                affinity: PathAffinity::default(),
            },
            &t,
            &f,
            &mut rng,
        );
        assert!(out.is_ok(), "surviving gateway rail must carry the leg");
        assert_eq!(
            f.rail(gw_rails[1]).bytes_carried.load(Ordering::Relaxed),
            4096
        );
        assert_eq!(f.relay_bytes(crate::topology::NodeId(2)), (4096, 4096));
    }
}
