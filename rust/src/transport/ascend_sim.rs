//! Ascend UB / HIXL backend: vendor-exclusive NPU↔NPU fabric. Only present
//! on Ascend nodes — on a mixed fleet this is exactly the "communication
//! silo" hardware of §2.1 that TENT's late binding has to bridge (via
//! staged host routes when peers live on different vendor stacks).

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct AscendBackend;

impl TransportBackend for AscendBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::AscendUb
    }
    fn name(&self) -> &'static str {
        "ascend_hixl_sim"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        if !src.loc.is_device() || !dst.loc.is_device() {
            return Vec::new();
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if !topo.node_in_fabric(sn, FabricKind::AscendUb)
            || !topo.node_in_fabric(dn, FabricKind::AscendUb)
        {
            return Vec::new();
        }
        let src_gpu = src.loc.pcie_root();
        topo.rails_of(sn, FabricKind::AscendUb)
            .into_iter()
            .filter(|&r| topo.rail(r).gpu_idx == src_gpu)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn npu_pair_reachable_on_ascend_profile() {
        let t = build_profile("ascend_ub", 1).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::device(0, 7), 1024).unwrap();
        assert_eq!(AscendBackend.plan_rails(&a, &b, &t).len(), 1);
    }

    #[test]
    fn silo_boundary_in_mixed_fleet() {
        // NVIDIA-node GPU ↔ Ascend-node NPU: neither NVLink, Ascend, nor
        // (cross-silo) direct fabric applies.
        let t = build_profile("mixed_fleet", 0).unwrap();
        let m = SegmentManager::new();
        let nv = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let asc = m.register_memory(Location::device(1, 0), 1024).unwrap();
        assert!(AscendBackend.plan_rails(&nv, &asc, &t).is_empty());
        assert!(
            crate::transport::nvlink_sim::NvLinkBackend
                .plan_rails(&nv, &asc, &t)
                .is_empty()
        );
    }
}
