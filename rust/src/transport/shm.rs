//! Shared-memory backend: host↔host within one node (NUMA-paced memcpy).

use super::*;
use crate::fabric::Fabric;
use crate::segment::{Location, Segment};
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct ShmBackend;

impl TransportBackend for ShmBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::Shm
    }
    fn name(&self) -> &'static str {
        "shm"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        let (Location::Host { node: sn, numa }, Location::Host { node: dn, .. }) =
            (&src.loc, &dst.loc)
        else {
            return Vec::new();
        };
        if sn != dn || !topo.node_in_fabric(*sn, FabricKind::Shm) {
            return Vec::new();
        }
        // The source socket's SHM rail carries the copy.
        topo.rails_of(*sn, FabricKind::Shm)
            .into_iter()
            .filter(|&r| topo.rail(r).numa == *numa)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::segment::SegmentManager;
    use crate::topology::profile::build_profile;

    #[test]
    fn same_node_hosts_reachable() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::host(0, 1), 64).unwrap();
        let b = m.register_memory(Location::host(0, 0), 64).unwrap();
        let rails = ShmBackend.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 1);
        assert_eq!(t.rail(rails[0]).numa, 1);
    }

    #[test]
    fn cross_node_or_device_rejected() {
        let t = build_profile("h800_hgx", 2).unwrap();
        let m = SegmentManager::new();
        let a = m.register_memory(Location::host(0, 0), 64).unwrap();
        let b = m.register_memory(Location::host(1, 0), 64).unwrap();
        let g = m.register_memory(Location::device(0, 0), 64).unwrap();
        assert!(ShmBackend.plan_rails(&a, &b, &t).is_empty());
        assert!(ShmBackend.plan_rails(&a, &g, &t).is_empty());
    }
}
