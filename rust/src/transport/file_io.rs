//! File-I/O backend (io_uring analogue): moves bytes between a memory
//! segment and a real file on the node's SSD using positional I/O.
//!
//! This is *real* storage I/O — the only pacing applied is the SSD rail's
//! nominal bandwidth so that sim-scale ratios stay consistent (Table 4's
//! io_uring row: TENT matches native throughput; here "native" is the same
//! pread/pwrite path without engine overhead).

use super::*;
use crate::fabric::Fabric;
use crate::segment::{Backing, Segment};
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::clock;
use crate::util::prng::Pcg64;
use crate::Result;

pub struct FileIoBackend;

impl TransportBackend for FileIoBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::FileIo
    }
    fn name(&self) -> &'static str {
        "file_io"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // Exactly one endpoint is storage; same node.
        if src.loc.is_storage() == dst.loc.is_storage() {
            return Vec::new();
        }
        let n = src.loc.node();
        if n != dst.loc.node() || !topo.node_in_fabric(n, FabricKind::FileIo) {
            return Vec::new();
        }
        topo.rails_of(n, FabricKind::FileIo)
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        let service = fabric
            .service_ns(topo, io.rail, io.len, io.affinity, rng)
            .ok_or_else(|| crate::Error::TransferFailed(format!("{} down", io.rail)))?;
        let start = clock::now_ns();
        // Move through a stack/heap bounce buffer with real positional I/O.
        let mut buf = vec![0u8; io.len as usize];
        match (&io.src.backing, &io.dst.backing) {
            (Backing::File(_), _) => {
                io.src.read_at(io.src_off, &mut buf)?;
                io.dst.write_at(io.dst_off, &buf)?;
            }
            (_, Backing::File(_)) => {
                io.src.read_at(io.src_off, &mut buf)?;
                io.dst.write_at(io.dst_off, &buf)?;
            }
            _ => {
                return Err(crate::Error::TransferFailed(
                    "file_io backend needs a storage endpoint".into(),
                ))
            }
        }
        fabric.pace(io.rail, start, service);
        Ok(ExecOutcome { service_ns: service })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;

    #[test]
    fn memory_to_file_and_back() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        let m = SegmentManager::new();
        let mem = m.register_memory(Location::host(0, 0), 8192).unwrap();
        let gpu = m.register_memory(Location::device(0, 0), 8192).unwrap();
        let path = std::env::temp_dir().join(format!("tent_fio_{}", std::process::id()));
        let file = m
            .register_file(Location::storage(0, path.clone()), 8192)
            .unwrap();

        mem.write_at(0, &[0x5A; 4096]).unwrap();
        let rail = FileIoBackend.plan_rails(&mem, &file, &t)[0];
        let mut rng = Pcg64::new(1, 0);
        FileIoBackend
            .execute(
                &SliceIo {
                    src: &mem,
                    src_off: 0,
                    dst: &file,
                    dst_off: 1024,
                    len: 4096,
                    rail,
                    affinity: PathAffinity::default(),
                },
                &t,
                &f,
                &mut rng,
            )
            .unwrap();
        // Read back into "GPU" memory (GPU→File path works both ways).
        FileIoBackend
            .execute(
                &SliceIo {
                    src: &file,
                    src_off: 1024,
                    dst: &gpu,
                    dst_off: 0,
                    len: 4096,
                    rail,
                    affinity: PathAffinity::default(),
                },
                &t,
                &f,
                &mut rng,
            )
            .unwrap();
        let mut buf = [0u8; 4096];
        gpu.read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&b| b == 0x5A));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn file_to_file_rejected() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let m = SegmentManager::new();
        let p1 = std::env::temp_dir().join(format!("tent_fio_a_{}", std::process::id()));
        let p2 = std::env::temp_dir().join(format!("tent_fio_b_{}", std::process::id()));
        let f1 = m.register_file(Location::storage(0, p1.clone()), 64).unwrap();
        let f2 = m.register_file(Location::storage(0, p2.clone()), 64).unwrap();
        assert!(FileIoBackend.plan_rails(&f1, &f2, &t).is_empty());
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }
}
