//! Multi-rail RDMA (RoCE) backend — the workhorse fabric of the paper's
//! H800 testbed (8 × 200 Gbps rails per node).
//!
//! Reachability: any two memory segments whose nodes are both in the RDMA
//! fabric. Device (GPU) endpoints additionally require a GPUDirect-capable
//! NIC — otherwise the orchestrator must synthesize a staged route (§4.1).
//! The backend exposes *every local NIC* as a candidate rail; which rail a
//! slice actually rides is entirely the scheduler's decision (one-sided
//! writes land at absolute destination offsets, so slices are independent
//! and idempotent).

use super::*;
use crate::fabric::Fabric;
use crate::segment::Segment;
use crate::topology::{FabricKind, RailId, Topology};
use crate::util::prng::Pcg64;
use crate::Result;

pub struct RdmaBackend;

impl TransportBackend for RdmaBackend {
    fn fabric(&self) -> FabricKind {
        FabricKind::Rdma
    }

    fn name(&self) -> &'static str {
        "rdma_sim"
    }

    fn plan_rails(&self, src: &Segment, dst: &Segment, topo: &Topology) -> Vec<RailId> {
        // Storage endpoints never ride RDMA directly (NVMe-oF is out of
        // scope for this backend; file_io handles local storage).
        if src.loc.is_storage() || dst.loc.is_storage() {
            return Vec::new();
        }
        // Both endpoints must be registered with the RNIC (have an rkey).
        if src.meta.rdma_rkey.is_none() || dst.meta.rdma_rkey.is_none() {
            return Vec::new();
        }
        let (sn, dn) = (src.loc.node(), dst.loc.node());
        if !topo.node_in_fabric(sn, FabricKind::Rdma) || !topo.node_in_fabric(dn, FabricKind::Rdma)
        {
            return Vec::new();
        }
        // A device endpoint requires GPUDirect capability on *its own*
        // node's NICs (the remote RNIC must be able to DMA into that
        // accelerator's memory — not the case across vendor silos).
        if dst.loc.is_device()
            && !topo
                .rails_of(dn, FabricKind::Rdma)
                .iter()
                .any(|&r| topo.rail(r).gpudirect)
        {
            return Vec::new();
        }
        let needs_gpudirect = src.loc.is_device() || dst.loc.is_device();
        topo.rails_of(sn, FabricKind::Rdma)
            .into_iter()
            .filter(|&r| !needs_gpudirect || topo.rail(r).gpudirect)
            .collect()
    }

    fn execute(
        &self,
        io: &SliceIo,
        topo: &Topology,
        fabric: &Fabric,
        rng: &mut Pcg64,
    ) -> Result<ExecOutcome> {
        paced_mem_copy(io, topo, fabric, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;
    use crate::segment::{Location, SegmentManager};
    use crate::topology::profile::build_profile;
    use crate::topology::NodeId;

    fn setup() -> (Topology, Fabric, SegmentManager) {
        let t = build_profile("h800_hgx", 2).unwrap();
        let f = Fabric::new(&t, FabricConfig::default());
        (t, f, SegmentManager::new())
    }

    #[test]
    fn host_to_host_inter_node_uses_all_local_nics() {
        let (t, _f, m) = setup();
        let a = m.register_memory(Location::host(0, 0), 1024).unwrap();
        let b = m.register_memory(Location::host(1, 1), 1024).unwrap();
        let rails = RdmaBackend.plan_rails(&a, &b, &t);
        assert_eq!(rails.len(), 8);
        assert!(rails.iter().all(|&r| t.rail(r).node == NodeId(0)));
    }

    #[test]
    fn gpu_endpoints_need_gpudirect() {
        let t = build_profile("no_gpudirect", 1).unwrap();
        let m = SegmentManager::new();
        let g = m.register_memory(Location::device(0, 0), 1024).unwrap();
        let h = m.register_memory(Location::host(0, 0), 1024).unwrap();
        assert!(RdmaBackend.plan_rails(&g, &h, &t).is_empty());
        // Host-to-host still fine without GPUDirect.
        let h2 = m.register_memory(Location::host(0, 1), 1024).unwrap();
        assert_eq!(RdmaBackend.plan_rails(&h, &h2, &t).len(), 8);
    }

    #[test]
    fn storage_endpoint_rejected() {
        let (t, _f, m) = setup();
        let a = m.register_memory(Location::host(0, 0), 1024).unwrap();
        let path = std::env::temp_dir().join(format!("tent_rdma_t_{}", std::process::id()));
        let s = m
            .register_file(Location::storage(0, path.clone()), 1024)
            .unwrap();
        assert!(RdmaBackend.plan_rails(&a, &s, &t).is_empty());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn execute_moves_bytes_and_paces() {
        let (t, f, m) = setup();
        let a = m.register_memory(Location::host(0, 0), 1 << 20).unwrap();
        let b = m.register_memory(Location::host(1, 0), 1 << 20).unwrap();
        a.write_at(0, &[0xAB; 1 << 16]).unwrap();
        let rail = RdmaBackend.plan_rails(&a, &b, &t)[0];
        let mut rng = Pcg64::new(1, 0);
        let io = SliceIo {
            src: &a,
            src_off: 0,
            dst: &b,
            dst_off: 0,
            len: 1 << 16,
            rail,
            affinity: PathAffinity::default(),
        };
        let start = crate::util::clock::now_ns();
        let out = RdmaBackend.execute(&io, &t, &f, &mut rng).unwrap();
        let took = crate::util::clock::now_ns() - start;
        // 64 KiB @ 250 MB/s ≈ 262 µs (+20 µs latency); pacing must hold.
        assert!(out.service_ns > 200_000, "service {}", out.service_ns);
        assert!(took >= out.service_ns, "took {took} < service {}", out.service_ns);
        let mut buf = [0u8; 16];
        b.read_at(0, &mut buf).unwrap();
        assert_eq!(buf, [0xAB; 16]);
    }

    #[test]
    fn execute_fails_on_dead_rail() {
        let (t, f, m) = setup();
        let a = m.register_memory(Location::host(0, 0), 4096).unwrap();
        let b = m.register_memory(Location::host(1, 0), 4096).unwrap();
        let rail = RdmaBackend.plan_rails(&a, &b, &t)[0];
        f.inject_failure(rail);
        let mut rng = Pcg64::new(1, 0);
        let io = SliceIo {
            src: &a,
            src_off: 0,
            dst: &b,
            dst_off: 0,
            len: 4096,
            rail,
            affinity: PathAffinity::default(),
        };
        assert!(RdmaBackend.execute(&io, &t, &f, &mut rng).is_err());
    }
}
