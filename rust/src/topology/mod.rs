//! Device model, topology graph, affinity tiers, and reachability (§3.1).
//!
//! At initialization TENT performs automated topology discovery (here: from a
//! cluster profile — the simulation analogue of walking sysfs/NVML), builds a
//! tiered topology graph, and derives per-segment transport capabilities.
//! Links are classified into protocol-independent affinity tiers:
//!
//! * **tier-1** — optimal paths (NVLink peer, GPUDirect NIC on the same PCIe
//!   root complex as the GPU, NIC local to the buffer's NUMA node),
//! * **tier-2** — cross-root but same NUMA domain,
//! * **tier-3** — NUMA-crossing fallbacks.
//!
//! Algorithm 1 applies penalty P = {1, 3, ∞} to tiers 1–3.

pub mod json_profile;
pub mod profile;

use std::fmt;

/// A physical host ("node") in the simulated cluster.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct NodeId(pub u16);

/// Index of a rail (schedulable transport channel) in the fabric.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct RailId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{}", self.0)
    }
}
impl fmt::Display for RailId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rail{}", self.0)
    }
}

/// Kinds of devices in the topology.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DeviceKind {
    /// CPU socket / NUMA domain.
    CpuNuma { numa: u8 },
    /// Accelerator (GPU/NPU) with its NUMA affinity and PCIe root.
    Gpu { idx: u8, numa: u8, pcie_root: u8 },
    /// NIC with NUMA affinity and PCIe root complex.
    Nic { idx: u8, numa: u8, pcie_root: u8 },
    /// Local SSD.
    Ssd { idx: u8, numa: u8 },
}

/// A device entry in a node's inventory.
#[derive(Clone, Debug)]
pub struct Device {
    pub node: NodeId,
    pub kind: DeviceKind,
}

/// Fabric families a node may participate in. A backend is *feasible* for a
/// transfer only if both endpoints' nodes share the fabric (or the fabric is
/// intra-node and the endpoints are colocated).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FabricKind {
    /// Multi-rail RDMA (RoCE). Inter- and intra-node.
    Rdma,
    /// Intra-node GPU-to-GPU (NVLink / Infinity Fabric).
    NvLink,
    /// Rack-scale GPU fabric (Multi-Node NVLink). GPU↔GPU only.
    Mnnvl,
    /// Ascend UB / HIXL rack fabric. NPU↔NPU only.
    AscendUb,
    /// Plain TCP (always available between nodes that list it).
    Tcp,
    /// Intra-node shared-memory (host↔host same node).
    Shm,
    /// Intra-node PCIe host↔device staging path.
    Pcie,
    /// Local storage via io_uring-style file I/O.
    FileIo,
}

impl FabricKind {
    pub const ALL: [FabricKind; 8] = [
        FabricKind::Rdma,
        FabricKind::NvLink,
        FabricKind::Mnnvl,
        FabricKind::AscendUb,
        FabricKind::Tcp,
        FabricKind::Shm,
        FabricKind::Pcie,
        FabricKind::FileIo,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Rdma => "rdma",
            FabricKind::NvLink => "nvlink",
            FabricKind::Mnnvl => "mnnvl",
            FabricKind::AscendUb => "ascend_ub",
            FabricKind::Tcp => "tcp",
            FabricKind::Shm => "shm",
            FabricKind::Pcie => "pcie",
            FabricKind::FileIo => "file_io",
        }
    }
}

/// Affinity tier of a rail relative to a memory location (§3.1).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Tier {
    T1 = 1,
    T2 = 2,
    T3 = 3,
}

impl Tier {
    /// The paper's default penalties P_tier = {1, 3, ∞}.
    pub fn default_penalty(&self) -> f64 {
        match self {
            Tier::T1 => 1.0,
            Tier::T2 => 3.0,
            Tier::T3 => f64::INFINITY,
        }
    }
}

/// A rail definition produced by discovery: the schedulable unit.
#[derive(Clone, Debug)]
pub struct RailDef {
    pub id: RailId,
    pub name: String,
    pub fabric: FabricKind,
    pub node: NodeId,
    /// NUMA domain the rail's device hangs off.
    pub numa: u8,
    /// PCIe root complex id (for tier-1 vs tier-2 classification).
    pub pcie_root: u8,
    /// Nominal bandwidth in bytes/sec (sim-scaled).
    pub bw_bytes_per_sec: f64,
    /// Fixed per-slice base latency (ns): posting + propagation.
    pub base_latency_ns: u64,
    /// For GPU fabrics: which local GPU this rail serves (NVLink port).
    pub gpu_idx: Option<u8>,
    /// Whether this NIC supports GPUDirect (device memory access).
    pub gpudirect: bool,
}

/// The discovered cluster topology: nodes, devices, rails, fabric membership.
#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub profile_name: String,
    pub devices: Vec<Device>,
    pub rails: Vec<RailDef>,
    /// (node, fabric) membership pairs.
    pub fabrics: Vec<(NodeId, FabricKind)>,
    pub nodes: Vec<NodeId>,
}

impl Topology {
    pub fn rail(&self, id: RailId) -> &RailDef {
        &self.rails[id.0 as usize]
    }

    pub fn node_in_fabric(&self, node: NodeId, fabric: FabricKind) -> bool {
        self.fabrics.iter().any(|&(n, f)| n == node && f == fabric)
    }

    /// All rails of a fabric kind on a node.
    pub fn rails_of(&self, node: NodeId, fabric: FabricKind) -> Vec<RailId> {
        self.rails
            .iter()
            .filter(|r| r.node == node && r.fabric == fabric)
            .map(|r| r.id)
            .collect()
    }

    /// GPUs present on a node.
    pub fn gpus(&self, node: NodeId) -> Vec<(u8, u8, u8)> {
        self.devices
            .iter()
            .filter_map(|d| match d.kind {
                DeviceKind::Gpu { idx, numa, pcie_root } if d.node == node => {
                    Some((idx, numa, pcie_root))
                }
                _ => None,
            })
            .collect()
    }

    /// Classify a rail's affinity tier relative to a memory location
    /// described by (numa, pcie_root). `pcie_root == None` means the location
    /// is host memory without a device root (NUMA affinity only).
    pub fn classify_tier(&self, rail: RailId, loc_numa: u8, loc_root: Option<u8>) -> Tier {
        let r = self.rail(rail);
        match loc_root {
            Some(root) => {
                if r.pcie_root == root {
                    Tier::T1
                } else if r.numa == loc_numa {
                    Tier::T2
                } else {
                    Tier::T3
                }
            }
            None => {
                // Host memory: NUMA-local NICs are tier-1, the rest tier-3
                // (crossing the socket interconnect).
                if r.numa == loc_numa {
                    Tier::T1
                } else {
                    Tier::T3
                }
            }
        }
    }

    /// Dump a human-readable topology description.
    pub fn describe(&self) -> String {
        let mut s = format!("profile: {}\n", self.profile_name);
        for &n in &self.nodes {
            s.push_str(&format!("{}:\n", n));
            for d in self.devices.iter().filter(|d| d.node == n) {
                s.push_str(&format!("  {:?}\n", d.kind));
            }
            for r in self.rails.iter().filter(|r| r.node == n) {
                s.push_str(&format!(
                    "  {} {} numa{} root{} {} lat={}ns{}\n",
                    r.name,
                    r.fabric.name(),
                    r.numa,
                    r.pcie_root,
                    crate::util::fmt_bw(r.bw_bytes_per_sec),
                    r.base_latency_ns,
                    if r.gpudirect { " gpudirect" } else { "" },
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::profile::build_profile;
    use super::*;

    #[test]
    fn h800_profile_shape() {
        let t = build_profile("h800_hgx", 2).unwrap();
        assert_eq!(t.nodes.len(), 2);
        // 8 RDMA NICs per node.
        assert_eq!(t.rails_of(NodeId(0), FabricKind::Rdma).len(), 8);
        // 8 NVLink ports per node (one per GPU).
        assert_eq!(t.rails_of(NodeId(0), FabricKind::NvLink).len(), 8);
        assert_eq!(t.gpus(NodeId(0)).len(), 8);
        assert!(t.node_in_fabric(NodeId(0), FabricKind::Rdma));
        assert!(!t.node_in_fabric(NodeId(0), FabricKind::Mnnvl));
    }

    #[test]
    fn tier_classification_gpu_affinity() {
        let t = build_profile("h800_hgx", 1).unwrap();
        // GPU 0 is on numa 0, pcie root 0. Exactly one tier-1 RDMA NIC.
        let rails = t.rails_of(NodeId(0), FabricKind::Rdma);
        let tiers: Vec<Tier> = rails
            .iter()
            .map(|&r| t.classify_tier(r, 0, Some(0)))
            .collect();
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T1).count(), 1);
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T2).count(), 3);
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T3).count(), 4);
    }

    #[test]
    fn tier_classification_host_numa() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let rails = t.rails_of(NodeId(0), FabricKind::Rdma);
        let t1 = rails
            .iter()
            .filter(|&&r| t.classify_tier(r, 0, None) == Tier::T1)
            .count();
        assert_eq!(t1, 4); // 4 NICs per socket
    }

    #[test]
    fn penalties_match_paper() {
        assert_eq!(Tier::T1.default_penalty(), 1.0);
        assert_eq!(Tier::T2.default_penalty(), 3.0);
        assert!(Tier::T3.default_penalty().is_infinite());
    }

    #[test]
    fn unknown_profile_rejected() {
        assert!(build_profile("warp_drive", 1).is_err());
    }
}
