//! Device model, topology graph, affinity tiers, and reachability (§3.1).
//!
//! At initialization TENT performs automated topology discovery (here: from a
//! cluster profile — the simulation analogue of walking sysfs/NVML), builds a
//! tiered topology graph, and derives per-segment transport capabilities.
//! Links are classified into protocol-independent affinity tiers:
//!
//! * **tier-1** — optimal paths (NVLink peer, GPUDirect NIC on the same PCIe
//!   root complex as the GPU, NIC local to the buffer's NUMA node),
//! * **tier-2** — cross-root but same NUMA domain,
//! * **tier-3** — NUMA-crossing fallbacks.
//!
//! Algorithm 1 applies penalty P = {1, 3, ∞} to tiers 1–3.

pub mod json_profile;
pub mod profile;

use std::fmt;

/// A physical host ("node") in the simulated cluster.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct NodeId(pub u16);

/// Index of a rail (schedulable transport channel) in the fabric.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct RailId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{}", self.0)
    }
}
impl fmt::Display for RailId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rail{}", self.0)
    }
}

/// Kinds of devices in the topology.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DeviceKind {
    /// CPU socket / NUMA domain.
    CpuNuma { numa: u8 },
    /// Accelerator (GPU/NPU) with its NUMA affinity and PCIe root.
    Gpu { idx: u8, numa: u8, pcie_root: u8 },
    /// NIC with NUMA affinity and PCIe root complex.
    Nic { idx: u8, numa: u8, pcie_root: u8 },
    /// Local SSD.
    Ssd { idx: u8, numa: u8 },
}

/// A device entry in a node's inventory.
#[derive(Clone, Debug)]
pub struct Device {
    pub node: NodeId,
    pub kind: DeviceKind,
}

/// Fabric families a node may participate in. A backend is *feasible* for a
/// transfer only if both endpoints' nodes share the fabric (or the fabric is
/// intra-node and the endpoints are colocated).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum FabricKind {
    /// Multi-rail RDMA (RoCE). Inter- and intra-node.
    Rdma,
    /// Intra-node GPU-to-GPU (NVLink / Infinity Fabric).
    NvLink,
    /// Rack-scale GPU fabric (Multi-Node NVLink). GPU↔GPU only.
    Mnnvl,
    /// Ascend UB / HIXL rack fabric. NPU↔NPU only.
    AscendUb,
    /// Plain TCP (always available between nodes that list it).
    Tcp,
    /// Intra-node shared-memory (host↔host same node).
    Shm,
    /// Intra-node PCIe host↔device staging path.
    Pcie,
    /// Local storage via io_uring-style file I/O.
    FileIo,
}

impl FabricKind {
    pub const ALL: [FabricKind; 8] = [
        FabricKind::Rdma,
        FabricKind::NvLink,
        FabricKind::Mnnvl,
        FabricKind::AscendUb,
        FabricKind::Tcp,
        FabricKind::Shm,
        FabricKind::Pcie,
        FabricKind::FileIo,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Rdma => "rdma",
            FabricKind::NvLink => "nvlink",
            FabricKind::Mnnvl => "mnnvl",
            FabricKind::AscendUb => "ascend_ub",
            FabricKind::Tcp => "tcp",
            FabricKind::Shm => "shm",
            FabricKind::Pcie => "pcie",
            FabricKind::FileIo => "file_io",
        }
    }
}

/// Affinity tier of a rail relative to a memory location (§3.1).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Tier {
    T1 = 1,
    T2 = 2,
    T3 = 3,
}

impl Tier {
    /// The paper's default penalties P_tier = {1, 3, ∞}.
    pub fn default_penalty(&self) -> f64 {
        match self {
            Tier::T1 => 1.0,
            Tier::T2 => 3.0,
            Tier::T3 => f64::INFINITY,
        }
    }
}

/// A rail definition produced by discovery: the schedulable unit.
#[derive(Clone, Debug)]
pub struct RailDef {
    pub id: RailId,
    pub name: String,
    pub fabric: FabricKind,
    pub node: NodeId,
    /// NUMA domain the rail's device hangs off.
    pub numa: u8,
    /// PCIe root complex id (for tier-1 vs tier-2 classification).
    pub pcie_root: u8,
    /// Nominal bandwidth in bytes/sec (sim-scaled).
    pub bw_bytes_per_sec: f64,
    /// Fixed per-slice base latency (ns): posting + propagation.
    pub base_latency_ns: u64,
    /// For GPU fabrics: which local GPU this rail serves (NVLink port).
    pub gpu_idx: Option<u8>,
    /// Whether this NIC supports GPUDirect (device memory access).
    pub gpudirect: bool,
}

/// The discovered cluster topology: nodes, devices, rails, fabric membership.
#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub profile_name: String,
    pub devices: Vec<Device>,
    pub rails: Vec<RailDef>,
    /// (node, fabric) membership pairs.
    pub fabrics: Vec<(NodeId, FabricKind)>,
    pub nodes: Vec<NodeId>,
}

/// Fabrics that can carry host-memory bytes *between* nodes — the edges of
/// the relay-reachability graph. Device fabrics (NVLink, MNNVL, UB), the
/// intra-node paths (SHM, PCIe), and storage are not relay legs.
pub const HOST_NET_FABRICS: [FabricKind; 2] = [FabricKind::Rdma, FabricKind::Tcp];

/// Cap on inter-node legs in a synthesized relay route (k ≤ 3: at most two
/// host-memory bounces on intermediate nodes).
pub const MAX_RELAY_LEGS: usize = 3;

/// A multi-hop relay route through host memory on intermediate nodes,
/// produced by [`Topology::relay_routes`] when no direct backend (and no
/// single-bounce staged path) spans a pair of endpoints.
///
/// `nodes` is the full node sequence including both endpoints, so a k-leg
/// route has `k + 1` entries and `k - 1` relay nodes; `fabrics[i]` is the
/// inter-node fabric chosen for the leg `nodes[i] → nodes[i+1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct RelayRoute {
    pub nodes: Vec<NodeId>,
    pub fabrics: Vec<FabricKind>,
    /// Bottleneck bandwidth across the network legs (bytes/sec): the
    /// minimum over legs of the best rail the egress node offers on that
    /// leg's fabric. Endpoint PCIe staging hops are min-ed in by the
    /// planner, which knows whether the endpoints are device memory.
    pub bottleneck_bw: f64,
}

impl RelayRoute {
    /// Inter-node leg count (k).
    pub fn legs(&self) -> usize {
        self.fabrics.len()
    }

    /// The intermediate nodes whose host memory buffers the transfer —
    /// everything between the endpoints.
    pub fn relays(&self) -> &[NodeId] {
        &self.nodes[1..self.nodes.len() - 1]
    }
}

impl Topology {
    pub fn rail(&self, id: RailId) -> &RailDef {
        &self.rails[id.0 as usize]
    }

    pub fn node_in_fabric(&self, node: NodeId, fabric: FabricKind) -> bool {
        self.fabrics.iter().any(|&(n, f)| n == node && f == fabric)
    }

    /// All rails of a fabric kind on a node.
    pub fn rails_of(&self, node: NodeId, fabric: FabricKind) -> Vec<RailId> {
        self.rails
            .iter()
            .filter(|r| r.node == node && r.fabric == fabric)
            .map(|r| r.id)
            .collect()
    }

    /// GPUs present on a node.
    pub fn gpus(&self, node: NodeId) -> Vec<(u8, u8, u8)> {
        self.devices
            .iter()
            .filter_map(|d| match d.kind {
                DeviceKind::Gpu { idx, numa, pcie_root } if d.node == node => {
                    Some((idx, numa, pcie_root))
                }
                _ => None,
            })
            .collect()
    }

    /// Classify a rail's affinity tier relative to a memory location
    /// described by (numa, pcie_root). `pcie_root == None` means the location
    /// is host memory without a device root (NUMA affinity only).
    pub fn classify_tier(&self, rail: RailId, loc_numa: u8, loc_root: Option<u8>) -> Tier {
        let r = self.rail(rail);
        match loc_root {
            Some(root) => {
                if r.pcie_root == root {
                    Tier::T1
                } else if r.numa == loc_numa {
                    Tier::T2
                } else {
                    Tier::T3
                }
            }
            None => {
                // Host memory: NUMA-local NICs are tier-1, the rest tier-3
                // (crossing the socket interconnect).
                if r.numa == loc_numa {
                    Tier::T1
                } else {
                    Tier::T3
                }
            }
        }
    }

    /// The best host-network fabric shared by two distinct nodes — the one
    /// whose fastest rail on the egress node `a` has the highest nominal
    /// bandwidth (deterministic tie-break: [`HOST_NET_FABRICS`] order).
    /// `None` means no single inter-node leg can connect the pair.
    pub fn host_net_between(&self, a: NodeId, b: NodeId) -> Option<FabricKind> {
        if a == b {
            return None;
        }
        let mut best: Option<(FabricKind, f64)> = None;
        for f in HOST_NET_FABRICS {
            if !self.node_in_fabric(a, f) || !self.node_in_fabric(b, f) {
                continue;
            }
            let bw = self.best_leg_bw(a, f);
            if bw <= 0.0 {
                continue;
            }
            if best.map(|(_, b)| bw > b).unwrap_or(true) {
                best = Some((f, bw));
            }
        }
        best.map(|(f, _)| f)
    }

    /// Fastest rail bandwidth a node offers on a fabric (0.0 if it has no
    /// rails of that kind — fabric membership without rails cannot carry a
    /// leg).
    pub fn best_leg_bw(&self, node: NodeId, fabric: FabricKind) -> f64 {
        self.rails
            .iter()
            .filter(|r| r.node == node && r.fabric == fabric)
            .map(|r| r.bw_bytes_per_sec)
            .fold(0.0, f64::max)
    }

    /// Bounded fabric-reachability search (§3.1 extended to heterogeneous
    /// silos): enumerate relay routes from `src` to `dst` through host
    /// memory on intermediate nodes, using at most `max_legs` inter-node
    /// legs (clamped to [`MAX_RELAY_LEGS`]).
    ///
    /// Only *shortest* routes are returned (all of them, relay nodes in
    /// ascending order, capped at 4), so the result is deterministic for a
    /// given topology — the planner's "same seed → same relay choice"
    /// contract costs nothing because no RNG is involved at all. Returns an
    /// empty vec when the pair is unreachable within the leg budget, and a
    /// single one-leg route when the endpoints share a host fabric
    /// directly.
    pub fn relay_routes(&self, src: NodeId, dst: NodeId, max_legs: usize) -> Vec<RelayRoute> {
        let max_legs = max_legs.clamp(1, MAX_RELAY_LEGS);
        if src == dst {
            return Vec::new();
        }
        // BFS distances from src over the shared-host-fabric edge relation.
        let idx = |n: NodeId| self.nodes.iter().position(|&x| x == n);
        let (Some(_), Some(dst_i)) = (idx(src), idx(dst)) else {
            return Vec::new();
        };
        let n = self.nodes.len();
        let mut dist = vec![usize::MAX; n];
        let mut frontier = vec![src];
        dist[idx(src).unwrap()] = 0;
        let mut d = 0;
        while !frontier.is_empty() && d < max_legs && dist[dst_i] == usize::MAX {
            d += 1;
            let mut next = Vec::new();
            for &a in &frontier {
                for (i, &b) in self.nodes.iter().enumerate() {
                    if dist[i] != usize::MAX || self.host_net_between(a, b).is_none() {
                        continue;
                    }
                    dist[i] = d;
                    next.push(b);
                }
            }
            frontier = next;
        }
        let legs = dist[dst_i];
        if legs == usize::MAX {
            return Vec::new();
        }
        // Enumerate every shortest path by walking the BFS layers forward;
        // node order keeps it deterministic, the cap keeps it cheap.
        let mut routes = Vec::new();
        let mut stack: Vec<Vec<NodeId>> = vec![vec![src]];
        while let Some(path) = stack.pop() {
            if routes.len() >= 4 {
                break;
            }
            let here = *path.last().unwrap();
            let depth = path.len() - 1;
            if here == dst {
                let fabrics: Vec<FabricKind> = path
                    .windows(2)
                    .map(|w| self.host_net_between(w[0], w[1]).unwrap())
                    .collect();
                let bottleneck_bw = path
                    .windows(2)
                    .zip(&fabrics)
                    .map(|(w, &f)| self.best_leg_bw(w[0], f))
                    .fold(f64::INFINITY, f64::min);
                routes.push(RelayRoute {
                    nodes: path,
                    fabrics,
                    bottleneck_bw,
                });
                continue;
            }
            if depth >= legs {
                continue;
            }
            // Push in reverse node order so the stack pops ascending.
            for (i, &b) in self.nodes.iter().enumerate().rev() {
                let on_layer = dist[i] == depth + 1 && (b == dst || depth + 1 < legs);
                if on_layer && self.host_net_between(here, b).is_some() && !path.contains(&b) {
                    let mut next = path.clone();
                    next.push(b);
                    stack.push(next);
                }
            }
        }
        routes
    }

    /// Dump a human-readable topology description.
    pub fn describe(&self) -> String {
        let mut s = format!("profile: {}\n", self.profile_name);
        for &n in &self.nodes {
            s.push_str(&format!("{}:\n", n));
            for d in self.devices.iter().filter(|d| d.node == n) {
                s.push_str(&format!("  {:?}\n", d.kind));
            }
            for r in self.rails.iter().filter(|r| r.node == n) {
                s.push_str(&format!(
                    "  {} {} numa{} root{} {} lat={}ns{}\n",
                    r.name,
                    r.fabric.name(),
                    r.numa,
                    r.pcie_root,
                    crate::util::fmt_bw(r.bw_bytes_per_sec),
                    r.base_latency_ns,
                    if r.gpudirect { " gpudirect" } else { "" },
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::profile::build_profile;
    use super::*;

    #[test]
    fn h800_profile_shape() {
        let t = build_profile("h800_hgx", 2).unwrap();
        assert_eq!(t.nodes.len(), 2);
        // 8 RDMA NICs per node.
        assert_eq!(t.rails_of(NodeId(0), FabricKind::Rdma).len(), 8);
        // 8 NVLink ports per node (one per GPU).
        assert_eq!(t.rails_of(NodeId(0), FabricKind::NvLink).len(), 8);
        assert_eq!(t.gpus(NodeId(0)).len(), 8);
        assert!(t.node_in_fabric(NodeId(0), FabricKind::Rdma));
        assert!(!t.node_in_fabric(NodeId(0), FabricKind::Mnnvl));
    }

    #[test]
    fn tier_classification_gpu_affinity() {
        let t = build_profile("h800_hgx", 1).unwrap();
        // GPU 0 is on numa 0, pcie root 0. Exactly one tier-1 RDMA NIC.
        let rails = t.rails_of(NodeId(0), FabricKind::Rdma);
        let tiers: Vec<Tier> = rails
            .iter()
            .map(|&r| t.classify_tier(r, 0, Some(0)))
            .collect();
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T1).count(), 1);
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T2).count(), 3);
        assert_eq!(tiers.iter().filter(|&&x| x == Tier::T3).count(), 4);
    }

    #[test]
    fn tier_classification_host_numa() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let rails = t.rails_of(NodeId(0), FabricKind::Rdma);
        let t1 = rails
            .iter()
            .filter(|&&r| t.classify_tier(r, 0, None) == Tier::T1)
            .count();
        assert_eq!(t1, 4); // 4 NICs per socket
    }

    #[test]
    fn penalties_match_paper() {
        assert_eq!(Tier::T1.default_penalty(), 1.0);
        assert_eq!(Tier::T2.default_penalty(), 3.0);
        assert!(Tier::T3.default_penalty().is_infinite());
    }

    #[test]
    fn unknown_profile_rejected() {
        assert!(build_profile("warp_drive", 1).is_err());
    }

    #[test]
    fn relay_routes_bridge_partitioned_silos() {
        let t = build_profile("silo_fleet", 3).unwrap();
        // GPU silo (0) → NPU silo (1): no shared host fabric, so the only
        // route is the 2-leg relay through the gateway's host memory.
        let routes = t.relay_routes(NodeId(0), NodeId(1), 3);
        assert_eq!(routes.len(), 1);
        let r = &routes[0];
        assert_eq!(r.nodes, vec![NodeId(0), NodeId(2), NodeId(1)]);
        assert_eq!(r.fabrics, vec![FabricKind::Rdma, FabricKind::Tcp]);
        assert_eq!(r.legs(), 2);
        assert_eq!(r.relays(), &[NodeId(2)]);
        // Bottleneck = the gateway's TCP leg, not the fat RDMA first leg.
        let tcp_bw = t.best_leg_bw(NodeId(2), FabricKind::Tcp);
        let rdma_bw = t.best_leg_bw(NodeId(0), FabricKind::Rdma);
        assert!(tcp_bw < rdma_bw);
        assert_eq!(r.bottleneck_bw, tcp_bw);
        // A 1-leg budget can't reach across the partition.
        assert!(t.relay_routes(NodeId(0), NodeId(1), 1).is_empty());
    }

    #[test]
    fn relay_routes_are_deterministic_and_shortest() {
        let t = build_profile("silo_fleet", 6).unwrap();
        let a = t.relay_routes(NodeId(0), NodeId(4), 3);
        let b = t.relay_routes(NodeId(0), NodeId(4), 3);
        assert_eq!(a, b, "route search must be a pure function of the topology");
        assert!(!a.is_empty());
        // Two gateways (2 and 5) → two shortest 2-leg routes, relays in
        // ascending node order.
        assert!(a.iter().all(|r| r.legs() == 2), "{a:?}");
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].relays(), &[NodeId(2)]);
        assert_eq!(a[1].relays(), &[NodeId(5)]);
        // Directly-connected pairs get a single-leg route.
        let direct = t.relay_routes(NodeId(0), NodeId(3), 3);
        assert!(direct.iter().all(|r| r.legs() == 1));
        // Same node: nothing to relay.
        assert!(t.relay_routes(NodeId(0), NodeId(0), 3).is_empty());
    }
}
