//! Built-in cluster profiles — the simulation analogue of topology discovery.
//!
//! All bandwidths are the paper's hardware scaled 1:100 (`SCALE`), so an
//! 8-rail H800 node's 8×25 GB/s RDMA fabric becomes 8×250 MB/s of *actually
//! copied* bytes, and benches complete in seconds while preserving every
//! ratio the paper reports.

use super::*;
use crate::{Error, Result};

/// Bandwidth scale factor versus the paper's hardware.
pub const SCALE: f64 = 100.0;

/// GB/s (paper units) → bytes/sec (sim units).
pub fn gbps_paper(gb_per_s: f64) -> f64 {
    gb_per_s * 1e9 / SCALE
}

/// Paper-reported theoretical bandwidths (GB/s, *unscaled*), used by the
/// Table 4 bench to print the theoretical column.
pub mod theoretical {
    /// Per 200 Gbps RoCE rail.
    pub const RDMA_RAIL_GBPS: f64 = 25.0;
    /// NVLink GPU↔GPU (26.562 × 8).
    pub const NVLINK_GBPS: f64 = 204.496;
    /// Multi-Node NVLink.
    pub const MNNVL_GBPS: f64 = 956.2;
    /// Ascend UB.
    pub const ASCEND_GBPS: f64 = 196.0;
    /// Host PCIe gen5 x16 staging path.
    pub const PCIE_GBPS: f64 = 64.0;
}

struct Builder {
    topo: Topology,
}

impl Builder {
    fn new(name: &str) -> Self {
        Builder {
            topo: Topology {
                profile_name: name.to_string(),
                ..Default::default()
            },
        }
    }

    fn node(&mut self, id: u16) -> NodeId {
        let n = NodeId(id);
        self.topo.nodes.push(n);
        n
    }

    fn fabric(&mut self, node: NodeId, f: FabricKind) {
        self.topo.fabrics.push((node, f));
    }

    fn device(&mut self, node: NodeId, kind: DeviceKind) {
        self.topo.devices.push(Device { node, kind });
    }

    #[allow(clippy::too_many_arguments)]
    fn rail(
        &mut self,
        node: NodeId,
        fabric: FabricKind,
        name: String,
        numa: u8,
        pcie_root: u8,
        bw: f64,
        lat_ns: u64,
        gpu_idx: Option<u8>,
        gpudirect: bool,
    ) -> RailId {
        let id = RailId(self.topo.rails.len() as u32);
        self.topo.rails.push(RailDef {
            id,
            name,
            fabric,
            node,
            numa,
            pcie_root,
            bw_bytes_per_sec: bw,
            base_latency_ns: lat_ns,
            gpu_idx,
            gpudirect,
        });
        id
    }

    /// A standard H800 HGX node: 2 sockets, 8 GPUs, 8 NICs (one per PCIe
    /// root, shared with its GPU), NVLink among GPUs, 1 NVMe, SHM + PCIe +
    /// TCP rails. `tcp = false` drops the TCP fallback (the silo-isolated
    /// prefill shape: the node reaches the rest of the fleet over RDMA
    /// only).
    fn h800_node(&mut self, id: u16, gpudirect: bool, nvlink: bool, tcp: bool) -> NodeId {
        let n = self.node(id);
        self.fabric(n, FabricKind::Rdma);
        if tcp {
            self.fabric(n, FabricKind::Tcp);
        }
        self.fabric(n, FabricKind::Shm);
        self.fabric(n, FabricKind::Pcie);
        self.fabric(n, FabricKind::FileIo);
        if nvlink {
            self.fabric(n, FabricKind::NvLink);
        }
        for numa in 0..2u8 {
            self.device(n, DeviceKind::CpuNuma { numa });
        }
        for g in 0..8u8 {
            let numa = g / 4;
            self.device(
                n,
                DeviceKind::Gpu {
                    idx: g,
                    numa,
                    pcie_root: g,
                },
            );
            // One 200 Gbps NIC per PCIe root complex, adjacent to GPU g.
            self.rail(
                n,
                FabricKind::Rdma,
                format!("n{id}-mlx{g}"),
                numa,
                g,
                gbps_paper(theoretical::RDMA_RAIL_GBPS),
                20_000,
                None,
                gpudirect,
            );
            self.device(
                n,
                DeviceKind::Nic {
                    idx: g,
                    numa,
                    pcie_root: g,
                },
            );
            if nvlink {
                // Each GPU's NVLink port into the NVSwitch plane.
                self.rail(
                    n,
                    FabricKind::NvLink,
                    format!("n{id}-nvl{g}"),
                    numa,
                    g,
                    gbps_paper(theoretical::NVLINK_GBPS / 8.0) * 8.0, // full per-pair path
                    3_000,
                    Some(g),
                    true,
                );
            }
            // PCIe H2D/D2H staging rail for this GPU.
            self.rail(
                n,
                FabricKind::Pcie,
                format!("n{id}-pcie{g}"),
                numa,
                g,
                gbps_paper(theoretical::PCIE_GBPS),
                10_000,
                Some(g),
                true,
            );
        }
        // Intra-node host<->host shared memory, one rail per socket.
        for numa in 0..2u8 {
            self.rail(
                n,
                FabricKind::Shm,
                format!("n{id}-shm{numa}"),
                numa,
                255,
                gbps_paper(500.0),
                2_000,
                None,
                false,
            );
        }
        // TCP fallback rail (real loopback sockets, paced to 10 Gbps/SCALE).
        if tcp {
            self.rail(
                n,
                FabricKind::Tcp,
                format!("n{id}-tcp"),
                0,
                255,
                gbps_paper(1.25),
                80_000,
                None,
                false,
            );
        }
        // One NVMe SSD, io_uring-style file backend (real file I/O, unpaced).
        self.device(n, DeviceKind::Ssd { idx: 0, numa: 0 });
        self.rail(
            n,
            FabricKind::FileIo,
            format!("n{id}-nvme0"),
            0,
            255,
            gbps_paper(6.0),
            30_000,
            None,
            false,
        );
        n
    }

    /// An Ascend NPU node. `roce = false` drops the RoCE NICs and RDMA
    /// fabric membership (the silo-isolated decode shape: the node reaches
    /// the rest of the fleet over TCP only).
    fn ascend_node(&mut self, id: u16, roce: bool) -> NodeId {
        let n = self.node(id);
        self.fabric(n, FabricKind::AscendUb);
        if roce {
            self.fabric(n, FabricKind::Rdma);
        }
        self.fabric(n, FabricKind::Tcp);
        self.fabric(n, FabricKind::Shm);
        self.fabric(n, FabricKind::Pcie);
        for numa in 0..2u8 {
            self.device(n, DeviceKind::CpuNuma { numa });
        }
        for g in 0..8u8 {
            let numa = g / 4;
            self.device(
                n,
                DeviceKind::Gpu {
                    idx: g,
                    numa,
                    pcie_root: g,
                },
            );
            // Ascend UB port per NPU.
            self.rail(
                n,
                FabricKind::AscendUb,
                format!("n{id}-ub{g}"),
                numa,
                g,
                gbps_paper(theoretical::ASCEND_GBPS),
                4_000,
                Some(g),
                true,
            );
            self.rail(
                n,
                FabricKind::Pcie,
                format!("n{id}-pcie{g}"),
                numa,
                g,
                gbps_paper(theoretical::PCIE_GBPS / 2.0),
                12_000,
                Some(g),
                true,
            );
        }
        // 4 RoCE NICs (no GPUDirect on this stack — HIXL handles NPU mem).
        if roce {
            for i in 0..4u8 {
                self.rail(
                    n,
                    FabricKind::Rdma,
                    format!("n{id}-roce{i}"),
                    i / 2,
                    2 * i,
                    gbps_paper(theoretical::RDMA_RAIL_GBPS / 2.0),
                    25_000,
                    None,
                    false,
                );
            }
        }
        for numa in 0..2u8 {
            self.rail(
                n,
                FabricKind::Shm,
                format!("n{id}-shm{numa}"),
                numa,
                255,
                gbps_paper(400.0),
                2_000,
                None,
                false,
            );
        }
        self.rail(
            n,
            FabricKind::Tcp,
            format!("n{id}-tcp"),
            0,
            255,
            gbps_paper(1.25),
            80_000,
            None,
            false,
        );
        n
    }

    fn tcp_only_node(&mut self, id: u16) -> NodeId {
        let n = self.node(id);
        self.fabric(n, FabricKind::Tcp);
        self.fabric(n, FabricKind::Shm);
        self.device(n, DeviceKind::CpuNuma { numa: 0 });
        self.rail(
            n,
            FabricKind::Shm,
            format!("n{id}-shm0"),
            0,
            255,
            gbps_paper(300.0),
            2_500,
            None,
            false,
        );
        self.rail(
            n,
            FabricKind::Tcp,
            format!("n{id}-tcp"),
            0,
            255,
            gbps_paper(1.25),
            90_000,
            None,
            false,
        );
        n
    }

    /// A host-only relay gateway bridging the RDMA backbone and the TCP
    /// front net: the one node a silo-isolated fleet can route cross-silo
    /// traffic through. Two rails per fabric so a single rail failure on
    /// the relay never severs the route.
    fn gateway_node(&mut self, id: u16) -> NodeId {
        let n = self.node(id);
        self.fabric(n, FabricKind::Rdma);
        self.fabric(n, FabricKind::Tcp);
        self.fabric(n, FabricKind::Shm);
        self.device(n, DeviceKind::CpuNuma { numa: 0 });
        for i in 0..2u8 {
            self.rail(
                n,
                FabricKind::Rdma,
                format!("n{id}-gwmlx{i}"),
                0,
                2 * i,
                gbps_paper(theoretical::RDMA_RAIL_GBPS),
                20_000,
                None,
                false,
            );
            self.device(
                n,
                DeviceKind::Nic {
                    idx: i,
                    numa: 0,
                    pcie_root: 2 * i,
                },
            );
            self.rail(
                n,
                FabricKind::Tcp,
                format!("n{id}-gwtcp{i}"),
                0,
                255,
                gbps_paper(1.25),
                80_000,
                None,
                false,
            );
        }
        self.rail(
            n,
            FabricKind::Shm,
            format!("n{id}-shm0"),
            0,
            255,
            gbps_paper(300.0),
            2_500,
            None,
            false,
        );
        n
    }

    fn mnnvl_node(&mut self, id: u16) -> NodeId {
        let n = self.h800_node(id, true, true, true);
        self.fabric(n, FabricKind::Mnnvl);
        for g in 0..8u8 {
            let numa = g / 4;
            self.rail(
                n,
                FabricKind::Mnnvl,
                format!("n{id}-mnnvl{g}"),
                numa,
                g,
                gbps_paper(theoretical::MNNVL_GBPS),
                5_000,
                Some(g),
                true,
            );
        }
        n
    }
}

/// Build a named profile with `nodes` hosts (where the profile is
/// node-count-parametric).
///
/// Profiles:
/// * `h800_hgx` — the paper's primary testbed: 8×GPU + 8×200 Gbps RoCE +
///   NVLink per node.
/// * `h800_no_nvlink` — same, NVLink disabled (the Mooncake-TE deployment
///   shape where GPU↔GPU goes over RDMA).
/// * `no_gpudirect` — consumer-GPU shape: RDMA NICs cannot reach device
///   memory, NVLink absent → the orchestrator must synthesize staged routes.
/// * `mnnvl_rack` — GB200-NVL72-like rack: adds MNNVL GPU fabric.
/// * `ascend_ub` — Huawei Ascend node with UB/HIXL + RoCE.
/// * `legacy_tcp` — hosts with TCP only.
/// * `mixed_fleet` — H800 / Ascend / legacy nodes in a repeating 1:1:1 mix
///   (the paper's communication-silo scenario); `nodes` below 3 yields the
///   canonical 3-node shape.
/// * `silo_fleet` — mixed fleet with *partitioned* host fabrics: RDMA-only
///   H800 prefill nodes, TCP-only Ascend decode nodes, and host-only
///   RDMA+TCP gateway relays in a repeating 1:1:1 mix — cross-silo pairs
///   are reachable only through a k-hop staged route via a gateway.
pub fn build_profile(name: &str, nodes: u16) -> Result<Topology> {
    let mut b = Builder::new(name);
    match name {
        "h800_hgx" => {
            for i in 0..nodes.max(1) {
                b.h800_node(i, true, true, true);
            }
        }
        "h800_no_nvlink" => {
            for i in 0..nodes.max(1) {
                b.h800_node(i, true, false, true);
            }
        }
        "no_gpudirect" => {
            for i in 0..nodes.max(1) {
                b.h800_node(i, false, false, true);
            }
        }
        "mnnvl_rack" => {
            for i in 0..nodes.max(1) {
                b.mnnvl_node(i);
            }
        }
        "ascend_ub" => {
            for i in 0..nodes.max(1) {
                b.ascend_node(i, true);
            }
        }
        "legacy_tcp" => {
            for i in 0..nodes.max(1) {
                b.tcp_only_node(i);
            }
        }
        "mixed_fleet" => {
            // Node-count-parametric silo mix: the canonical 3-node shape
            // (H800, Ascend, legacy) repeats round-robin, so an N-node
            // fleet keeps the same heterogeneity ratio. `nodes ≤ 3` is the
            // original 3-node paper scenario.
            for i in 0..nodes.max(3) {
                match i % 3 {
                    0 => b.h800_node(i, true, true, true),
                    1 => b.ascend_node(i, true),
                    _ => b.tcp_only_node(i),
                };
            }
        }
        "silo_fleet" => {
            // Communication-silo disaggregation with *partitioned* host
            // fabrics: prefill H800 nodes speak RDMA only (no TCP front
            // net), decode Ascend nodes speak TCP only (no RoCE NICs), and
            // every third node is a host-only gateway on both — so a
            // cross-silo GPU→NPU transfer has no direct backend and no
            // single-bounce staged path, and must relay through a
            // gateway's host memory (RDMA leg, then TCP leg). The k-hop
            // planner's motivating shape.
            for i in 0..nodes.max(3) {
                match i % 3 {
                    0 => b.h800_node(i, true, true, false),
                    1 => b.ascend_node(i, false),
                    _ => b.gateway_node(i),
                };
            }
        }
        other => {
            return Err(Error::Config(format!(
                "unknown profile '{other}' (try h800_hgx, h800_no_nvlink, no_gpudirect, \
                 mnnvl_rack, ascend_ub, legacy_tcp, mixed_fleet, silo_fleet)"
            )))
        }
    }
    Ok(b.topo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_build() {
        for p in [
            "h800_hgx",
            "h800_no_nvlink",
            "no_gpudirect",
            "mnnvl_rack",
            "ascend_ub",
            "legacy_tcp",
            "mixed_fleet",
            "silo_fleet",
        ] {
            let t = build_profile(p, 2).unwrap();
            assert!(!t.rails.is_empty(), "{p} has rails");
            assert!(!t.nodes.is_empty());
            // Rail ids must be dense and self-consistent.
            for (i, r) in t.rails.iter().enumerate() {
                assert_eq!(r.id.0 as usize, i);
            }
        }
    }

    #[test]
    fn scale_is_1_to_100() {
        let t = build_profile("h800_hgx", 1).unwrap();
        let r = &t.rails[t.rails_of(NodeId(0), FabricKind::Rdma)[0].0 as usize];
        assert!((r.bw_bytes_per_sec - 250e6).abs() < 1.0);
    }

    #[test]
    fn no_gpudirect_profile_has_no_device_capable_nics() {
        let t = build_profile("no_gpudirect", 1).unwrap();
        assert!(t
            .rails_of(NodeId(0), FabricKind::Rdma)
            .iter()
            .all(|&r| !t.rail(r).gpudirect));
        assert!(t.rails_of(NodeId(0), FabricKind::NvLink).is_empty());
    }

    #[test]
    fn mixed_fleet_is_heterogeneous() {
        let t = build_profile("mixed_fleet", 0).unwrap();
        assert!(t.node_in_fabric(NodeId(0), FabricKind::NvLink));
        assert!(t.node_in_fabric(NodeId(1), FabricKind::AscendUb));
        assert!(!t.node_in_fabric(NodeId(2), FabricKind::Rdma));
        // TCP is the only fabric shared by all three.
        for n in [NodeId(0), NodeId(1), NodeId(2)] {
            assert!(t.node_in_fabric(n, FabricKind::Tcp));
        }
    }

    #[test]
    fn mixed_fleet_is_node_count_parametric() {
        let t = build_profile("mixed_fleet", 8).unwrap();
        assert_eq!(t.nodes.len(), 8);
        // Repeating 1:1:1 silo mix.
        for n in [NodeId(0), NodeId(3), NodeId(6)] {
            assert!(t.node_in_fabric(n, FabricKind::NvLink), "{n:?}");
        }
        for n in [NodeId(1), NodeId(4), NodeId(7)] {
            assert!(t.node_in_fabric(n, FabricKind::AscendUb), "{n:?}");
        }
        for n in [NodeId(2), NodeId(5)] {
            assert!(!t.node_in_fabric(n, FabricKind::Rdma), "{n:?}");
            assert!(t.node_in_fabric(n, FabricKind::Tcp), "{n:?}");
        }
    }

    #[test]
    fn silo_fleet_partitions_host_fabrics() {
        let t = build_profile("silo_fleet", 3).unwrap();
        // Prefill silo: RDMA backbone, no TCP front net.
        assert!(t.node_in_fabric(NodeId(0), FabricKind::Rdma));
        assert!(!t.node_in_fabric(NodeId(0), FabricKind::Tcp));
        assert!(t.node_in_fabric(NodeId(0), FabricKind::NvLink));
        // Decode silo: TCP only, no RoCE.
        assert!(!t.node_in_fabric(NodeId(1), FabricKind::Rdma));
        assert!(t.node_in_fabric(NodeId(1), FabricKind::Tcp));
        assert!(t.node_in_fabric(NodeId(1), FabricKind::AscendUb));
        assert!(t.rails_of(NodeId(1), FabricKind::Rdma).is_empty());
        // Gateway: both, host-only, dual rails per fabric.
        assert!(t.node_in_fabric(NodeId(2), FabricKind::Rdma));
        assert!(t.node_in_fabric(NodeId(2), FabricKind::Tcp));
        assert_eq!(t.rails_of(NodeId(2), FabricKind::Rdma).len(), 2);
        assert_eq!(t.rails_of(NodeId(2), FabricKind::Tcp).len(), 2);
        assert!(t.gpus(NodeId(2)).is_empty());
        // The silos share no host fabric with each other; both reach the
        // gateway.
        assert!(t.host_net_between(NodeId(0), NodeId(1)).is_none());
        assert_eq!(t.host_net_between(NodeId(0), NodeId(2)), Some(FabricKind::Rdma));
        assert_eq!(t.host_net_between(NodeId(1), NodeId(2)), Some(FabricKind::Tcp));
    }

    #[test]
    fn silo_fleet_is_node_count_parametric() {
        let t = build_profile("silo_fleet", 6).unwrap();
        assert_eq!(t.nodes.len(), 6);
        for n in [NodeId(2), NodeId(5)] {
            assert!(t.node_in_fabric(n, FabricKind::Rdma), "{n:?}");
            assert!(t.node_in_fabric(n, FabricKind::Tcp), "{n:?}");
            assert!(t.gpus(n).is_empty(), "{n:?} is host-only");
        }
    }

    #[test]
    fn mnnvl_rack_has_mnnvl_rails() {
        let t = build_profile("mnnvl_rack", 2).unwrap();
        assert_eq!(t.rails_of(NodeId(0), FabricKind::Mnnvl).len(), 8);
        assert!(t.node_in_fabric(NodeId(1), FabricKind::Mnnvl));
    }
}
