//! Custom cluster profiles from JSON files — operators describe their own
//! fleet instead of using a built-in profile (the deployment-config path of
//! a production transfer engine).
//!
//! Schema (see `describe_schema()`):
//! ```json
//! {
//!   "name": "my_fleet",
//!   "nodes": [
//!     { "id": 0, "numa_domains": 2,
//!       "gpus": [ {"idx": 0, "numa": 0, "pcie_root": 0}, ... ],
//!       "rails": [
//!         { "fabric": "rdma", "name": "mlx0", "numa": 0, "pcie_root": 0,
//!           "bw_gbps_paper": 25.0, "base_latency_us": 20,
//!           "gpudirect": true },
//!         { "fabric": "nvlink", "name": "nvl0", "numa": 0, "pcie_root": 0,
//!           "bw_gbps_paper": 204.5, "base_latency_us": 3, "gpu_idx": 0 }
//!       ] }
//!   ]
//! }
//! ```
//! Bandwidths are given in *paper* GB/s and scaled by 1:SCALE like the
//! built-ins, so custom profiles stay comparable.

use super::profile::SCALE;
use super::*;
use crate::util::json::Json;
use crate::{Error, Result};

fn fabric_kind(s: &str) -> Result<FabricKind> {
    FabricKind::ALL
        .iter()
        .copied()
        .find(|f| f.name() == s)
        .ok_or_else(|| Error::Config(format!("unknown fabric '{s}'")))
}

/// Parse a topology from JSON text.
pub fn parse_profile(text: &str) -> Result<Topology> {
    let j = Json::parse(text).map_err(|e| Error::Config(format!("profile json: {e}")))?;
    let name = j
        .get("name")
        .as_str()
        .ok_or_else(|| Error::Config("profile needs a 'name'".into()))?
        .to_string();
    let mut topo = Topology {
        profile_name: name,
        ..Default::default()
    };
    let nodes = j
        .get("nodes")
        .as_arr()
        .ok_or_else(|| Error::Config("profile needs 'nodes' array".into()))?;
    if nodes.is_empty() {
        return Err(Error::Config("profile has no nodes".into()));
    }
    for n in nodes {
        let id = NodeId(
            n.get("id")
                .as_u64()
                .ok_or_else(|| Error::Config("node needs 'id'".into()))? as u16,
        );
        if topo.nodes.contains(&id) {
            return Err(Error::Config(format!("duplicate node id {}", id.0)));
        }
        topo.nodes.push(id);
        let numa_domains = n.get("numa_domains").as_u64().unwrap_or(1) as u8;
        for numa in 0..numa_domains {
            topo.devices.push(Device {
                node: id,
                kind: DeviceKind::CpuNuma { numa },
            });
        }
        if let Some(gpus) = n.get("gpus").as_arr() {
            for g in gpus {
                let idx = g
                    .get("idx")
                    .as_u64()
                    .ok_or_else(|| Error::Config("gpu needs 'idx'".into()))?
                    as u8;
                topo.devices.push(Device {
                    node: id,
                    kind: DeviceKind::Gpu {
                        idx,
                        numa: g.get("numa").as_u64().unwrap_or(0) as u8,
                        pcie_root: g.get("pcie_root").as_u64().unwrap_or(idx as u64) as u8,
                    },
                });
            }
        }
        let rails = n
            .get("rails")
            .as_arr()
            .ok_or_else(|| Error::Config(format!("node {} needs 'rails'", id.0)))?;
        for r in rails {
            let fabric = fabric_kind(
                r.get("fabric")
                    .as_str()
                    .ok_or_else(|| Error::Config("rail needs 'fabric'".into()))?,
            )?;
            let bw_paper = r
                .get("bw_gbps_paper")
                .as_f64()
                .ok_or_else(|| Error::Config("rail needs 'bw_gbps_paper'".into()))?;
            if bw_paper <= 0.0 {
                return Err(Error::Config("rail bandwidth must be positive".into()));
            }
            let rail_id = RailId(topo.rails.len() as u32);
            topo.rails.push(RailDef {
                id: rail_id,
                name: format!(
                    "n{}-{}",
                    id.0,
                    r.get("name").as_str().unwrap_or(fabric.name())
                ),
                fabric,
                node: id,
                numa: r.get("numa").as_u64().unwrap_or(0) as u8,
                pcie_root: r.get("pcie_root").as_u64().unwrap_or(255) as u8,
                bw_bytes_per_sec: bw_paper * 1e9 / SCALE,
                base_latency_ns: r.get("base_latency_us").as_u64().unwrap_or(20) * 1000,
                gpu_idx: r.get("gpu_idx").as_u64().map(|v| v as u8),
                gpudirect: r.get("gpudirect").as_bool().unwrap_or(false),
            });
            if !topo.node_in_fabric(id, fabric) {
                topo.fabrics.push((id, fabric));
            }
        }
    }
    Ok(topo)
}

/// Load a topology from a JSON file path.
pub fn load_profile_file(path: &std::path::Path) -> Result<Topology> {
    parse_profile(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "custom_duo",
      "nodes": [
        { "id": 0, "numa_domains": 2,
          "gpus": [ {"idx": 0, "numa": 0, "pcie_root": 0} ],
          "rails": [
            { "fabric": "rdma", "name": "mlx0", "numa": 0, "pcie_root": 0,
              "bw_gbps_paper": 25.0, "base_latency_us": 20, "gpudirect": true },
            { "fabric": "rdma", "name": "mlx1", "numa": 1, "pcie_root": 4,
              "bw_gbps_paper": 12.5, "base_latency_us": 25 },
            { "fabric": "nvlink", "name": "nvl0", "numa": 0, "pcie_root": 0,
              "bw_gbps_paper": 204.5, "base_latency_us": 3, "gpu_idx": 0,
              "gpudirect": true },
            { "fabric": "tcp", "bw_gbps_paper": 1.25, "base_latency_us": 80 }
          ] },
        { "id": 1, "numa_domains": 1,
          "rails": [
            { "fabric": "rdma", "name": "mlx0", "numa": 0, "pcie_root": 0,
              "bw_gbps_paper": 25.0 },
            { "fabric": "tcp", "bw_gbps_paper": 1.25 }
          ] }
      ]
    }"#;

    #[test]
    fn parses_custom_profile() {
        let t = parse_profile(SAMPLE).unwrap();
        assert_eq!(t.profile_name, "custom_duo");
        assert_eq!(t.nodes.len(), 2);
        assert_eq!(t.rails.len(), 6);
        assert_eq!(t.rails_of(NodeId(0), FabricKind::Rdma).len(), 2);
        assert!(t.node_in_fabric(NodeId(0), FabricKind::NvLink));
        assert!(!t.node_in_fabric(NodeId(1), FabricKind::NvLink));
        // Scaled like built-ins: 25 GB/s paper → 250 MB/s sim.
        let r = t.rail(t.rails_of(NodeId(0), FabricKind::Rdma)[0]);
        assert!((r.bw_bytes_per_sec - 250e6).abs() < 1.0);
        assert!(r.gpudirect);
        assert_eq!(r.base_latency_ns, 20_000);
        // Rail ids dense.
        for (i, r) in t.rails.iter().enumerate() {
            assert_eq!(r.id.0 as usize, i);
        }
    }

    #[test]
    fn custom_profile_drives_a_real_engine() {
        use crate::engine::{EngineConfig, TentEngine, TransferReq};
        use crate::fabric::FabricConfig;
        use crate::segment::Location;
        use std::sync::Arc;

        let topo = Arc::new(parse_profile(SAMPLE).unwrap());
        let cluster =
            crate::cluster::Cluster::from_topology(topo, FabricConfig::default()).unwrap();
        let e = TentEngine::new(&cluster, EngineConfig::default()).unwrap();
        let a = e.register_segment(Location::host(0, 0), 1 << 20).unwrap();
        let b = e.register_segment(Location::host(1, 0), 1 << 20).unwrap();
        e.segment(a).unwrap().write_at(0, &[9u8; 1 << 20]).unwrap();
        e.transfer_sync(
            TransferReq::write(a, 0, b, 0, 1 << 20),
            std::time::Duration::from_secs(60),
        )
        .unwrap();
        let mut buf = vec![0u8; 1 << 20];
        e.segment(b).unwrap().read_at(0, &mut buf).unwrap();
        assert!(buf.iter().all(|&x| x == 9));
    }

    #[test]
    fn rejects_malformed_profiles() {
        assert!(parse_profile("{}").is_err()); // no name
        assert!(parse_profile(r#"{"name":"x"}"#).is_err()); // no nodes
        assert!(parse_profile(r#"{"name":"x","nodes":[]}"#).is_err());
        // unknown fabric
        let bad = r#"{"name":"x","nodes":[{"id":0,"rails":[
            {"fabric":"warp","bw_gbps_paper":1}]}]}"#;
        assert!(parse_profile(bad).is_err());
        // negative bandwidth
        let bad2 = r#"{"name":"x","nodes":[{"id":0,"rails":[
            {"fabric":"tcp","bw_gbps_paper":-1}]}]}"#;
        assert!(parse_profile(bad2).is_err());
        // duplicate node ids
        let bad3 = r#"{"name":"x","nodes":[
            {"id":0,"rails":[{"fabric":"tcp","bw_gbps_paper":1}]},
            {"id":0,"rails":[{"fabric":"tcp","bw_gbps_paper":1}]}]}"#;
        assert!(parse_profile(bad3).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let p = std::env::temp_dir().join(format!("tent_prof_{}.json", std::process::id()));
        std::fs::write(&p, SAMPLE).unwrap();
        let t = load_profile_file(&p).unwrap();
        assert_eq!(t.profile_name, "custom_duo");
        std::fs::remove_file(p).ok();
    }
}
