//! Target-rot guard: every example and bench target must keep compiling.
//!
//! `cargo test` only builds the lib, bin, tests, and examples — bench
//! targets (declared `test = false` so their long workloads stay out of the
//! test run) would otherwise rot silently. This test shells back into cargo
//! and builds all of them. CI runs the same command as a dedicated step;
//! this test makes the guarantee hold for plain local `cargo test` too.

use std::process::Command;

#[test]
fn examples_and_benches_compile() {
    // Opt-out for runs where a dedicated `cargo build --examples --benches`
    // step already covers this (CI sets it on the tier-1 job to avoid
    // building everything twice).
    if std::env::var_os("TENT_SKIP_TARGET_SMOKE").is_some() {
        eprintln!("skipping: TENT_SKIP_TARGET_SMOKE set (covered by a dedicated build step)");
        return;
    }
    // The cargo that spawned this test run; skip if invoked outside cargo
    // (e.g. running the test binary directly).
    let Some(cargo) = std::env::var_os("CARGO") else {
        eprintln!("skipping: CARGO not set (test binary run outside cargo)");
        return;
    };
    let manifest = concat!(env!("CARGO_MANIFEST_DIR"), "/Cargo.toml");
    // Build into a dedicated target dir: never contends with an outer
    // cargo's directory lock, never clobbers its artifacts.
    let target = concat!(env!("CARGO_MANIFEST_DIR"), "/target/smoke-targets");
    let out = Command::new(cargo)
        .args([
            "build",
            "--examples",
            "--benches",
            "--manifest-path",
            manifest,
            "--target-dir",
            target,
        ])
        .output()
        .expect("failed to spawn cargo");
    assert!(
        out.status.success(),
        "`cargo build --examples --benches` failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}
