//! Fleet-scale stress: many engines over one shared fabric (tier-1).
//!
//! Every test stands up a `cluster::Fleet` — one engine per node, all
//! funneling into the cluster-shared per-rail workers — drives the mixed
//! KV-fetch (Latency) / checkpoint (Bulk) workload from *every* engine
//! concurrently, and checks the invariants that must survive scale:
//!
//! * **slice conservation** — the fabric's per-NIC byte counters sum to
//!   exactly the payload bytes the engines submitted: nothing lost,
//!   nothing duplicated, even across retries;
//! * **ledger balance** — per engine, completed == dispatched, queued
//!   bytes drain to zero on every rail, and the sharded queued-bytes
//!   counters never underflow;
//! * **per-class accounting** — latency + bulk completions add up, and
//!   both classes make progress on every engine;
//! * **bounded fairness** — no engine starves on a homogeneous fleet;
//! * **failure re-convergence** — a mid-run rail kill + recovery is masked
//!   (zero failed batches) and the recovered rails carry traffic again.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;
use tent::cluster::{Fleet, FleetConfig, WorkloadConfig};
use tent::topology::{FabricKind, NodeId};

fn workload(ms: u64) -> WorkloadConfig {
    WorkloadConfig {
        duration: Duration::from_millis(ms),
        submitters_per_engine: 2,
        ..Default::default()
    }
}

/// Core invariant pack shared by every scale point.
fn check_invariants(fleet: &Fleet, submitted_floor: u64) {
    let mut bytes_submitted = 0u64;
    for (i, e) in fleet.engines().iter().enumerate() {
        let s = e.stats();
        assert_eq!(
            s.slices_completed, s.slices_dispatched,
            "engine {i} ledger: {s:?}"
        );
        assert_eq!(s.permanent_failures, 0, "engine {i}: {s:?}");
        assert_eq!(
            s.slices_completed_latency + s.slices_completed_bulk,
            s.slices_completed,
            "engine {i} class split: {s:?}"
        );
        assert!(
            s.slices_completed_latency > 0 && s.slices_completed_bulk > 0,
            "engine {i} must complete both classes: {s:?}"
        );
        bytes_submitted += s.bytes_submitted;
    }
    assert!(
        bytes_submitted >= submitted_floor,
        "workload too small: {bytes_submitted}"
    );
    // Conservation vs the per-NIC byte counters: every slice carried
    // exactly once (retried slices are carried only by their successful
    // attempt).
    assert_eq!(
        fleet.carried_bytes(),
        bytes_submitted,
        "fabric byte counters must equal submitted payload"
    );
    // All queues drained; sharded counters never went negative.
    for rail in &fleet.cluster.fabric.rails {
        assert_eq!(rail.queued_bytes(), 0, "{} leaked queue", rail.id);
    }
    let clamps = fleet.cluster.fabric.contention.underflow_clamps.load(Ordering::Relaxed);
    assert_eq!(clamps, 0, "queued-bytes accounting underflowed");
}

#[test]
fn h800_8_nodes_concurrent_all_engines() {
    let fleet = Fleet::new(FleetConfig::new("h800_hgx", 8)).unwrap();
    let r = fleet.run_workload(&workload(400)).unwrap();
    assert_eq!(r.failed_batches, 0, "no injection -> no failures");
    assert!(r.total_batches >= 8 * 4, "batches: {}", r.total_batches);
    check_invariants(&fleet, 8 << 20);
    // Homogeneous fleet: nobody starves.
    assert!(r.per_engine_bytes.iter().all(|&b| b > 0), "{:?}", r.per_engine_bytes);
    assert!(
        r.fairness() >= 0.25,
        "fairness {:.3} ({:?})",
        r.fairness(),
        r.per_engine_bytes
    );
    // Lazy worker spawn: the workload is host-to-host, so GPU-only rails
    // (NVLink/PCIe) never cost a thread.
    let dp = fleet.cluster.datapath().expect("datapath up");
    assert!(dp.spawned_workers() > 0);
    assert!(
        dp.spawned_workers() < fleet.cluster.topo.rails.len(),
        "lazy spawn: {} of {} rails live",
        dp.spawned_workers(),
        fleet.cluster.topo.rails.len()
    );
    // Flag-gated wakeups coalesce under load.
    let coalesced: u64 = fleet
        .engines()
        .iter()
        .map(|e| e.stats().wakeups_coalesced)
        .sum();
    assert!(coalesced > 0, "busy rails must skip redundant unparks");
}

#[test]
fn h800_32_nodes_concurrent_all_engines() {
    let fleet = Fleet::new(FleetConfig::new("h800_hgx", 32)).unwrap();
    let r = fleet.run_workload(&workload(500)).unwrap();
    assert_eq!(r.failed_batches, 0);
    check_invariants(&fleet, 32 << 20);
    assert!(r.per_engine_bytes.iter().all(|&b| b > 0), "{:?}", r.per_engine_bytes);
    assert!(
        r.fairness() >= 0.15,
        "32-node fairness {:.3} ({:?})",
        r.fairness(),
        r.per_engine_bytes
    );
    // 32 engines share one fabric through one datapath: worker count is a
    // property of live rails, not engines x rails.
    let dp = fleet.cluster.datapath().expect("datapath up");
    assert!(
        dp.spawned_workers() < fleet.cluster.topo.rails.len(),
        "{} workers for {} rails",
        dp.spawned_workers(),
        fleet.cluster.topo.rails.len()
    );
}

#[test]
fn mixed_fleet_8_nodes_crosses_silos() {
    let fleet = Fleet::new(FleetConfig::new("mixed_fleet", 8)).unwrap();
    // Legacy nodes ride a single 10 Gbps TCP rail; shrink blocks so the
    // slow silo finishes inside the test budget.
    let w = WorkloadConfig {
        duration: Duration::from_millis(400),
        latency_block: 128 << 10,
        bulk_block: 512 << 10,
        ..Default::default()
    };
    let r = fleet.run_workload(&w).unwrap();
    assert_eq!(r.failed_batches, 0);
    check_invariants(&fleet, 4 << 20);
    // Heterogeneous silos: fairness is not ~1, but nobody is starved —
    // even the TCP-only nodes complete fetches from every silo.
    assert!(r.per_engine_bytes.iter().all(|&b| b > 0), "{:?}", r.per_engine_bytes);
}

#[test]
fn mixed_fleet_32_nodes_builds_and_moves() {
    let fleet = Fleet::new(FleetConfig::new("mixed_fleet", 32)).unwrap();
    assert_eq!(fleet.cluster.topo.nodes.len(), 32);
    let w = WorkloadConfig {
        duration: Duration::from_millis(400),
        latency_block: 128 << 10,
        bulk_block: 512 << 10,
        submitters_per_engine: 1,
        ..Default::default()
    };
    let r = fleet.run_workload(&w).unwrap();
    assert_eq!(r.failed_batches, 0);
    check_invariants(&fleet, 8 << 20);
    assert!(r.per_engine_bytes.iter().all(|&b| b > 0), "{:?}", r.per_engine_bytes);
}

#[test]
fn failure_and_recovery_mid_run_reconverges_all_engines() {
    let fleet = Fleet::new(FleetConfig::new("h800_hgx", 8)).unwrap();

    // Phase 1: clean traffic.
    let r1 = fleet.run_workload(&workload(250)).unwrap();
    assert_eq!(r1.failed_batches, 0);

    // Phase 2: kill two of node 1's NICs mid-run, recover before the end.
    let victims: Vec<_> = fleet
        .cluster
        .topo
        .rails_of(NodeId(1), FabricKind::Rdma)
        .into_iter()
        .take(2)
        .collect();
    let fabric = Arc::clone(&fleet.cluster.fabric);
    let v = victims.clone();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(80));
        for &r in &v {
            fabric.inject_failure(r);
        }
        std::thread::sleep(Duration::from_millis(150));
        for &r in &v {
            fabric.recover(r);
        }
    });
    let r2 = fleet.run_workload(&workload(400)).unwrap();
    killer.join().unwrap();
    // Dual-layer resilience masks the kill: batches all succeed even
    // though slices died on the failed rails and rerouted.
    assert_eq!(r2.failed_batches, 0, "failover must mask the rail kill");

    // Let probers re-admit the recovered rails everywhere.
    std::thread::sleep(Duration::from_millis(100));
    let before: Vec<u64> = victims
        .iter()
        .map(|&r| fleet.cluster.fabric.rail(r).bytes_carried.load(Ordering::Relaxed))
        .collect();

    // Phase 3: every engine re-converges — the recovered rails carry
    // fetch traffic again (node 1 is a random-peer source for all).
    let r3 = fleet.run_workload(&workload(400)).unwrap();
    assert_eq!(r3.failed_batches, 0);
    let regained: u64 = victims
        .iter()
        .zip(&before)
        .map(|(&r, &b)| {
            fleet
                .cluster
                .fabric
                .rail(r)
                .bytes_carried
                .load(Ordering::Relaxed)
                .saturating_sub(b)
        })
        .sum();
    assert!(regained > 0, "recovered rails must be re-integrated");

    // Conservation holds across the whole kill/recover history.
    check_invariants(&fleet, 16 << 20);
    assert!(r3.per_engine_bytes.iter().all(|&b| b > 0));
}
